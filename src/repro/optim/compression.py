"""PowerSGD-style low-rank gradient compression with error feedback.

Beyond-paper feature that REUSES the paper's insight: DFW-TRACE communicates
rank-1 factors (O(d+m)) instead of d x m gradients; PowerSGD generalizes the
same trick to rank-r compression of *backbone* data-parallel gradient syncs.
One power-method iteration per step (warm-started Q), orthonormalized P.

With an ``axis_name`` the psums are the only cross-device traffic for the
compressed tensors: r(d+m) floats instead of d*m. Without it the math still
runs (tests / reference).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from ..core.power_method import block_power_step, orthonormalize_block

PyTree = Any


class PowerSGDState(NamedTuple):
    q: PyTree  # per-compressed-leaf (m, r) warm-start factors
    error: PyTree  # per-compressed-leaf (d, m) error feedback


def _compressible(leaf: jax.Array, min_size: int) -> bool:
    return leaf.ndim >= 2 and leaf.size >= min_size


def _as2d(x: jax.Array) -> jax.Array:
    return x.reshape(x.shape[0], -1) if x.ndim != 2 else x


def init(params: PyTree, *, rank: int = 4, min_size: int = 4096, key=None) -> PowerSGDState:
    key = jax.random.PRNGKey(0) if key is None else key
    flat, treedef = jax.tree.flatten(params)
    qs, errs = [], []
    for i, p in enumerate(flat):
        if _compressible(p, min_size):
            m = _as2d(p).shape[1]
            qs.append(jax.random.normal(jax.random.fold_in(key, i), (m, rank), jnp.float32))
            errs.append(jnp.zeros(_as2d(p).shape, jnp.float32))
        else:
            qs.append(None)
            errs.append(None)
    return PowerSGDState(
        q=jax.tree.unflatten(treedef, qs), error=jax.tree.unflatten(treedef, errs)
    )


# Orthonormalization is the shared Cholesky-QR primitive from the FW block
# power method (core/power_method.py). The PowerSGD approximation
# P P^T G = P Q'^T is a projection onto span(P) — basis-invariant — so
# swapping QR for Cholesky-QR leaves the compressed gradient (and the error
# feedback) mathematically unchanged.
_orthonormalize = orthonormalize_block


def compress_and_sync(
    grads: PyTree,
    state: PowerSGDState,
    *,
    min_size: int = 4096,
    axis_name: Optional[str] = None,
) -> Tuple[PyTree, PowerSGDState]:
    """Replace each large-2D grad with its rank-r sync'd approximation.

    Small leaves are psum-averaged exactly. Returns (synced_grads, new_state).

    Each compressed leaf runs exactly one warm-started half-pair of block
    power iteration — ``power_method.block_power_step``, the same primitive
    the ``block:k`` FW solver iterates — with ``reduce`` = pmean (PowerSGD
    averages gradients where the LMO psums them).
    """

    def psum_mean(x):
        if axis_name is None:
            return x
        return jax.lax.pmean(x, axis_name)

    def one(g, q, e):
        if q is None:
            return psum_mean(g), None, None
        g2 = _as2d(g).astype(jnp.float32) + e  # error feedback
        # One block power step: p = orth(pmean(G q)); q' = pmean(G^T p).
        # The two reduced (d,r)/(m,r) blocks are the only wire traffic.
        p, q_new = block_power_step(
            lambda qq: g2 @ qq, lambda pp: g2.T @ pp, q, reduce=psum_mean
        )
        approx = p @ q_new.T
        e_new = g2 - approx
        return approx.reshape(g.shape).astype(g.dtype), q_new, e_new

    flat_g, treedef = jax.tree.flatten(grads)
    flat_q = treedef.flatten_up_to(state.q)
    flat_e = treedef.flatten_up_to(state.error)
    outs = [one(g, q, e) for g, q, e in zip(flat_g, flat_q, flat_e)]
    synced = jax.tree.unflatten(treedef, [o[0] for o in outs])
    new_q = jax.tree.unflatten(treedef, [o[1] for o in outs])
    new_e = jax.tree.unflatten(treedef, [o[2] for o in outs])
    return synced, PowerSGDState(q=new_q, error=new_e)


def wire_bytes(params: PyTree, *, rank: int = 4, min_size: int = 4096) -> Dict[str, int]:
    """Bytes-on-wire per DP sync: compressed vs dense (paper Table-1 analogue)."""
    dense = 0
    compressed = 0
    for p in jax.tree.leaves(params):
        nbytes = p.size * 4
        if _compressible(p, min_size):
            d, m = _as2d(p).shape
            compressed += 4 * rank * (d + m)
        else:
            compressed += nbytes
        dense += nbytes
    return {"dense": dense, "compressed": compressed}
