from . import adamw, compression, hybrid, schedule
from .adamw import AdamWState
from .compression import PowerSGDState

__all__ = ["adamw", "compression", "hybrid", "schedule", "AdamWState", "PowerSGDState"]
