"""AdamW from scratch (no optax dependency), FSDP-friendly.

State mirrors the param pytree (m, v in f32) so any param sharding applies
verbatim to the optimizer state — ZeRO-style when params are FSDP-sharded.
"""
from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array  # () int32
    m: PyTree  # f32, like params
    v: PyTree  # f32, like params


def init(params: PyTree) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros, v=jax.tree.map(jnp.copy, zeros))


def update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    *,
    lr: jax.Array | float,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
) -> Tuple[PyTree, AdamWState]:
    """Returns (new_params, new_state)."""
    step = state.step + 1
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        m = b1 * m + (1.0 - b1) * g32
        v = b2 * v + (1.0 - b2) * (g32 * g32)
        mh = m / c1
        vh = v / c2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
