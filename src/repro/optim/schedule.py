"""Learning-rate schedules (pure functions of the step counter)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr: float, warmup: int, total: int, floor: float = 0.1):
    step = step.astype(jnp.float32)
    warm = peak_lr * step / max(warmup, 1)
    frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * frac)))
    return jnp.where(step < warmup, warm, cos)


def constant(step, *, peak_lr: float):
    return jnp.full_like(step, peak_lr, dtype=jnp.float32)
