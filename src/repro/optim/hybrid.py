"""Hybrid optimizer: AdamW backbone + DFW-TRACE trace-norm-constrained head.

The paper's technique as a first-class training-loop feature: the unembedding
head W (d_model x vocab) is optimized with Frank-Wolfe steps inside the
trace-norm ball ||W||_* <= mu (rank-1 update per step, LMO via the power
method on the head gradient), while every other parameter takes AdamW.

Distribution: the head gradient is already data-parallel-summed by the
surrounding pjit (GSPMD inserts the reduction); on top of that the FW update
itself only *applies* a rank-1 matrix — per-step head traffic beyond the
gradient psum is O(d + V), the paper's headline property. With the head
gradient sharded (vocab over 'model'), the power-method matvecs run sharded
and psum O(d)/O(V/16) vectors.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.power_method import power_iterations, sphere_vector

from . import adamw, schedule

PyTree = Any


class HybridState(NamedTuple):
    adam: adamw.AdamWState  # over backbone params (head slots zero-masked)
    fw_step: jax.Array  # () int32 — FW epoch counter t


def init(params: PyTree) -> HybridState:
    return HybridState(adam=adamw.init(params), fw_step=jnp.zeros((), jnp.int32))


def make_hybrid_train_step(
    cfg,
    *,
    mu: float = 100.0,
    power_iters: int = 2,
    peak_lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    head_key: str = "unembed",
):
    """Returns train_step(params, state, batch, key) for untied-head configs."""
    from repro.models import lm

    if cfg.tie_embeddings:
        raise ValueError("hybrid DFW head requires an untied unembedding")

    def train_step(params: Dict, state: HybridState, batch, key):
        (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
            params, batch, cfg
        )
        g_head = grads[head_key].astype(jnp.float32)  # (d, V)

        # --- DFW-TRACE step on the head -----------------------------------
        t = state.fw_step.astype(jnp.float32)
        v0 = sphere_vector(jax.random.fold_in(key, state.fw_step), g_head.shape[1])
        res = power_iterations(
            lambda v: g_head @ v, lambda u: g_head.T @ u, v0, power_iters
        )
        gamma = 2.0 / (t + 2.0)
        head_new = (
            (1.0 - gamma) * params[head_key].astype(jnp.float32)
            - (gamma * mu) * jnp.outer(res.u, res.v)
        ).astype(params[head_key].dtype)

        # --- AdamW on everything else --------------------------------------
        grads = dict(grads, **{head_key: jnp.zeros_like(grads[head_key])})
        lr = schedule.cosine_with_warmup(
            state.adam.step, peak_lr=peak_lr, warmup=warmup, total=total_steps
        )
        new_params, new_adam = adamw.update(grads, state.adam, params, lr=lr)
        new_params = dict(new_params, **{head_key: head_new})

        metrics = dict(metrics, loss=loss, fw_gamma=gamma, fw_sigma=res.sigma)
        return new_params, HybridState(adam=new_adam, fw_step=state.fw_step + 1), metrics

    return train_step
