"""Deterministic, resumable, host-sharded synthetic data pipeline.

Real deployments stream tokenized shards from blob storage; the structure
here is identical (per-host shard assignment, stateless step->batch mapping)
with a synthetic generator standing in for disk I/O, so the training loop,
checkpoint/restart and elasticity logic exercise the same control flow they
would at scale.

Key property: ``batch_for_step(step)`` is a pure function of (seed, step,
host_id/num_hosts) — restart or re-shard at any step reproduces the exact
stream with no iterator state to snapshot beyond the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig, ShapeSpec


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 17
    # markov-chain synthetic text: next ~ (cur * a + noise) % vocab; gives the
    # model nontrivial structure to learn (loss decreases measurably).
    structure: int = 8


class SyntheticLMStream:
    def __init__(
        self,
        cfg: ModelConfig,
        shape: ShapeSpec,
        data_cfg: DataConfig = DataConfig(),
        *,
        host_id: int = 0,
        num_hosts: int = 1,
    ):
        self.cfg = cfg
        self.shape = shape
        self.data_cfg = data_cfg
        self.host_id = host_id
        self.num_hosts = num_hosts
        assert shape.global_batch % num_hosts == 0
        self.local_batch = shape.global_batch // num_hosts

    def batch_for_step(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step: deterministic, resumable, elastic-safe."""
        b, s, v = self.local_batch, self.shape.seq_len, self.cfg.vocab_size
        rng = np.random.default_rng(
            (self.data_cfg.seed * 1_000_003 + step) * 4096 + self.host_id
        )
        if self.cfg.family == "audio":
            frames = rng.standard_normal((b, s, self.cfg.frontend_dim), np.float32)
            labels = rng.integers(0, v, (b, s)).astype(np.int32)
            return {"frames": frames, "labels": labels}

        k = self.data_cfg.structure
        start = rng.integers(0, v, (b, 1))
        steps = rng.integers(0, k, (b, s)) + 1
        toks = (np.cumsum(steps, axis=1) + start) % v
        toks = toks.astype(np.int32)
        labels = np.roll(toks, -1, axis=1)
        labels[:, -1] = toks[:, 0]
        batch = {"tokens": toks, "labels": labels}
        if self.cfg.family == "vlm":
            sv = self.cfg.vision_tokens
            batch["tokens"] = toks[:, : s - sv]
            batch["vision_embeds"] = rng.standard_normal(
                (b, sv, self.cfg.d_model), np.float32
            )
            pos = np.broadcast_to(np.arange(s)[None, None, :], (b, 3, s))
            batch["positions"] = np.ascontiguousarray(pos, np.int32)
        return batch

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_for_step(step)
            step += 1


def device_put_batch(batch: Dict[str, np.ndarray], shardings: Optional[Dict] = None):
    if shardings is None:
        return jax.tree.map(jnp.asarray, batch)
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
