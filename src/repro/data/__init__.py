from . import pipeline
from .pipeline import DataConfig, SyntheticLMStream, device_put_batch

__all__ = ["pipeline", "DataConfig", "SyntheticLMStream", "device_put_batch"]
