"""Span tracing + event stream with JSONL and Chrome-trace sinks.

A :class:`Telemetry` handle is the single object threaded through
``DFWConfig``, ``frank_wolfe.fit`` and ``ServeConfig``. It owns

* a :class:`~repro.obs.registry.MetricsRegistry` (aggregates),
* a bounded in-memory event stream (the timeline), and
* export sinks: ``write_jsonl`` (one JSON object per line) and
  ``write_chrome_trace`` (a ``chrome://tracing`` / Perfetto-loadable
  trace), plus an optional ``jax.profiler`` hook for XLA-level capture.

Zero-sync discipline: nothing in this module touches a device value.
Instrumentation sites hand in host scalars they already have — engine
epoch scalars ride the existing segment-boundary ``device_get``, comm
bytes are computed analytically / from HLO once per executable, and
checkpoint latency is stamped on the writer thread. The no-op handle
(``Telemetry.noop()``) records nothing and allocates nothing per call;
its overhead is pinned by ``analysis/contracts.py`` via
:func:`noop_contract`.

Events are stored in Chrome trace-event form (ph "X" complete spans,
"i" instants, "C" counter samples) so both sinks serialize the same
dicts; timestamps are microseconds from the handle's creation
(``time.perf_counter`` based — monotonic, sub-us resolution).
"""
from __future__ import annotations

import json
import os
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

from .registry import MetricsRegistry

__all__ = ["Telemetry", "noop_contract"]


class _NullSpan:
    """Shared do-nothing span returned by a disabled handle."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _Span:
    """Context manager emitting one ph="X" complete event on exit."""

    __slots__ = ("_tel", "_name", "_cat", "_t0", "_args")

    def __init__(self, tel: "Telemetry", name: str, cat: str,
                 t0: Optional[float], args: Dict[str, Any]):
        self._tel = tel
        self._name = name
        self._cat = cat
        self._t0 = t0
        self._args = args

    def __enter__(self):
        if self._t0 is None:
            self._t0 = self._tel.now_us()
        return self

    def __exit__(self, exc_type, exc, tb):
        self._tel.complete(self._name, self._cat, self._t0,
                           self._tel.now_us() - self._t0, **self._args)
        return False


class Telemetry:
    """Run-wide telemetry handle (metrics registry + event stream + sinks).

    Parameters
    ----------
    enabled:
        When False the handle is inert: every record call is a cheap
        no-op, ``span()`` returns a shared null context manager, and the
        event stream stays empty. ``Telemetry.noop()`` returns a module
        singleton built this way.
    capture_hlo:
        Allow instrumentation sites to take the ahead-of-time compile
        path and run ``analysis/hlo.py`` over each executable (once per
        compile, never per step). Off by default only on the noop handle.
    max_events:
        Hard cap on the in-memory stream; past it events are counted as
        dropped rather than appended, so a runaway loop cannot exhaust
        host memory.
    profiler_dir:
        When set, ``profiler()`` brackets the run with
        ``jax.profiler.start_trace/stop_trace`` writing XLA-level data
        there; when None the hook is a no-op.
    """

    def __init__(self, enabled: bool = True, *, capture_hlo: bool = True,
                 max_events: int = 200_000,
                 profiler_dir: Optional[str] = None):
        self.enabled = bool(enabled)
        self.capture_hlo = bool(capture_hlo)
        self.max_events = int(max_events)
        self.profiler_dir = profiler_dir
        self.registry = MetricsRegistry()
        self._events: List[Dict[str, Any]] = []
        self._dropped = 0
        self._lock = threading.Lock()  # checkpoint writer thread emits too
        self._pid = os.getpid()
        self._t0_perf = time.perf_counter()
        self._t0_unix = time.time()

    # -- time ---------------------------------------------------------------

    def now_us(self) -> float:
        """Microseconds since this handle was created (monotonic)."""
        return (time.perf_counter() - self._t0_perf) * 1e6

    # -- recording ----------------------------------------------------------

    @property
    def wants_hlo(self) -> bool:
        return self.enabled and self.capture_hlo

    def _append(self, ev: Dict[str, Any]) -> None:
        # Lock-free on the common path: list.append is atomic under the
        # GIL, which is all the concurrent checkpoint-writer thread needs.
        # The cap check races benignly — a burst can overshoot max_events
        # by at most one event per appending thread. Measured in situ this
        # halves the per-event cost on the serving fetch path.
        if len(self._events) < self.max_events:
            self._events.append(ev)
        else:
            with self._lock:
                self._dropped += 1

    def span(self, name: str, cat: str = "run",
             t0: Optional[float] = None, **args: Any):
        """Context manager producing a complete ("X") event on exit.

        ``t0`` (microseconds, from :meth:`now_us`) backdates the span
        start — used when the enclosing work began before the handle
        could be consulted (e.g. a dispatch whose wall time is only
        known at the blocking fetch).
        """
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, cat, t0, args)

    def complete(self, name: str, cat: str, ts_us: float, dur_us: float,
                 **args: Any) -> None:
        """Record a retroactive complete span [ts_us, ts_us + dur_us]."""
        if not self.enabled:
            return
        self._append({"name": name, "cat": cat, "ph": "X",
                      "ts": round(ts_us, 3), "dur": round(max(dur_us, 0.0), 3),
                      "pid": self._pid, "tid": threading.get_ident(),
                      "args": args})

    def event(self, name: str, cat: str = "run",
              ts_us: Optional[float] = None, **args: Any) -> None:
        """Record an instant ("i") event, e.g. early_stop or hot_swap."""
        if not self.enabled:
            return
        self._append({"name": name, "cat": cat, "ph": "i", "s": "t",
                      "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                      "pid": self._pid, "tid": threading.get_ident(),
                      "args": args})

    def counter_sample(self, name: str, value: float, cat: str = "metrics",
                       ts_us: Optional[float] = None) -> None:
        """Record a ph="C" counter sample (renders as a track in Perfetto)."""
        if not self.enabled:
            return
        self._append({"name": name, "cat": cat, "ph": "C",
                      "ts": round(self.now_us() if ts_us is None else ts_us, 3),
                      "pid": self._pid, "tid": 0,
                      "args": {"value": value}})

    def event_count(self) -> int:
        with self._lock:
            return len(self._events)

    def events(self) -> List[Dict[str, Any]]:
        """Copy of the event stream (Chrome trace-event dicts)."""
        with self._lock:
            return list(self._events)

    # -- jax.profiler hook --------------------------------------------------

    @contextmanager
    def profiler(self):
        """Bracket a region with XLA-level capture when profiler_dir is set."""
        if not (self.enabled and self.profiler_dir):
            yield
            return
        import jax

        jax.profiler.start_trace(self.profiler_dir)
        try:
            yield
        finally:
            jax.profiler.stop_trace()

    # -- sinks --------------------------------------------------------------

    def _meta(self) -> Dict[str, Any]:
        return {"type": "meta", "t0_unix": self._t0_unix, "pid": self._pid,
                "clock": "us_since_start", "dropped_events": self._dropped,
                "max_events": self.max_events}

    def write_jsonl(self, path) -> None:
        """One JSON object per line: meta, then events, then a final
        ``{"type": "metrics", ...}`` registry snapshot."""
        events = self.events()
        with open(path, "w") as fh:
            fh.write(json.dumps(self._meta()) + "\n")
            for ev in events:
                fh.write(json.dumps(ev) + "\n")
            fh.write(json.dumps({"type": "metrics",
                                 "data": self.registry.snapshot()}) + "\n")

    def write_chrome_trace(self, path) -> None:
        """Chrome trace JSON (open in Perfetto / chrome://tracing)."""
        doc = {
            "traceEvents": self.events(),
            "displayTimeUnit": "ms",
            "otherData": {"meta": self._meta(),
                          "metrics": self.registry.snapshot()},
        }
        with open(path, "w") as fh:
            json.dump(doc, fh)

    # -- no-op singleton ----------------------------------------------------

    _NOOP: Optional["Telemetry"] = None

    @classmethod
    def noop(cls) -> "Telemetry":
        """Shared inert handle — the default everywhere a Telemetry is
        accepted. Records nothing; its per-span overhead is contract-pinned."""
        if cls._NOOP is None:
            cls._NOOP = cls(enabled=False, capture_hlo=False, max_events=0)
        return cls._NOOP


def noop_contract():
    """Contract pinning the disabled handle: sub-50us span entry/exit and
    a permanently empty event stream. Checked by ``make analyze`` probe 4."""
    from repro.analysis.contracts import Contract

    return Contract(name="obs.noop_overhead", max_noop_span_us=50.0,
                    max_events=0)
