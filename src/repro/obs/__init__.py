"""repro.obs: zero-sync telemetry spine (metrics, spans, trace export).

Public surface:

* :class:`MetricsRegistry` / :class:`Counter` / :class:`Gauge` /
  :class:`Histogram` — process-local aggregates with ``snapshot()`` and
  ``reset()``.
* :class:`Telemetry` — the handle threaded through ``DFWConfig``,
  ``frank_wolfe.fit`` and ``ServeConfig``: span tracing, instant events,
  counter samples, JSONL + Chrome-trace sinks, ``jax.profiler`` hook.
  ``Telemetry.noop()`` is the inert default.
* :func:`noop_contract` — the ``analysis/contracts.py`` clause pinning
  the no-op handle's overhead (``make analyze`` probe 4).

Design rule (see docs/OBSERVABILITY.md): this package imports only the
standard library; instrumentation never adds a host sync — every scalar
recorded here was already on the host.
"""
from .registry import Counter, Gauge, Histogram, MetricsRegistry
from .telemetry import Telemetry, noop_contract

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Telemetry",
    "noop_contract",
]
