"""Process-local metrics registry: counters, gauges, and histograms.

The registry is the *aggregate* half of the telemetry spine (the event
stream in :mod:`repro.obs.telemetry` is the timeline half). Instruments
are plain Python objects mutated from host code only — never from inside
a traced/jitted function — so updating one can never introduce a device
sync. ``snapshot()`` returns a JSON-ready dict and ``reset()`` zeroes
every instrument in place (handles stay valid), which is what the serving
engine's registry-backed ``stats`` and the benchmark harness both rely on.

Thread safety: instruments are updated under the registry lock only when
callers opt in (the checkpoint writer thread does); the single-writer hot
paths (engine boundary code, serving dispatch) use bare ``+=`` on floats,
which is adequate for monitoring counters and costs nothing.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Optional

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


class Counter:
    """Monotonically increasing value (resettable via the registry)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def inc(self, amount: float = 1.0) -> None:
        self.value += amount

    def snapshot(self) -> float:
        return self.value

    def reset(self) -> None:
        self.value = 0.0


class Gauge:
    """Last-write-wins scalar (e.g. current gap, current sigma)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value: Optional[float] = None

    def set(self, value: float) -> None:
        self.value = float(value)

    def snapshot(self) -> Optional[float]:
        return self.value

    def reset(self) -> None:
        self.value = None


# Bucket upper bounds in powers of two: 1us .. ~67s, plus +inf. Fixed
# log2 buckets mean observe() is a bit_length() call, not a bisect, and
# two histograms from different runs can always be merged bucket-wise.
_NUM_BUCKETS = 27


class Histogram:
    """Log2-bucketed histogram with count/sum/min/max summary stats.

    Bucket ``i`` counts observations in ``[2**(i-1), 2**i)`` (bucket 0 is
    ``[0, 1)``); the final bucket is the overflow. Intended unit is
    microseconds for latency series but any nonnegative value works.
    """

    __slots__ = ("name", "count", "total", "min", "max", "buckets")

    def __init__(self, name: str):
        self.name = name
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.buckets: List[int] = [0] * _NUM_BUCKETS

    def observe(self, value: float) -> None:
        v = float(value)
        self.count += 1
        self.total += v
        if self.min is None or v < self.min:
            self.min = v
        if self.max is None or v > self.max:
            self.max = v
        idx = int(v).bit_length() if v >= 1.0 else 0
        self.buckets[min(idx, _NUM_BUCKETS - 1)] += 1

    @property
    def mean(self) -> Optional[float]:
        return self.total / self.count if self.count else None

    def snapshot(self) -> Dict[str, object]:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min,
            "max": self.max,
            "mean": self.mean,
            # sparse {bucket_index: count}; upper bound of bucket i is 2**i
            "buckets": {str(i): c for i, c in enumerate(self.buckets) if c},
        }

    def reset(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = None
        self.max = None
        self.buckets = [0] * _NUM_BUCKETS


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    ``counter``/``gauge``/``histogram`` return the same object for the
    same name, so call sites can resolve instruments once at setup time
    and hold the handle (the serving engine does exactly this for its
    ``stats`` counters).
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._gauges: Dict[str, Gauge] = {}
        self._histograms: Dict[str, Histogram] = {}

    def counter(self, name: str) -> Counter:
        with self._lock:
            inst = self._counters.get(name)
            if inst is None:
                inst = self._counters[name] = Counter(name)
            return inst

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            inst = self._gauges.get(name)
            if inst is None:
                inst = self._gauges[name] = Gauge(name)
            return inst

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            inst = self._histograms.get(name)
            if inst is None:
                inst = self._histograms[name] = Histogram(name)
            return inst

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """JSON-ready view: {"counters": {...}, "gauges": {...}, "histograms": {...}}."""
        with self._lock:
            return {
                "counters": {n: c.snapshot() for n, c in sorted(self._counters.items())},
                "gauges": {n: g.snapshot() for n, g in sorted(self._gauges.items())},
                "histograms": {n: h.snapshot() for n, h in sorted(self._histograms.items())},
            }

    def reset(self) -> None:
        """Zero every instrument in place; existing handles remain valid."""
        with self._lock:
            for c in self._counters.values():
                c.reset()
            for g in self._gauges.values():
                g.reset()
            for h in self._histograms.values():
                h.reset()
