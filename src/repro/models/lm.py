"""Unified language-model zoo: init / forward / loss / decode for all families.

Families:
  dense | moe | vlm | audio  — (pre-norm GQA transformer; MoE swaps the FFN;
                                vlm/audio differ only in the input frontend)
  hybrid                     — zamba2: stacks of Mamba2 layers with one SHARED
                                attention+MLP block applied every
                                ``hybrid_block`` layers (9 applications)
  ssm                        — rwkv6: time-mix + channel-mix, attention-free

Layers run under lax.scan over stacked parameters (compact HLO, fast SPMD
compiles); remat policy per config. Everything is parameter-dict based.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import active_mesh, shard

from . import layers as L
from . import mamba2, moe, rwkv6
from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _stack_init(fn, key, n: int):
    """vmap a per-layer init over n layer keys -> stacked (n, ...) params."""
    return jax.vmap(fn)(jax.random.split(key, n))


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    cfg.validate()
    dt = cfg.jnp_dtype
    d = cfg.d_model
    keys = jax.random.split(key, 8)
    p: Params = {}

    if cfg.family == "audio":
        p["frame_proj"] = (
            jax.random.normal(keys[0], (cfg.frontend_dim, d), dt) * cfg.frontend_dim**-0.5
        )
    p["embed"] = jax.random.normal(keys[1], (cfg.vocab_size, d), dt) * d**-0.5

    if cfg.family in ("dense", "moe", "vlm", "audio"):

        def one_layer(k):
            k1, k2 = jax.random.split(k)
            lp = {
                "ln1": jnp.ones((d,), dt),
                "attn": L.init_attention(k1, cfg, dt),
                "ln2": jnp.ones((d,), dt),
            }
            if cfg.family == "moe":
                lp["moe"] = moe.init_moe(k2, cfg, dt)
                if cfg.moe_dense_residual:
                    k3 = jax.random.fold_in(k2, 1)
                    lp["mlp"] = L.init_mlp(
                        k3, d, cfg.moe_dense_ff or cfg.d_ff, cfg.mlp_type, dt
                    )
            else:
                lp["mlp"] = L.init_mlp(k2, d, cfg.d_ff, cfg.mlp_type, dt)
            return lp

        p["layers"] = _stack_init(one_layer, keys[2], cfg.num_layers)

    elif cfg.family == "hybrid":

        def one_mamba(k):
            return {"ln": jnp.ones((d,), dt), "mamba": mamba2.init_mamba(k, cfg, dt)}

        p["layers"] = _stack_init(one_mamba, keys[2], cfg.num_layers)
        k1, k2 = jax.random.split(keys[3])
        p["shared"] = {
            "ln1": jnp.ones((d,), dt),
            "attn": L.init_attention(k1, cfg, dt),
            "ln2": jnp.ones((d,), dt),
            "mlp": L.init_mlp(k2, d, cfg.d_ff, cfg.mlp_type, dt),
        }

    elif cfg.family == "ssm":

        def one_rwkv(k):
            return {
                "ln1": jnp.ones((d,), dt),
                "ln2": jnp.ones((d,), dt),
                "tm_cm": rwkv6.init_rwkv(k, cfg, dt),
            }

        p["layers"] = _stack_init(one_rwkv, keys[2], cfg.num_layers)
    else:
        raise ValueError(cfg.family)

    p["final_norm"] = jnp.ones((d,), dt)
    if not cfg.tie_embeddings:
        p["unembed"] = jax.random.normal(keys[4], (d, cfg.vocab_size), dt) * d**-0.5
    return p


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def _embed_inputs(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig):
    """Returns (h (B,S,D), angles or None)."""
    if cfg.family == "audio":
        h = batch["frames"].astype(cfg.jnp_dtype) @ params["frame_proj"]
        b, s, d = h.shape
        # stub positional encoding (the real model uses a conv pos-embed)
        half = d // 2
        inv = 10000 ** (-jnp.arange(half, dtype=jnp.float32) / half)
        pos = jnp.arange(s, dtype=jnp.float32)[:, None] * inv
        pe = jnp.concatenate([jnp.sin(pos), jnp.cos(pos)], -1).astype(h.dtype)
        return shard(h + pe, "batch", "seq_act", "embed"), None

    tok = params["embed"][batch["tokens"]]  # gather; vocab-sharded table
    if cfg.family == "vlm":
        h = jnp.concatenate(
            [batch["vision_embeds"].astype(tok.dtype), tok], axis=1
        )
        angles = L.mrope_angles(
            batch["positions"], cfg.head_dim_, cfg.rope_theta, cfg.mrope_sections
        )
    else:
        h = tok
        b, s = batch["tokens"].shape
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
        angles = L.rope_angles(positions, cfg.head_dim_, cfg.rope_theta)
        if cfg.family == "ssm":
            angles = None
    return shard(h, "batch", "seq_act", "embed"), angles


def _unembed(params: Params, h: jax.Array, cfg: ModelConfig) -> jax.Array:
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = h @ w.astype(h.dtype)
    return shard(logits, "batch", None, "vocab")


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
        )
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------


def forward(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
    *,
    mode: str = "train",  # train | prefill
) -> Dict[str, Any]:
    h, angles = _embed_inputs(params, batch, cfg)
    prefill = mode == "prefill"
    aux0 = jnp.zeros((), jnp.float32)
    cache = None

    if cfg.family in ("dense", "moe", "vlm", "audio"):

        def block(carry, lp):
            hh, aux = carry
            a_in = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            attn_out, kv = L.attention_block(
                lp["attn"], a_in, cfg, angles=angles, return_kv=prefill
            )
            hh = hh + attn_out
            m_in = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                mo, al = moe.moe_block(lp["moe"], m_in, cfg)
                if cfg.moe_dense_residual:
                    mo = mo + L.mlp_block(lp["mlp"], m_in, cfg.mlp_type)
                aux = aux + al
            else:
                mo = L.mlp_block(lp["mlp"], m_in, cfg.mlp_type)
            hh = shard(hh + mo, "batch", "seq_act", "embed")
            return (hh, aux), (kv if prefill else None)

        (h, aux0), kvs = jax.lax.scan(_maybe_remat(block, cfg), (h, aux0), params["layers"])
        if prefill and kvs is not None:
            cache = {"k": kvs[0], "v": kvs[1]}  # (L, B, Hkv, S, Dh)

    elif cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.hybrid_block
        grouped = jax.tree.map(
            lambda x: x.reshape((nb, cfg.hybrid_block) + x.shape[1:]), params["layers"]
        )
        shared = params["shared"]
        shared_kvs, m_h, m_conv = [], [], []

        def mblock(hh, lp):
            out = mamba2.mamba_block(
                lp["mamba"], L.rms_norm(hh, lp["ln"], cfg.norm_eps), cfg,
                return_state=prefill,
            )
            if prefill:
                y, mcache = out
                return hh + y, (mcache.h, mcache.conv)
            return hh + out, None

        for i in range(nb):
            blk = jax.tree.map(lambda x: x[i], grouped)
            h, ys = jax.lax.scan(_maybe_remat(mblock, cfg), h, blk)
            if prefill:
                m_h.append(ys[0])
                m_conv.append(ys[1])
            a_in = L.rms_norm(h, shared["ln1"], cfg.norm_eps)
            attn_out, kv = L.attention_block(
                shared["attn"], a_in, cfg, angles=angles, return_kv=prefill
            )
            h = h + attn_out
            h = h + L.mlp_block(
                shared["mlp"], L.rms_norm(h, shared["ln2"], cfg.norm_eps), cfg.mlp_type
            )
            if prefill:
                shared_kvs.append(kv)
        if prefill:
            cache = {
                "k": jnp.stack([kv[0] for kv in shared_kvs]),
                "v": jnp.stack([kv[1] for kv in shared_kvs]),
                "mamba_h": jnp.concatenate(m_h, axis=0),
                "mamba_conv": jnp.concatenate(m_conv, axis=0).astype(cfg.jnp_dtype),
            }

    elif cfg.family == "ssm":
        b = h.shape[0]
        zeros_x = jnp.zeros((b, cfg.d_model), h.dtype)
        s0 = jnp.zeros((b, cfg.d_model // rwkv6.HEAD, rwkv6.HEAD, rwkv6.HEAD), jnp.float32)

        def block(hh, lp):
            y, s_n, x_tm = rwkv6.time_mix(
                lp["tm_cm"], L.rms_norm(hh, lp["ln1"], cfg.norm_eps), cfg, zeros_x, s0
            )
            hh = hh + y
            cm_in = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
            cm, x_cm = rwkv6.channel_mix(lp["tm_cm"], cm_in, zeros_x)
            ys = (
                (s_n, x_tm.astype(jnp.float32), x_cm.astype(jnp.float32))
                if prefill else None
            )
            return hh + cm, ys

        h, ys = jax.lax.scan(_maybe_remat(block, cfg), h, params["layers"])
        if prefill:
            cache = {"s": ys[0], "x_tm": ys[1], "x_cm": ys[2]}
    else:
        raise ValueError(cfg.family)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    out = {"hidden": h, "aux_loss": aux0}
    if mode != "hidden":
        out["logits"] = _unembed(params, h, cfg)
    if prefill:
        out["cache"] = cache
    return out


# ---------------------------------------------------------------------------
# Loss / train objective
# ---------------------------------------------------------------------------


def _chunked_ce(h: jax.Array, labels: jax.Array, w: jax.Array, chunk: int):
    """Cross entropy without materializing full-sequence f32 logits.

    Scans over sequence chunks; the chunk logits are rematerialized in the
    backward pass (jax.checkpoint), so live memory is one (B, chunk, V) slab
    instead of (B, S, V). The unembed wgrad accumulates across chunks."""
    b, s, d = h.shape
    if s % chunk:
        chunk = s  # fallback: single chunk
    n = s // chunk
    hc = h.reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(b, n, chunk).transpose(1, 0, 2)

    @jax.checkpoint
    def body(acc, args):
        hi, li = args
        logits = shard((hi @ w).astype(jnp.float32), "batch", None, "vocab")
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        return acc + jnp.sum(lse - gold), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
    return total / (b * s)


def loss_fn(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            *, loss_chunk: int = 512):
    from repro.launch.sharding import axes_size

    out = forward(params, batch, cfg, mode="hidden")
    h = out["hidden"]
    labels = batch["labels"]
    if cfg.family == "vlm":  # loss over text positions only
        ntext = batch["tokens"].shape[1]
        h = h[:, -ntext:, :]
        labels = labels[:, -ntext:]
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    if axes_size("seq_act") > 1:
        # SP profile: the seq dim is sharded over the model axis, so the full
        # logits fit (1/16 of rows per device) — chunk-scanning would break
        # the seq sharding and replicate the vocab matmul on every shard.
        logits = shard((h @ w.astype(h.dtype)).astype(jnp.float32),
                       "batch", "seq_act", None)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
    else:
        ce = _chunked_ce(h, labels, w.astype(h.dtype), loss_chunk)
    total = ce + 0.01 * out["aux_loss"]
    return total, {"ce": ce, "aux": out["aux_loss"]}


# ---------------------------------------------------------------------------
# Decode (serve_step)
# ---------------------------------------------------------------------------


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    """Allocated decode cache (smoke tests); mirror of cache_specs."""
    return jax.tree.map(
        lambda s: jnp.zeros(s.shape, s.dtype), cache_specs(cfg, batch, max_len)
    )


def cache_specs(cfg: ModelConfig, batch: int, max_len: int) -> Dict[str, Any]:
    dt = cfg.jnp_dtype
    hkv, dh = cfg.num_kv_heads, cfg.head_dim_
    sds = jax.ShapeDtypeStruct
    if cfg.family in ("dense", "moe", "vlm"):
        nl = cfg.num_layers
        return {
            "k": sds((nl, batch, hkv, max_len, dh), dt),
            "v": sds((nl, batch, hkv, max_len, dh), dt),
        }
    if cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.hybrid_block
        d_inner, nh, hd, n = mamba2.dims(cfg)
        conv_dim = d_inner + 2 * n
        return {
            "k": sds((nb, batch, hkv, max_len, dh), dt),
            "v": sds((nb, batch, hkv, max_len, dh), dt),
            "mamba_h": sds((cfg.num_layers, batch, nh, hd, n), jnp.float32),
            "mamba_conv": sds((cfg.num_layers, batch, cfg.d_conv - 1, conv_dim), dt),
        }
    if cfg.family == "ssm":
        nl, d = cfg.num_layers, cfg.d_model
        return {
            "s": sds((nl, batch, d // rwkv6.HEAD, rwkv6.HEAD, rwkv6.HEAD), jnp.float32),
            "x_tm": sds((nl, batch, d), jnp.float32),
            "x_cm": sds((nl, batch, d), jnp.float32),
        }
    raise ValueError(cfg.family)


def decode_step(
    params: Params,
    cache: Dict[str, Any],
    batch: Dict[str, jax.Array],
    cfg: ModelConfig,
) -> Tuple[jax.Array, Dict[str, Any]]:
    """One token for every sequence in the batch. tokens: (B, 1)."""
    tokens, pos = batch["tokens"], batch["cache_pos"]
    b = tokens.shape[0]
    h = shard(params["embed"][tokens], "batch", None, "embed")
    mesh = active_mesh()
    seq_sharded = b == 1 and mesh is not None and cfg.family != "ssm"

    if cfg.family == "vlm":
        angles = L.mrope_angles(
            batch["positions"], cfg.head_dim_, cfg.rope_theta, cfg.mrope_sections
        )
    elif cfg.family == "ssm":
        angles = None
    else:
        positions = jnp.broadcast_to(pos[None, None], (b, 1))
        angles = L.rope_angles(positions, cfg.head_dim_, cfg.rope_theta)

    if cfg.family in ("dense", "moe", "vlm"):

        def block(hh, xs):
            lp, ck, cv = xs
            a_in = L.rms_norm(hh, lp["ln1"], cfg.norm_eps)
            attn_out, kv = L.attention_block(
                lp["attn"], a_in, cfg, angles=angles, cache=(ck, cv), cache_pos=pos,
                mesh=mesh, seq_sharded_cache=seq_sharded,
            )
            hh = hh + attn_out
            m_in = L.rms_norm(hh, lp["ln2"], cfg.norm_eps)
            if cfg.family == "moe":
                mo, _ = moe.moe_block(lp["moe"], m_in, cfg)
                if cfg.moe_dense_residual:
                    mo = mo + L.mlp_block(lp["mlp"], m_in, cfg.mlp_type)
            else:
                mo = L.mlp_block(lp["mlp"], m_in, cfg.mlp_type)
            return hh + mo, kv

        h, kvs = jax.lax.scan(block, h, (params["layers"], cache["k"], cache["v"]))
        new_cache = {"k": kvs[0], "v": kvs[1]}

    elif cfg.family == "hybrid":
        nb = cfg.num_layers // cfg.hybrid_block
        grouped = jax.tree.map(
            lambda x: x.reshape((nb, cfg.hybrid_block) + x.shape[1:]), params["layers"]
        )
        mh = cache["mamba_h"].reshape((nb, cfg.hybrid_block) + cache["mamba_h"].shape[1:])
        mc = cache["mamba_conv"].reshape(
            (nb, cfg.hybrid_block) + cache["mamba_conv"].shape[1:]
        )
        shared = params["shared"]
        new_k, new_v, new_h, new_conv = [], [], [], []

        def mblock(hh, xs):
            lp, h_st, c_st = xs
            y, mcache = mamba2.mamba_decode_step(
                lp["mamba"],
                L.rms_norm(hh, lp["ln"], cfg.norm_eps),
                mamba2.MambaCache(h=h_st, conv=c_st),
                cfg,
            )
            return hh + y, (mcache.h, mcache.conv)

        for i in range(nb):
            blk = jax.tree.map(lambda x: x[i], grouped)
            h, (hs, cs) = jax.lax.scan(mblock, h, (blk, mh[i], mc[i]))
            new_h.append(hs)
            new_conv.append(cs)
            a_in = L.rms_norm(h, shared["ln1"], cfg.norm_eps)
            attn_out, kv = L.attention_block(
                shared["attn"], a_in, cfg, angles=angles,
                cache=(cache["k"][i], cache["v"][i]), cache_pos=pos,
                mesh=mesh, seq_sharded_cache=seq_sharded,
            )
            h = h + attn_out
            h = h + L.mlp_block(
                shared["mlp"], L.rms_norm(h, shared["ln2"], cfg.norm_eps), cfg.mlp_type
            )
            new_k.append(kv[0])
            new_v.append(kv[1])
        new_cache = {
            "k": jnp.stack(new_k),
            "v": jnp.stack(new_v),
            "mamba_h": jnp.concatenate(new_h).reshape(cache["mamba_h"].shape),
            "mamba_conv": jnp.concatenate(new_conv).reshape(cache["mamba_conv"].shape),
        }

    elif cfg.family == "ssm":
        h2 = h[:, 0, :]

        def block(hh, xs):
            lp, s_st, xtm, xcm = xs
            y, s_n, x_tm = rwkv6.time_mix_decode(
                lp["tm_cm"], L.rms_norm(hh, lp["ln1"], cfg.norm_eps), cfg, xtm, s_st
            )
            hh = hh + y
            cm, x_cm = rwkv6.channel_mix_decode(
                lp["tm_cm"], L.rms_norm(hh, lp["ln2"], cfg.norm_eps), xcm
            )
            return hh + cm, (s_n, x_tm.astype(jnp.float32), x_cm.astype(jnp.float32))

        h2, (s_n, xtm_n, xcm_n) = jax.lax.scan(
            block, h2, (params["layers"], cache["s"], cache["x_tm"], cache["x_cm"])
        )
        h = h2[:, None, :]
        new_cache = {"s": s_n, "x_tm": xtm_n, "x_cm": xcm_n}
    else:
        raise ValueError(cfg.family)

    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    logits = _unembed(params, h, cfg)
    return logits, new_cache
