from . import config, layers, lm, mamba2, moe, rwkv6
from .config import LM_SHAPES, ModelConfig, ShapeSpec, applicable_shapes, input_specs

__all__ = [
    "config",
    "layers",
    "lm",
    "mamba2",
    "moe",
    "rwkv6",
    "LM_SHAPES",
    "ModelConfig",
    "ShapeSpec",
    "applicable_shapes",
    "input_specs",
]
