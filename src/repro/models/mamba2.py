"""Mamba-2 (SSD) block — used by zamba2-2.7b.

Chunked state-space-duality algorithm: within a chunk the recurrence is
evaluated as masked (decay-weighted) attention-like matmuls; across chunks a
small state (heads, head_dim, N) is carried by lax.scan. Per-head scalar decay
(the SSD restriction) with n_groups=1 shared B/C, per-head dt, conv width 4.

Decode is the exact recurrence: h <- exp(dt*A) h + dt * x (x) B, y = h C + Dx.
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard

Params = Dict[str, jax.Array]


class MambaCache(NamedTuple):
    h: jax.Array  # (B, nh, hd, N) SSM state
    conv: jax.Array  # (B, d_conv-1, d_conv_dim) rolling conv inputs


def dims(cfg) -> Tuple[int, int, int, int]:
    d_inner = 2 * cfg.d_model
    nh = d_inner // cfg.ssm_head_dim
    return d_inner, nh, cfg.ssm_head_dim, cfg.ssm_state


def init_mamba(key, cfg, dtype) -> Params:
    d = cfg.d_model
    d_inner, nh, hd, n = dims(cfg)
    conv_dim = d_inner + 2 * n
    ks = jax.random.split(key, 5)
    std = d**-0.5
    return {
        # in_proj -> [z (d_inner), xBC (d_inner + 2N), dt (nh)]
        "w_in": jax.random.normal(ks[0], (d, 2 * d_inner + 2 * n + nh), dtype) * std,
        "conv_w": jax.random.normal(ks[1], (cfg.d_conv, conv_dim), dtype) * 0.1,
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "d_skip": jnp.ones((nh,), jnp.float32),
        "norm": jnp.ones((d_inner,), dtype),
        "w_out": jax.random.normal(ks[2], (d_inner, d), dtype) * (d_inner**-0.5),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv along seq. x: (B, S, C), w: (K, C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(k))
    return out + b


def _split(cfg, proj):
    d_inner, nh, hd, n = dims(cfg)
    z, xbc, dt = jnp.split(proj, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def mamba_block(
    p: Params, x: jax.Array, cfg, *, return_state: bool = False
):
    """Full-sequence (train/prefill) chunked SSD. x: (B, S, D) -> (B, S, D)
    (+ final MambaCache when ``return_state`` — SSM prefill emits O(1) state
    instead of a KV cache)."""
    b, s, d = x.shape
    d_inner, nh, hd, n = dims(cfg)
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0
    nchunks = s // q

    proj = x @ p["w_in"]
    z, xbc, dt = _split(cfg, proj)
    xbc_preconv = xbc
    xbc = jax.nn.silu(_causal_conv(xbc, p["conv_w"], p["conv_b"]))
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, s, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,nh)
    a = -jnp.exp(p["a_log"])  # (nh,) negative
    loga = dt * a  # (B,S,nh) log decay, <= 0

    # chunk views
    xs_c = xs.reshape(b, nchunks, q, nh, hd)
    b_c = bmat.reshape(b, nchunks, q, n)
    c_c = cmat.reshape(b, nchunks, q, n)
    dt_c = dt.reshape(b, nchunks, q, nh)
    la_c = loga.reshape(b, nchunks, q, nh)

    def chunk_step(h, args):
        xq, bq, cq, dtq, laq = args  # (B,q,...) for one chunk
        cum = jnp.cumsum(laq, axis=1)  # (B,q,nh) inclusive
        # intra-chunk: y[i] += sum_{j<=i} (C_i.B_j) exp(cum_i - cum_j) dt_j x_j
        g = jnp.einsum("bin,bjn->bij", cq.astype(jnp.float32), bq.astype(jnp.float32))
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,nh)
        mask = jnp.tril(jnp.ones((q, q), bool))
        # mask BEFORE exp: upper-triangle args are positive and would overflow
        # to inf, poisoning the backward pass with inf*0 = nan.
        decay = jnp.where(mask[None, :, :, None], decay, -1e9)
        m = jnp.exp(decay)
        w_ij = g[..., None] * m  # (B,i,j,nh)
        dx = dtq[..., None] * xq.astype(jnp.float32)  # (B,q,nh,hd)
        y = jnp.einsum("bijh,bjhp->bihp", w_ij, dx)
        # inter-chunk: y[i] += exp(cum_i) * C_i . h_in
        y = y + jnp.einsum("bin,bhpn->bihp", cq.astype(jnp.float32), h) * jnp.exp(
            cum
        ).transpose(0, 1, 2)[..., None]
        # state update: h_out = exp(cum_last) h_in + sum_j exp(cum_last-cum_j) dx_j (x) B_j
        tail = jnp.exp(cum[:, -1:, :] - cum)  # (B,q,nh)
        h = h * jnp.exp(cum[:, -1, :])[:, :, None, None] + jnp.einsum(
            "bjhp,bjn,bjh->bhpn", dx, bq.astype(jnp.float32), tail
        )
        return h, y

    h0 = jnp.zeros((b, nh, hd, n), jnp.float32)
    h_final, ys = jax.lax.scan(
        chunk_step,
        h0,
        (
            xs_c.transpose(1, 0, 2, 3, 4),
            b_c.transpose(1, 0, 2, 3),
            c_c.transpose(1, 0, 2, 3),
            dt_c.transpose(1, 0, 2, 3),
            la_c.transpose(1, 0, 2, 3),
        ),
    )
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s, nh, hd)
    y = y + p["d_skip"][None, None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, s, d_inner).astype(x.dtype)

    from .layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = shard(y @ p["w_out"], "batch", "seq_act", "embed")
    if return_state:
        cache = MambaCache(h=h_final, conv=xbc_preconv[:, s - cfg.d_conv + 1 :, :])
        return out, cache
    return out


def init_mamba_cache(cfg, batch: int, dtype=jnp.float32) -> MambaCache:
    d_inner, nh, hd, n = dims(cfg)
    conv_dim = d_inner + 2 * n
    return MambaCache(
        h=jnp.zeros((batch, nh, hd, n), jnp.float32),
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
    )


def mamba_decode_step(
    p: Params, x: jax.Array, cache: MambaCache, cfg
) -> Tuple[jax.Array, MambaCache]:
    """One-token recurrence. x: (B, 1, D)."""
    b, _, d = x.shape
    d_inner, nh, hd, n = dims(cfg)

    proj = x[:, 0] @ p["w_in"]
    z, xbc, dt = _split(cfg, proj)
    conv_in = jnp.concatenate([cache.conv, xbc[:, None, :]], axis=1)  # (B,K,C)
    xbc = jax.nn.silu(jnp.einsum("bkc,kc->bc", conv_in, p["conv_w"]) + p["conv_b"])
    new_conv = conv_in[:, 1:, :]

    xs, bvec, cvec = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    xs = xs.reshape(b, nh, hd)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,nh)
    da = jnp.exp(dt * (-jnp.exp(p["a_log"])))  # (B,nh)

    dx = dt[..., None] * xs.astype(jnp.float32)  # (B,nh,hd)
    h = cache.h * da[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", dx, bvec.astype(jnp.float32)
    )
    y = jnp.einsum("bhpn,bn->bhp", h, cvec.astype(jnp.float32))
    y = y + p["d_skip"][None, :, None] * xs.astype(jnp.float32)
    y = y.reshape(b, d_inner).astype(x.dtype)

    from .layers import rms_norm

    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return (y @ p["w_out"])[:, None, :], MambaCache(h=h, conv=new_conv)
