"""Model / shape configuration for the architecture zoo.

One frozen dataclass covers all 10 assigned families; family-specific fields
default to inert values. Exact per-arch instantiations live in
``repro/configs/<arch>.py`` (plus a reduced smoke variant each).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | vlm | audio | hybrid | ssm
    num_layers: int
    d_model: int
    num_heads: int  # 0 for attention-free (rwkv6)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dense_residual: bool = False  # arctic: dense FFN in parallel w/ MoE
    moe_capacity_factor: float = 1.25
    moe_dense_ff: int = 0  # arctic residual MLP width (defaults to d_ff)

    # attention details
    mlp_type: str = "swiglu"  # swiglu | gelu
    qkv_bias: bool = False  # qwen family
    rope_theta: float = 1e4
    causal: bool = True  # False for encoder-only (hubert)
    mrope_sections: Tuple[int, ...] = ()  # qwen2-vl M-RoPE (t, h, w) splits

    # ssm / hybrid
    ssm_state: int = 0
    ssm_head_dim: int = 64
    d_conv: int = 4
    hybrid_block: int = 0  # zamba2: mamba layers per shared-attention call

    # frontends (vlm/audio stubs)
    frontend_dim: int = 0  # audio: raw frame feature dim
    vision_tokens: int = 0  # vlm: patches per train/prefill sequence

    tie_embeddings: bool = False  # qwen2-1.5b ties embed/unembed

    # numerics / execution
    dtype: str = "bfloat16"
    remat: str = "full"  # none | full | dots
    seq_chunk: int = 2048  # chunked-attention q block
    ssm_chunk: int = 256  # SSD / WKV chunk length
    norm_eps: float = 1e-5

    @property
    def head_dim_(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def encoder_only(self) -> bool:
        return not self.causal

    @property
    def jnp_dtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def validate(self) -> None:
        if not self.attention_free:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0
        if self.family in ("moe",):
            assert self.num_experts > 0 and self.experts_per_token > 0
        if self.family == "hybrid":
            assert self.ssm_state > 0 and self.hybrid_block > 0
            assert self.num_layers % self.hybrid_block == 0


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell. kind:
    - train:   lower train_step  (tokens + labels, seq_len positions)
    - prefill: lower prefill_step (forward + KV-cache build)
    - decode:  lower serve_step  (1 new token against a seq_len-long cache)
    """

    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


LM_SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable_shapes(cfg: ModelConfig) -> Dict[str, ShapeSpec]:
    """Shape cells that are well-defined for this architecture.

    Skips (recorded in DESIGN.md §Arch-applicability):
      - encoder-only (hubert): no decode step -> skip decode_32k, long_500k
      - pure full-attention archs: long_500k needs sub-quadratic attention ->
        run only for ssm/hybrid families.
    """
    out = dict(LM_SHAPES)
    if cfg.encoder_only:
        out.pop("decode_32k")
        out.pop("long_500k")
    elif cfg.family not in ("ssm", "hybrid"):
        out.pop("long_500k")
    return out


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (no allocation).

    [vlm]/[audio] give the transformer BACKBONE only; the modality frontend is
    a stub supplying precomputed patch/frame embeddings per the assignment.
    """
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32

    if shape.kind == "train":
        if cfg.family == "audio":
            return {
                "frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32),
                "labels": jax.ShapeDtypeStruct((b, s), i32),
            }
        specs = {
            "tokens": jax.ShapeDtypeStruct((b, s), i32),
            "labels": jax.ShapeDtypeStruct((b, s), i32),
        }
        if cfg.family == "vlm":
            sv = cfg.vision_tokens
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - sv), i32)
            specs["vision_embeds"] = jax.ShapeDtypeStruct((b, sv, cfg.d_model), jnp.float32)
            specs["positions"] = jax.ShapeDtypeStruct((b, 3, s), i32)  # M-RoPE (t,h,w)
        return specs

    if shape.kind == "prefill":
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct((b, s, cfg.frontend_dim), jnp.float32)}
        specs = {"tokens": jax.ShapeDtypeStruct((b, s), i32)}
        if cfg.family == "vlm":
            sv = cfg.vision_tokens
            specs["tokens"] = jax.ShapeDtypeStruct((b, s - sv), i32)
            specs["vision_embeds"] = jax.ShapeDtypeStruct((b, sv, cfg.d_model), jnp.float32)
            specs["positions"] = jax.ShapeDtypeStruct((b, 3, s), i32)
        return specs

    # decode: one new token; the cache spec is built by models.lm.cache_specs.
    specs = {
        "tokens": jax.ShapeDtypeStruct((b, 1), i32),
        "cache_pos": jax.ShapeDtypeStruct((), i32),
    }
    if cfg.family == "vlm":
        specs["positions"] = jax.ShapeDtypeStruct((b, 3, 1), i32)
    return specs
