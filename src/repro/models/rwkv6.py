"""RWKV-6 (Finch) block — data-dependent per-channel decay linear attention.

Time-mix recurrence per head (dk = dv = head size):
    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t @ (S_{t-1} + diag(u) k_t (x) v_t)
with decay w_t = exp(-exp(wproj_t)) in (0,1), data-dependent via a token-shift
LoRA. Training/prefill run a chunked form (intra-chunk masked matmuls +
inter-chunk state scan); decode is the exact recurrence.

Channel mix: relu^2 gated FFN with token shift (Finch §2).
"""
from __future__ import annotations

from typing import Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.launch.sharding import shard

Params = Dict[str, jax.Array]

HEAD = 64  # rwkv6 head size (dk = dv)


class RWKVCache(NamedTuple):
    s: jax.Array  # (B, H, dk, dv) wkv state
    x_tm: jax.Array  # (B, D) last token input of the time-mix ln
    x_cm: jax.Array  # (B, D) last token input of the channel-mix ln


def init_rwkv(key, cfg, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    h = d // HEAD
    ks = jax.random.split(key, 10)
    std = d**-0.5
    lora = 64
    return {
        # time mix
        "mu_r": jnp.full((d,), 0.5, dtype),
        "mu_k": jnp.full((d,), 0.5, dtype),
        "mu_v": jnp.full((d,), 0.5, dtype),
        "mu_w": jnp.full((d,), 0.5, dtype),
        "mu_g": jnp.full((d,), 0.5, dtype),
        "wr": jax.random.normal(ks[0], (d, d), dtype) * std,
        "wk": jax.random.normal(ks[1], (d, d), dtype) * std,
        "wv": jax.random.normal(ks[2], (d, d), dtype) * std,
        "wg": jax.random.normal(ks[3], (d, d), dtype) * std,
        "wo": jax.random.normal(ks[4], (d, d), dtype) * std,
        # data-dependent decay LoRA: w = base + tanh(x @ a) @ b
        "w_base": jnp.full((d,), -1.0, jnp.float32),
        "w_lora_a": jax.random.normal(ks[5], (d, lora), dtype) * std,
        "w_lora_b": jax.random.normal(ks[6], (lora, d), dtype) * (lora**-0.5),
        "u_bonus": jnp.zeros((h, HEAD), jnp.float32),
        "ln_x": jnp.ones((d,), dtype),
        # channel mix
        "cmu_k": jnp.full((d,), 0.5, dtype),
        "cmu_r": jnp.full((d,), 0.5, dtype),
        "ck": jax.random.normal(ks[7], (d, f), dtype) * std,
        "cv": jax.random.normal(ks[8], (f, d), dtype) * (f**-0.5),
        "cr": jax.random.normal(ks[9], (d, d), dtype) * std,
    }


def _token_shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """x: (B,S,D) -> previous token's x (first position uses x_prev)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, xs, mu):
    return x + (xs - x) * mu


def time_mix(
    p: Params, x: jax.Array, cfg, x_prev: jax.Array, s0: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Chunked WKV6. x: (B,S,D). Returns (y, new_state, last_x)."""
    b, s, d = x.shape
    h = d // HEAD
    q = min(cfg.ssm_chunk, s)
    assert s % q == 0
    nchunks = s // q

    xs = _token_shift(x, x_prev)
    r = _mix(x, xs, p["mu_r"]) @ p["wr"]
    k = _mix(x, xs, p["mu_k"]) @ p["wk"]
    v = _mix(x, xs, p["mu_v"]) @ p["wv"]
    g = jax.nn.silu(_mix(x, xs, p["mu_g"]) @ p["wg"])
    wx = _mix(x, xs, p["mu_w"])
    wproj = p["w_base"] + jnp.tanh(wx @ p["w_lora_a"]).astype(jnp.float32) @ p[
        "w_lora_b"
    ].astype(jnp.float32)
    logw = -jnp.exp(wproj)  # (B,S,D) log decay <= 0

    def heads(t):
        return t.reshape(b, s, h, HEAD)

    r_, k_, v_, lw = heads(r), heads(k), heads(v), logw.reshape(b, s, h, HEAD)
    rc = r_.reshape(b, nchunks, q, h, HEAD).transpose(1, 0, 3, 2, 4)  # (C,B,H,q,dk)
    kc = k_.reshape(b, nchunks, q, h, HEAD).transpose(1, 0, 3, 2, 4)
    vc = v_.reshape(b, nchunks, q, h, HEAD).transpose(1, 0, 3, 2, 4)
    lc = lw.reshape(b, nchunks, q, h, HEAD).transpose(1, 0, 3, 2, 4)
    u = p["u_bonus"]  # (H, dk)

    def chunk_step(state, args):
        rq, kq, vq, lq = (t.astype(jnp.float32) for t in args)  # (B,H,q,·)
        cw = jnp.cumsum(lq, axis=2)  # inclusive (B,H,q,dk)
        pw = cw - lq  # exclusive prefix (B,H,q,dk)
        # inter-chunk: y_t += (r_t * exp(pw_t)) @ S_in
        y = jnp.einsum("bhqk,bhkv->bhqv", rq * jnp.exp(pw), state)
        # intra-chunk, strictly-lower: A[t,s] = (r_t*exp(pw_t - cw_s)) . k_s.
        # The true pair exponent pw_t - cw_s <= 0; the FACTORED terms exp(pw)
        # and exp(-cw) can individually overflow for long chunks / fast decay,
        # so both exponents are clamped (heavily-decayed pairs round to 0).
        amat = jnp.einsum(
            "bhtk,bhsk->bhts",
            rq * jnp.exp(jnp.clip(pw, -80.0, 0.0)),
            kq * jnp.exp(jnp.clip(-cw, -80.0, 80.0)),
        )
        mask = jnp.tril(jnp.ones((q, q), bool), k=-1)
        amat = jnp.where(mask[None, None], amat, 0.0)
        y = y + jnp.einsum("bhts,bhsv->bhtv", amat, vq)
        # diagonal bonus: y_t += (r_t * u * k_t) . v_t
        diag = jnp.sum(rq * u[None, :, None, :] * kq, axis=-1, keepdims=True)
        y = y + diag * vq
        # state: S_out = exp(cw_last) * S_in + sum_s (k_s exp(cw_last-cw_s)) (x) v_s
        tail = jnp.exp(cw[:, :, -1:, :] - cw)
        state = state * jnp.exp(cw[:, :, -1, :])[..., None] + jnp.einsum(
            "bhsk,bhsv->bhkv", kq * tail, vq
        )
        return state, y

    sN, ys = jax.lax.scan(chunk_step, s0.astype(jnp.float32), (rc, kc, vc, lc))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(b, s, d).astype(x.dtype)

    from .layers import rms_norm

    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    y = shard(y @ p["wo"], "batch", "seq_act", "embed")
    return y, sN, x[:, -1, :]


def time_mix_decode(
    p: Params, x: jax.Array, cfg, x_prev: jax.Array, s0: jax.Array
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact single-token recurrence. x: (B, D)."""
    b, d = x.shape
    h = d // HEAD
    x_prev = x_prev.astype(x.dtype)  # cache stores f32; keep carry dtype stable
    r = _mix(x, x_prev, p["mu_r"]) @ p["wr"]
    k = _mix(x, x_prev, p["mu_k"]) @ p["wk"]
    v = _mix(x, x_prev, p["mu_v"]) @ p["wv"]
    g = jax.nn.silu(_mix(x, x_prev, p["mu_g"]) @ p["wg"])
    wx = _mix(x, x_prev, p["mu_w"])
    wproj = p["w_base"] + jnp.tanh(wx @ p["w_lora_a"]).astype(jnp.float32) @ p[
        "w_lora_b"
    ].astype(jnp.float32)
    w = jnp.exp(-jnp.exp(wproj)).reshape(b, h, HEAD)  # decay in (0,1)

    r_, k_, v_ = (t.reshape(b, h, HEAD).astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhk,bhv->bhkv", k_, v_)
    y = jnp.einsum("bhk,bhkv->bhv", r_, s0 + p["u_bonus"][None, :, :, None] * kv)
    s_new = s0 * w[..., None] + kv
    y = y.reshape(b, d).astype(x.dtype)

    from .layers import rms_norm

    y = rms_norm(y, p["ln_x"], cfg.norm_eps) * g
    return y @ p["wo"], s_new, x


def channel_mix(
    p: Params, x: jax.Array, x_prev: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Finch channel mix (relu^2). x: (B,S,D); returns (out, last_x)."""
    xs = _token_shift(x, x_prev)
    xk = _mix(x, xs, p["cmu_k"])
    xr = _mix(x, xs, p["cmu_r"])
    hdn = jnp.square(jax.nn.relu(xk @ p["ck"]))
    hdn = shard(hdn, "batch", "seq_act", "mlp")
    out = jax.nn.sigmoid(xr @ p["cr"]) * shard(hdn @ p["cv"], "batch", "seq_act", "embed")
    return out, x[:, -1, :]


def channel_mix_decode(p: Params, x: jax.Array, x_prev: jax.Array):
    x_prev = x_prev.astype(x.dtype)
    xk = _mix(x, x_prev, p["cmu_k"])
    xr = _mix(x, x_prev, p["cmu_r"])
    hdn = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (hdn @ p["cv"]), x


def init_rwkv_cache(cfg, batch: int) -> RWKVCache:
    d = cfg.d_model
    return RWKVCache(
        s=jnp.zeros((batch, d // HEAD, HEAD, HEAD), jnp.float32),
        x_tm=jnp.zeros((batch, d), jnp.float32),
        x_cm=jnp.zeros((batch, d), jnp.float32),
    )
