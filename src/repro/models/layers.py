"""Shared transformer layers: norms, RoPE/M-RoPE, GQA attention, MLPs.

All functions are parameter-dict based (no framework dependency) and annotate
activations/params with logical sharding dims via launch.sharding.shard — a
no-op outside a mesh context so smoke tests and dry-runs share one code path.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map_compat
from repro.kernels.flash_attention import ops as attn_ops
from repro.kernels.flash_attention import ref as attn_ref
from repro.launch.sharding import axes_size, seq_axes, shard

Params = Dict[str, jax.Array]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------


def rope_angles(
    positions: jax.Array,  # (B, S) int
    head_dim: int,
    theta: float,
) -> jax.Array:
    """(B, S, head_dim/2) rotation angles."""
    half = head_dim // 2
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    return positions[..., None].astype(jnp.float32) * inv_freq


def mrope_angles(
    positions: jax.Array,  # (B, 3, S) int — (temporal, height, width) ids
    head_dim: int,
    theta: float,
    sections: Tuple[int, ...],
) -> jax.Array:
    """Qwen2-VL M-RoPE: the half-dim frequency slots are partitioned into
    (t, h, w) sections, each rotating by its own position stream."""
    half = head_dim // 2
    assert sum(sections) == half, (sections, half)
    inv_freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections), total_repeat_length=half
    )  # (half,) in {0,1,2}
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        sec_id[None, :, None].repeat(positions.shape[0], 0),
        axis=1,
    )  # (B, half, S)
    return jnp.einsum("bhs,h->bsh", pos, inv_freq)


def apply_rope(x: jax.Array, angles: jax.Array) -> jax.Array:
    """x: (B, H, S, Dh); angles: (B, S, Dh/2). Split-half rotation."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = jnp.cos(angles)[:, None].astype(x.dtype)
    sin = jnp.sin(angles)[:, None].astype(x.dtype)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA). Three execution paths:
#   dense   — small seq (smoke tests)
#   chunked — scan over q chunks, O(chunk * S) live memory (train/prefill 32k)
#   decode  — 1 query vs cache; optional sequence-sharded flash-decode combine
# ---------------------------------------------------------------------------


def _expand_heads(kv: jax.Array, hq: int) -> jax.Array:
    """Broadcast KV heads to the q-head count and constrain on 'heads'.

    Keeping score/attention einsums on a single consistently-'heads'-sharded
    dim avoids the (hkv, group) reshape that the SPMD partitioner cannot
    shard when hkv doesn't divide the model axis (it would replicate whole
    score tensors). The repeat is cheap (K/V << scores)."""
    b, hkv, s, dh = kv.shape
    if hkv != hq:
        kv = jnp.repeat(kv, hq // hkv, axis=1)
    return shard(kv, "batch", "heads", None, None)


def _dense_attention(q, k, v, *, scale, causal, q_offset=0):
    hq = q.shape[1]
    return attn_ref.attention(
        q, _expand_heads(k, hq), _expand_heads(v, hq),
        scale=scale, causal=causal, q_offset=q_offset,
    )


def _chunked_attention(q, k, v, *, scale, causal, chunk: int):
    """lax.scan over q chunks; each chunk sees the full K/V with masking.
    Memory: O(B * H * chunk * S) transient scores (rematerialized per chunk)."""
    b, h, s, dh = q.shape
    nchunks = s // chunk
    k = _expand_heads(k, h)
    v = _expand_heads(v, h)

    qc = q.reshape(b, h, nchunks, chunk, dh).transpose(2, 0, 1, 3, 4)

    @jax.checkpoint
    def one_chunk(carry, args):
        i, qi = args  # qi: (B, H, chunk, Dh)
        out = attn_ref.attention_with_offset_array(
            qi, k, v, scale=scale, causal=causal, q_offset=i * chunk
        )
        return carry, out

    _, outs = jax.lax.scan(one_chunk, None, (jnp.arange(nchunks), qc))
    return outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, dh)


def attention(
    q: jax.Array,  # (B, Hq, Sq, Dh)
    k: jax.Array,  # (B, Hkv, Skv, Dh)
    v: jax.Array,
    *,
    scale: float,
    causal: bool,
    q_offset=0,
    chunk: int = 2048,
    use_pallas: Optional[bool] = None,
) -> jax.Array:
    """Dispatch: Pallas flash kernel on TPU, chunked-scan XLA elsewhere for
    long sequences, dense for short ones."""
    sq = q.shape[2]
    use = jax.default_backend() == "tpu" if use_pallas is None else use_pallas
    if use and sq > 1 and q_offset == 0:
        return attn_ops.flash_attention(q, k, v, scale=scale, causal=causal)
    if sq > chunk and sq % chunk == 0 and q_offset == 0:
        return _chunked_attention(q, k, v, scale=scale, causal=causal, chunk=chunk)
    return _dense_attention(q, k, v, scale=scale, causal=causal, q_offset=q_offset)


def decode_attention_seq_sharded(
    q: jax.Array,  # (B, Hq, 1, Dh) replicated over the data axes
    k: jax.Array,  # (B, Hkv, S, Dh) sharded on S over the data axes
    v: jax.Array,
    *,
    scale: float,
    cache_pos: jax.Array,  # () int — #valid cache entries
    mesh,
) -> jax.Array:
    """Flash-decode for long-context (bs=1): the KV cache is sharded along the
    sequence dim; each shard computes a partial softmax (m_j, l_j, acc_j) and
    the combine is two O(B*H*Dh) psums — never an S-length all-gather.
    """
    from jax.sharding import PartitionSpec as P

    axes = seq_axes()
    assert axes, "seq-sharded decode requires a data axis"
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    s_loc = k.shape[2] // n_shards

    def partial_attn(q_, k_, v_):
        idx = jnp.int32(0)  # linear index over the seq axes
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        start = idx * s_loc
        kpos = start + jnp.arange(s_loc)
        sres = jnp.einsum(
            "bhqd,bhkd->bhqk",
            q_.astype(jnp.float32),
            _expand_kv(k_, q_.shape[1]).astype(jnp.float32),
        ) * scale
        mask = (kpos < cache_pos)[None, None, None, :]
        sres = jnp.where(mask, sres, -1e30)
        m = jnp.max(sres, axis=-1, keepdims=True)
        p = jnp.exp(sres - m)
        lsum = jnp.sum(p, axis=-1, keepdims=True)
        acc = jnp.einsum("bhqk,bhkd->bhqd", p, _expand_kv(v_, q_.shape[1]).astype(jnp.float32))
        # global online-softmax combine
        m_g = jax.lax.pmax(m, axes)
        corr = jnp.exp(m - m_g)
        l_g = jax.lax.psum(lsum * corr, axes)
        acc_g = jax.lax.psum(acc * corr, axes)
        return (acc_g / jnp.maximum(l_g, 1e-30)).astype(q_.dtype)

    sax = axes if len(axes) > 1 else axes[0]
    return shard_map_compat(
        partial_attn,
        mesh,
        in_specs=(P(), P(None, None, sax, None), P(None, None, sax, None)),
        out_specs=P(),
    )(q, k, v)


def _expand_kv(kv: jax.Array, hq: int) -> jax.Array:
    """(B, Hkv, S, Dh) -> (B, Hkv, S, Dh) kept as-is; helper reshapes q-side
    grouping. Here we instead broadcast kv heads to q heads for plain einsum."""
    b, hkv, s, dh = kv.shape
    if hkv == hq:
        return kv
    return jnp.repeat(kv, hq // hkv, axis=1)


# ---------------------------------------------------------------------------
# Attention block (QKV proj + rope + attention + out proj)
# ---------------------------------------------------------------------------


def init_attention(key, cfg, dtype) -> Params:
    d, hq, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_
    k1, k2, k3, k4 = jax.random.split(key, 4)
    std = d**-0.5
    p = {
        "wq": jax.random.normal(k1, (d, hq * dh), dtype) * std,
        "wk": jax.random.normal(k2, (d, hkv * dh), dtype) * std,
        "wv": jax.random.normal(k3, (d, hkv * dh), dtype) * std,
        "wo": jax.random.normal(k4, (hq * dh, d), dtype) * std,
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq * dh,), dtype)
        p["bk"] = jnp.zeros((hkv * dh,), dtype)
        p["bv"] = jnp.zeros((hkv * dh,), dtype)
    return p


def attention_block(
    p: Params,
    x: jax.Array,  # (B, S, D)
    cfg,
    *,
    angles: Optional[jax.Array],  # rope angles for current positions
    cache: Optional[Tuple[jax.Array, jax.Array]] = None,  # (k,v): (B,Hkv,Smax,Dh)
    cache_pos=None,  # () int32: write offset / #valid entries
    mesh=None,
    seq_sharded_cache: bool = False,
    return_kv: bool = False,  # prefill: emit this layer's (k, v) as the cache
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    b, s, d = x.shape
    hq, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim_

    # Sharding-constraint policy: activations may be PADDED by XLA when a dim
    # doesn't divide the axis (legal for internal constraints, unlike pjit
    # in/out shardings), but constraining the small KV head dim (e.g. 8 on a
    # 16-way axis) invites bad propagation — keep K/V model-replicated then
    # (they're tiny next to scores) and let the q-head dim carry the TP.
    kv_l = "kv_heads" if hkv % max(axes_size("kv_heads"), 1) == 0 else None

    q = x @ p["wq"] + (p.get("bq", 0))
    kk = x @ p["wk"] + (p.get("bk", 0))
    vv = x @ p["wv"] + (p.get("bv", 0))
    q = shard(q.reshape(b, s, hq, dh).transpose(0, 2, 1, 3), "batch", "heads", "seq_act", None)
    kk = shard(kk.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3), "batch", kv_l, None, None)
    vv = shard(vv.reshape(b, s, hkv, dh).transpose(0, 2, 1, 3), "batch", kv_l, None, None)

    if angles is not None:
        q = apply_rope(q, angles)
        kk = apply_rope(kk, angles)

    scale = dh**-0.5
    new_cache = None
    if cache is None:
        out = attention(q, kk, vv, scale=scale, causal=cfg.causal, chunk=cfg.seq_chunk)
        if return_kv:
            new_cache = (kk, vv)
    elif s > 1:
        raise NotImplementedError("chunked prefill-into-cache not needed here")
    else:
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice(ck, kk.astype(ck.dtype), (0, 0, cache_pos, 0))
        cv = jax.lax.dynamic_update_slice(cv, vv.astype(cv.dtype), (0, 0, cache_pos, 0))
        new_cache = (ck, cv)
        if seq_sharded_cache and mesh is not None:
            out = decode_attention_seq_sharded(
                q, ck, cv, scale=scale, cache_pos=cache_pos + 1, mesh=mesh
            )
        else:
            out = _dense_attention(
                q, ck, cv, scale=scale, causal=True, q_offset=cache_pos
            )

    out = out.transpose(0, 2, 1, 3).reshape(b, s, hq * dh)
    out = out @ p["wo"]
    return shard(out, "batch", "seq_act", "embed"), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(key, d: int, f: int, kind: str, dtype) -> Params:
    ks = jax.random.split(key, 3)
    std = d**-0.5
    if kind == "swiglu":
        return {
            "wg": jax.random.normal(ks[0], (d, f), dtype) * std,
            "wu": jax.random.normal(ks[1], (d, f), dtype) * std,
            "wd": jax.random.normal(ks[2], (f, d), dtype) * (f**-0.5),
        }
    return {  # gelu
        "w1": jax.random.normal(ks[0], (d, f), dtype) * std,
        "w2": jax.random.normal(ks[1], (f, d), dtype) * (f**-0.5),
    }


def mlp_block(p: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])
        h = shard(h, "batch", "seq_act", "mlp")
        return shard(h @ p["wd"], "batch", "seq_act", "embed")
    h = jax.nn.gelu(x @ p["w1"])
    h = shard(h, "batch", "seq_act", "mlp")
    return shard(h @ p["w2"], "batch", "seq_act", "embed")
