"""Mixture-of-Experts layer with expert parallelism (EP) via shard_map.

Design (TPU-native, "replicated-activation EP"):
  Activations enter model-replicated / batch-sharded (Megatron convention).
  Experts are sharded over the ``model`` axis (+ their in-dim FSDP-sharded
  over the DP axes). Each model shard:
    1. computes the (replicated) router for its local tokens,
    2. selects, per *local* expert, a capacity-bounded token set via top_k
       (static shapes — no ragged ops),
    3. all-gathers its experts' FSDP weight shards (ZeRO-3 style),
    4. runs the batched expert MLP and scatter-adds gated outputs,
    5. psums partial outputs over ``model`` — the EP combine costs exactly
       one activation all-reduce, the same volume as the Megatron TP MLP
       all-reduce it replaces; no all-to-all is needed because activations
       are already model-replicated.
  Capacity per local expert: C_e = ceil(n_loc * k / E * capacity_factor);
  overflow tokens are dropped (Switch/GShard semantics).

Without a mesh the same math runs locally (E_loc = E) — used by smoke tests.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map_compat
from repro.launch.sharding import active_mesh, data_axes, model_axes

Params = Dict[str, jax.Array]


def init_moe(key, cfg, dtype) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)
    std = d**-0.5
    return {
        "router": jax.random.normal(ks[0], (d, e), jnp.float32) * std,
        "wg": jax.random.normal(ks[1], (e, d, f), dtype) * std,
        "wu": jax.random.normal(ks[2], (e, d, f), dtype) * std,
        "wd": jax.random.normal(ks[3], (e, f, d), dtype) * (f**-0.5),
    }


def _capacity(n_loc: int, k: int, e: int, factor: float) -> int:
    c = int(math.ceil(n_loc * k / e * factor))
    c = max(8, ((c + 7) // 8) * 8)  # TPU-friendly multiple of 8
    return min(c, n_loc)


def _moe_math(
    x: jax.Array,  # (n_loc, D) local tokens
    router: jax.Array,  # (D, E) replicated
    wg: jax.Array,  # (E_loc, D, F) local experts (already gathered)
    wu: jax.Array,
    wd: jax.Array,
    *,
    k: int,
    num_experts: int,
    expert_offset: jax.Array,  # () int: first global expert id on this shard
    capacity: int,
) -> Tuple[jax.Array, jax.Array]:
    """Per-shard dispatch/compute/combine. Returns (partial_out, aux_loss)."""
    n_loc, d = x.shape
    e_loc = wg.shape[0]

    logits = (x.astype(jnp.float32) @ router)  # (n, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)  # (n, k)

    # Per-local-expert token selection. score[e_l, t] = gate if token t routed
    # to local expert e_l else -1. (k is tiny: 1 or 2.)
    global_eid = expert_offset + jnp.arange(e_loc)  # (E_loc,)
    routed = eidx[None, :, :] == global_eid[:, None, None]  # (E_loc, n, k)
    score = jnp.max(jnp.where(routed, gate[None], -1.0), axis=-1)  # (E_loc, n)
    sel_gate, sel_idx = jax.lax.top_k(score, capacity)  # (E_loc, C)
    valid = sel_gate > -0.5

    xg = x[sel_idx]  # (E_loc, C, D)
    h = jnp.einsum("ecd,edf->ecf", xg, wg)
    h = jax.nn.silu(h) * jnp.einsum("ecd,edf->ecf", xg, wu)
    ye = jnp.einsum("ecf,efd->ecd", h, wd)
    # combine in the STORAGE dtype: the EP-combine psum over 'model' is the
    # biggest MoE collective; gating in f32 then casting keeps it bf16-wide
    ye = (ye * (sel_gate * valid).astype(ye.dtype)[..., None]).astype(x.dtype)

    out = jnp.zeros((n_loc, d), x.dtype).at[sel_idx.reshape(-1)].add(
        ye.reshape(-1, d)
    )

    # Switch-style load-balance auxiliary loss (local estimate).
    frac = jnp.mean(
        (eidx[..., None] == jnp.arange(num_experts)).any(axis=1).astype(jnp.float32),
        axis=0,
    )
    mean_p = jnp.mean(probs, axis=0)
    aux = num_experts * jnp.sum(frac * mean_p)
    return out, aux


def moe_block(p: Params, x: jax.Array, cfg) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (out (B, S, D), aux_loss ())."""
    b, s, d = x.shape
    mesh = active_mesh()
    k, e = cfg.experts_per_token, cfg.num_experts

    if mesh is None:  # local fallback (smoke tests)
        xt = x.reshape(b * s, d)
        cap = _capacity(b * s, k, e, cfg.moe_capacity_factor)
        out, aux = _moe_math(
            xt, p["router"], p["wg"], p["wu"], p["wd"],
            k=k, num_experts=e, expert_offset=jnp.int32(0), capacity=cap,
        )
        return out.reshape(b, s, d).astype(x.dtype), aux

    m_axes = model_axes()
    d_axes = data_axes()
    m_size = 1
    for a in m_axes:
        m_size *= mesh.shape[a]
    d_size = 1
    for a in d_axes:
        d_size *= mesh.shape[a]
    e_loc = e // max(m_size, 1)
    n_loc = (b * s) // max(d_size, 1)
    cap = _capacity(n_loc, k, e, cfg.moe_capacity_factor)

    batch_spec = d_axes if len(d_axes) > 1 else (d_axes[0] if d_axes else None)
    model_spec = m_axes if len(m_axes) > 1 else (m_axes[0] if m_axes else None)

    def body(xt, router, wg, wu, wd):
        # ZeRO-3: gather this shard's experts' weight slices over the DP axes.
        if d_axes:
            wg = jax.lax.all_gather(wg, d_axes, axis=1, tiled=True)
            wu = jax.lax.all_gather(wu, d_axes, axis=1, tiled=True)
            wd = jax.lax.all_gather(wd, d_axes, axis=1, tiled=True)
        off = jnp.int32(jax.lax.axis_index(m_axes) * e_loc) if m_axes else jnp.int32(0)
        out, aux = _moe_math(
            xt, router, wg, wu, wd,
            k=k, num_experts=e, expert_offset=off, capacity=cap,
        )
        if m_axes:  # EP combine: one activation all-reduce over 'model'
            out = jax.lax.psum(out, m_axes)
        if d_axes:  # replicate the scalar aux loss for a P() out_spec
            aux = jax.lax.pmean(aux, d_axes)
        return out, aux

    out, aux = shard_map_compat(
        body,
        mesh,
        in_specs=(
            P(batch_spec, None),  # tokens
            P(),  # router replicated
            P(model_spec, batch_spec, None),  # experts: EP x FSDP(dim 1)
            P(model_spec, batch_spec, None),
            P(model_spec, batch_spec, None),  # wd FSDP'd on its f-dim
        ),
        out_specs=(P(batch_spec, None), P()),
    )(x.reshape(b * s, d), p["router"], p["wg"], p["wu"], p["wd"])
    return out.reshape(b, s, d).astype(x.dtype), aux
