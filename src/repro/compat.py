"""Version bridges for the moving jax API surface.

``shard_map`` left ``jax.experimental`` and its replication-check flag was
renamed ``check_rep`` -> ``check_vma`` along the way; this module gives the
rest of the codebase one import that works on both sides. Keep this a leaf
module (jax-only imports) so ``core/``, ``models/`` and ``launch/`` can all
depend on it without cycles.
"""
from __future__ import annotations

try:  # jax >= 0.5 exports shard_map at top level
    from jax import shard_map as _shard_map  # type: ignore[attr-defined]

    _CHECK_KW = "check_vma"
except ImportError:  # jax 0.4.x
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map_compat(f, mesh, in_specs, out_specs):
    """shard_map with replication checking off, on any supported jax.

    ``mesh`` is forwarded by keyword: it is keyword-only in the top-level
    jax >= 0.5 API and positional-or-keyword in jax.experimental's.
    """
    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **{_CHECK_KW: False}
    )
