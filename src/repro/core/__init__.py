"""DFW-TRACE: the paper's contribution as a composable JAX library.

Public surface:
    frank_wolfe.fit / make_epoch_step   — DFW-TRACE (paper Alg. 2)
    power_method.power_iterations       — distributed power method
    baselines.make_naive_epoch_step     — NAIVE-DFW (paper §3.1)
    baselines.make_sva_epoch_step       — Singular Vector Averaging (§3.1)
    tasks.MultiTaskLeastSquares[Dense]  — paper §2.3 / App. B
    tasks.MultinomialLogistic           — paper §2.3 / App. B
    tasks.MatrixCompletion              — paper §2.3 / App. B (sparse Omega)
    low_rank.FactoredIterate            — O(t(d+m)) iterate store (§2.2)
    dfw_head.DFWHeadTrainer             — trace-norm head training on LM zoo
"""
from . import (
    baselines,
    dfw_head,
    engine,
    frank_wolfe,
    low_rank,
    power_method,
    tasks,
    trace_norm,
)
from .engine import EngineResult, Segment, plan_segments, run_epochs
from .frank_wolfe import (
    EpochAux,
    EpochCarry,
    FitResult,
    fit,
    init_carry,
    k_schedule,
    make_epoch_step,
)
from .low_rank import FactoredIterate
from .power_method import PowerResult, power_iterations, sphere_vector, top_singular_pair
from .tasks import (
    MatrixCompletion,
    MultinomialLogistic,
    MultiTaskLeastSquares,
    MultiTaskLeastSquaresDense,
    pack_observations,
)
from .trace_norm import duality_gap, lmo_trace_ball, trace_norm

__all__ = [
    "baselines",
    "frank_wolfe",
    "low_rank",
    "power_method",
    "tasks",
    "trace_norm",
    "engine",
    "EngineResult",
    "Segment",
    "plan_segments",
    "run_epochs",
    "EpochAux",
    "EpochCarry",
    "FitResult",
    "fit",
    "init_carry",
    "k_schedule",
    "make_epoch_step",
    "FactoredIterate",
    "PowerResult",
    "power_iterations",
    "sphere_vector",
    "top_singular_pair",
    "MatrixCompletion",
    "MultinomialLogistic",
    "MultiTaskLeastSquares",
    "MultiTaskLeastSquaresDense",
    "pack_observations",
    "duality_gap",
    "lmo_trace_ball",
    "trace_norm",
]
