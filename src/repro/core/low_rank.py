"""Factored low-rank iterate store.

FW with W^0 = 0 yields W^t = sum_k c_k u_k v_k^T — rank <= t. Storing the
factors costs O(t(d+m)) instead of O(dm) (paper §2.2). Buffers are
preallocated at max_rank so every shape is static under jit; the FW recurrence
``W <- (1-gamma) W + gamma S`` is absorbed into a running global scale so each
epoch touches O(d+m) memory, not O(t(d+m)).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class FactoredIterate(NamedTuple):
    """W = alpha * sum_{k<count} s[k] * U[k] V[k]^T."""

    u: jax.Array  # (max_rank, d)
    s: jax.Array  # (max_rank,)
    v: jax.Array  # (max_rank, m)
    alpha: jax.Array  # () running global scale
    count: jax.Array  # () int32, number of live factors


def init(max_rank: int, d: int, m: int, dtype=jnp.float32) -> FactoredIterate:
    return FactoredIterate(
        u=jnp.zeros((max_rank, d), dtype),
        s=jnp.zeros((max_rank,), dtype),
        v=jnp.zeros((max_rank, m), dtype),
        alpha=jnp.ones((), dtype),
        count=jnp.zeros((), jnp.int32),
    )


def fw_update(
    it: FactoredIterate, u: jax.Array, v: jax.Array, gamma: jax.Array, mu: float
) -> FactoredIterate:
    """W <- (1-gamma) W + gamma (-mu u v^T), appending one factor.

    Instead of rescaling all live factors by (1-gamma) — an O(t) sweep — we
    fold it into ``alpha`` and store the new factor pre-divided by the new
    alpha. gamma=1 annihilates the whole iterate (W <- S): alpha underflows
    to zero, so we floor it back to 1 *and zero the live factors' s entries*
    — flooring alone would resurrect the pre-existing factors at full scale
    (the line search clips gamma into [0, 1], so gamma == 1 is reachable at
    any t, not just epoch 0).
    """
    new_alpha = it.alpha * (1.0 - gamma)
    dead = jnp.abs(new_alpha) < 1e-30
    safe_alpha = jnp.where(dead, 1.0, new_alpha)
    s_live = jnp.where(dead, jnp.zeros_like(it.s), it.s)
    s_new = -gamma * mu / safe_alpha
    k = it.count
    return FactoredIterate(
        u=jax.lax.dynamic_update_slice(it.u, u[None, :].astype(it.u.dtype), (k, 0)),
        s=jax.lax.dynamic_update_slice(s_live, s_new[None].astype(it.s.dtype), (k,)),
        v=jax.lax.dynamic_update_slice(it.v, v[None, :].astype(it.v.dtype), (k, 0)),
        alpha=safe_alpha,
        count=k + 1,
    )


def fw_update_block(
    it: FactoredIterate,
    u: jax.Array,
    v: jax.Array,
    c: jax.Array,
    gamma: jax.Array,
    mu: float,
) -> FactoredIterate:
    """Rank-k FW step: ``W <- (1-gamma) W + gamma S`` with the blended block
    atom ``S = -mu sum_j c_j u_j v_j^T``, appending k factors at once.

    ``u`` (d, k) / ``v`` (m, k) hold unit atom columns, ``c`` (k,) the
    nonnegative blend weights with ``sum c <= 1`` — the triangle inequality
    then gives ``||S||_* <= mu``, so the step stays inside the trace-norm
    ball exactly like the rank-1 atom. Same alpha-folding and gamma=1
    dead-iterate handling as ``fw_update``; the k new rows land at
    ``count .. count+k-1`` of the live-rank prefix.
    """
    k = u.shape[1]
    new_alpha = it.alpha * (1.0 - gamma)
    dead = jnp.abs(new_alpha) < 1e-30
    safe_alpha = jnp.where(dead, 1.0, new_alpha)
    s_live = jnp.where(dead, jnp.zeros_like(it.s), it.s)
    s_new = (-gamma * mu / safe_alpha) * c.astype(it.s.dtype)
    n = it.count
    return FactoredIterate(
        u=jax.lax.dynamic_update_slice(it.u, u.T.astype(it.u.dtype), (n, 0)),
        s=jax.lax.dynamic_update_slice(s_live, s_new, (n,)),
        v=jax.lax.dynamic_update_slice(it.v, v.T.astype(it.v.dtype), (n, 0)),
        alpha=safe_alpha,
        count=n + k,
    )


def materialize(it: FactoredIterate) -> jax.Array:
    """Dense W — O(dm) memory; for tests/small problems only."""
    return it.alpha * jnp.einsum("k,kd,km->dm", it.s, it.u, it.v)


def matvec(it: FactoredIterate, x: jax.Array) -> jax.Array:
    """W @ x in O(t(d+m)) without materializing W."""
    return it.alpha * (it.u.T @ (it.s * (it.v @ x)))


def rmatvec(it: FactoredIterate, x: jax.Array) -> jax.Array:
    """W^T @ x in O(t(d+m))."""
    return it.alpha * (it.v.T @ (it.s * (it.u @ x)))


def gather_entries(it: FactoredIterate, rows: jax.Array, cols: jax.Array) -> jax.Array:
    """W[rows, cols] for index vectors (p,) in O(t p) — held-out evaluation
    for matrix completion without materializing W."""
    return it.alpha * jnp.einsum(
        "k,kp,kp->p", it.s, it.u[:, rows], it.v[:, cols]
    )


def right_multiply(it: FactoredIterate, x: jax.Array) -> jax.Array:
    """X @ W for row-major data X (n,d) -> (n,m), factored: (X U^T) diag(s) V."""
    return it.alpha * (((x @ it.u.T) * it.s) @ it.v)


def trace_norm_upper_bound(it: FactoredIterate) -> jax.Array:
    """||W||_* <= alpha * sum_k |s_k| (triangle inequality on unit factors)."""
    return jnp.abs(it.alpha) * jnp.sum(jnp.abs(it.s))


# ---------------------------------------------------------------------------
# Serialization: live-rank prefix packing (checkpoint/dfw.py payloads)
# ---------------------------------------------------------------------------


def pack_live(it: FactoredIterate) -> dict:
    """Host-side dict of the iterate trimmed to its ``count`` live factors.

    The buffers are preallocated at ``max_rank`` but rows at indices
    >= ``count`` are all-zero by construction (``init`` zeros them;
    ``fw_update`` only ever writes row ``count``), so a t-epoch checkpoint
    stores t factors instead of ``max_rank`` — and ``unpack_live`` re-pads
    to *any* capacity bit-exactly."""
    import numpy as np

    # One explicit batched device->host fetch: per-leaf np.asarray would be
    # five implicit blocking pulls (lint rule REP002) and serving hot-swaps
    # run pack_live under a transfer guard.
    host = jax.device_get(it)
    k = int(host.count)
    return {
        "u": np.asarray(host.u)[:k],
        "s": np.asarray(host.s)[:k],
        "v": np.asarray(host.v)[:k],
        "alpha": np.asarray(host.alpha),
        "count": np.asarray(host.count),
    }


def unpack_live(packed: dict, max_rank: int) -> FactoredIterate:
    """Inverse of ``pack_live`` onto a ``max_rank``-capacity store. The new
    capacity may differ from the one at save time (a resumed run may extend
    ``num_epochs``) as long as it holds the live prefix."""
    import numpy as np

    # No-op for already-host numpy leaves, an explicit boundary if a caller
    # hands us device arrays — either way the padding below is host-side.
    packed = jax.device_get(packed)
    k = int(np.asarray(packed["count"]))
    if max_rank < k:
        raise ValueError(
            f"max_rank={max_rank} < {k} live factors in the packed iterate"
        )

    def pad(x):
        out = np.zeros((max_rank,) + x.shape[1:], x.dtype)
        out[:k] = x
        return jnp.asarray(out)

    return FactoredIterate(
        u=pad(np.asarray(packed["u"])),
        s=pad(np.asarray(packed["s"])),
        v=pad(np.asarray(packed["v"])),
        alpha=jnp.asarray(packed["alpha"]),
        count=jnp.asarray(packed["count"]),
    )


def packed_like() -> dict:
    """Structure skeleton of ``pack_live``'s output (for treedef-matching
    restores; leaf values are ignored)."""
    import numpy as np

    z = np.zeros((0,), np.float32)
    return {"u": z, "s": z, "v": z, "alpha": z, "count": z}
