"""Trace-norm ball geometry: LMO, duality gap, feasibility certificates."""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Rank1(NamedTuple):
    """A rank-1 matrix ``scale * u v^T`` kept factored (never materialized)."""

    u: jax.Array  # (d,)
    v: jax.Array  # (m,)
    scale: jax.Array  # ()


def lmo_trace_ball(u: jax.Array, v: jax.Array, mu: float) -> Rank1:
    """S* = argmin_{||S||_* <= mu} <S, A> = -mu u1 v1^T for top pair (u1,v1)."""
    return Rank1(u=u, v=v, scale=jnp.asarray(-mu, u.dtype))


def trace_norm(w: jax.Array) -> jax.Array:
    """Exact trace norm (sum of singular values). O(dm min(d,m)) — tests only."""
    return jnp.sum(jnp.linalg.svd(w, compute_uv=False))


def duality_gap(inner_w_grad: jax.Array, sigma1: jax.Array, mu: float) -> jax.Array:
    """FW duality gap g(W) = <W - S*, grad> = <W, grad> + mu * sigma1(grad).

    ``g(W) >= F(W) - F(W*)`` (Jaggi 2013), so this is a computable optimality
    certificate. With the power-method sigma1 (an underestimate) the gap is
    slightly underestimated; tests use the exact sigma1.
    """
    return inner_w_grad + mu * sigma1


def default_step_size(t: jax.Array) -> jax.Array:
    """The classic FW schedule gamma_t = 2/(t+2)."""
    return 2.0 / (t + 2.0)
