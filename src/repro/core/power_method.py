"""Distributed power method — the heart of DFW-TRACE (paper Alg. 2, lines 5-10).

The paper's BSP exchange (workers send ``u_{k+1,j} = grad_j @ v_k`` to a master
which aggregates and broadcasts) maps onto SPMD as a ``psum`` over the data
mesh axes: every device holds an implicit shard ``A_j`` of the gradient
``A = sum_j A_j`` and only the O(d+m) iteration vectors cross the network.

All functions are pure and work both serially (``axis_name=None``) and inside
``shard_map`` (``axis_name='data'`` or ``('pod','data')``).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

# The exact-psum master aggregate goes through the comm layer's chokepoint
# (never a raw lax.psum here — lint rule REP001): the Reducer subsystem owns
# every vector collective so encodings and wire-byte accounting stay in one
# place. comm never imports core, so this is cycle-free.
from ..comm.base import psum as _psum

AxisName = Optional[Union[str, Sequence[str]]]
_EPS = 1e-30


class PowerResult(NamedTuple):
    """Top singular triple estimate after K two-sided power iterations."""

    u: jax.Array  # (d,)  left singular vector estimate, unit norm
    v: jax.Array  # (m,)  right singular vector estimate, unit norm
    sigma: jax.Array  # ()  top singular value estimate (= ||A^T u|| >= 0)


class BlockPowerResult(NamedTuple):
    """Top-k singular block estimate after K block power iterations.

    ``u``/``v`` columns pair up as rank-1 atoms (``u_j^T A v_j = sigma_j``;
    the v columns are unit but not mutually orthogonal mid-convergence);
    ``probe`` is the *orthonormalized* right block — the thing to warm-start
    the next epoch's iteration from. ``iters`` counts the iterations that
    actually executed (< K when the adaptive stop fired early)."""

    u: jax.Array  # (d, k) left block, orthonormal columns
    v: jax.Array  # (m, k) right block, unit columns (atom directions)
    sigma: jax.Array  # (k,) singular value estimates (unordered, >= 0)
    probe: jax.Array  # (m, k) orthonormal right block (warm-start carry)
    iters: jax.Array  # () int32 iterations executed


def collective_rounds_contract(num_iters: int, topology=None):
    """The paper's communication budget as a declared, checkable contract:
    K two-sided power iterations execute exactly 2K aggregation rounds
    (one all-reduce per matvec/rmatvec pair side), never 2K+1 — the
    carried-sigma invariant. Consumed by ``tests/test_power_method.py`` and
    ``tools/repro_contracts.py`` against the compiled HLO of a shard_map'd
    ``power_iterations``.

    With a ``topology`` (``repro.comm.Topology``) the 2K exchanges route
    through that graph instead of a flat all-reduce, and the contract pins
    the graph's own collective profile (``ppermute`` rounds for gossip,
    intra+inter split for hier) via ``Topology.collective_contract``."""
    from ..analysis.contracts import Contract  # lazy: analysis is tooling

    if topology is not None:
        return topology.collective_contract(
            2 * num_iters,
            name=(
                f"power_method.collective_rounds"
                f"[K={num_iters},topology={topology.spec}]"
            ),
        )
    return Contract(
        name=f"power_method.collective_rounds[K={num_iters}]",
        collective_counts={"all-reduce": 2.0 * num_iters},
    )


def block_collective_rounds_contract(num_iters: int, k: int, topology=None):
    """Block analogue of ``collective_rounds_contract``: K block iterations
    still execute exactly 2K all-reduce rounds — the (k,k) Gram
    orthogonalization runs on the *already-reduced replicated* block, so
    widening the probe from a vector to k columns multiplies the payload of
    each round by k but never adds a round. ``k`` is part of the name (and
    of wire-byte accounting); the round count is k-free by construction."""
    from ..analysis.contracts import Contract  # lazy: analysis is tooling

    if topology is not None:
        return topology.collective_contract(
            2 * num_iters,
            name=(
                f"power_method.block_collective_rounds"
                f"[K={num_iters},k={k},topology={topology.spec}]"
            ),
        )
    return Contract(
        name=f"power_method.block_collective_rounds[K={num_iters},k={k}]",
        collective_counts={"all-reduce": 2.0 * num_iters},
    )


def sphere_vector(key: jax.Array, m: int, dtype=jnp.float32) -> jax.Array:
    """Uniform random vector on the unit (m-1)-sphere.

    The paper has all workers draw the *same* v0 via a shared seed; in SPMD the
    key is replicated so this holds by construction with zero communication.
    """
    v = jax.random.normal(key, (m,), dtype=dtype)
    return v / (jnp.linalg.norm(v) + _EPS)


def power_iterations(
    matvec: Callable[[jax.Array], jax.Array],
    rmatvec: Callable[[jax.Array], jax.Array],
    v0: jax.Array,
    num_iters: int,
    *,
    axis_name: AxisName = None,
    worker_weight: Optional[jax.Array] = None,
    reducer=None,
    comm_state=None,
    key: Optional[jax.Array] = None,
):
    """Run ``num_iters`` two-sided power iterations on the implicit operator.

    ``matvec(v)``/``rmatvec(u)`` compute the *local* contribution ``A_j v`` /
    ``A_j^T u``; this routine psums them over ``axis_name`` (paper's
    aggregate-and-broadcast) and normalizes. The estimate ``sigma = ||A^T u||``
    is the norm of the *last* aggregated ``rmatvec`` — carried out of the loop,
    never recomputed, so an epoch costs exactly ``2 * num_iters`` collective
    rounds (regression-pinned in tests/test_power_method.py).

    ``worker_weight`` implements straggler mitigation: a 0/1 (or fractional)
    scalar multiplying the local contribution. Because each iteration
    renormalizes, dropping workers only reorients the estimate toward the
    surviving data's gradient — an unbiased LMO for the surviving partition
    (same weighting argument the paper uses for SVA).

    ``reducer`` (a ``repro.comm.Reducer``, or a ``repro.comm.Topology`` —
    anything with the ``exchange`` contract) reroutes the two vector
    aggregations through a compressed collective and/or a non-flat exchange
    graph. Under a per-node topology (gossip) the aggregates differ across
    workers, so ``u``/``v``/``sigma`` become per-node estimates. Default
    ``None`` preserves the exact-psum behavior bit for bit and returns a
    plain ``PowerResult``;
    with a reducer the return is ``(PowerResult, comm_state)`` where
    ``comm_state`` is the reducer's threaded per-worker state (pass the
    previous epoch's back in; ``None`` starts fresh via
    ``reducer.init_state``) and ``key`` feeds stochastic encodings (defaults
    to a constant key — pass a per-epoch key for unbiasedness across epochs).

    The two-sided iteration guarantees ``u^T A v = ||A^T u|| >= 0``, so the
    trace-norm LMO solution is always ``S* = -mu u v^T`` with no sign fix.
    """
    if num_iters < 1:
        raise ValueError(
            f"num_iters={num_iters}: power_iterations needs >= 1 iteration "
            "(0 returns u=0, sigma=0 and silently corrupts the caller)"
        )
    w = 1.0 if worker_weight is None else worker_weight
    d_probe = matvec(v0)  # shapes only; cheap under jit (dead if K>=1 reuses)
    u0 = jnp.zeros_like(d_probe)
    sigma0 = jnp.zeros((), jnp.float32)

    if reducer is None:

        def body(_, carry):
            _, v, _ = carry
            u = _psum(w * matvec(v), axis_name)
            u = u / (jnp.linalg.norm(u) + _EPS)
            vv = _psum(w * rmatvec(u), axis_name)
            nv = jnp.linalg.norm(vv)
            v = vv / (nv + _EPS)
            return (u, v, nv)

        u, v, sigma = jax.lax.fori_loop(0, num_iters, body, (u0, v0, sigma0))
        return PowerResult(u=u, v=v, sigma=sigma)

    if key is None:
        key = jax.random.PRNGKey(0)
    if comm_state is None:
        comm_state = reducer.init_state(u0.shape[0], v0.shape[0])

    def body(i, carry):
        _, v, _, cs = carry
        ki = jax.random.fold_in(key, i)
        # worker_weight rides along so stateful reducers can tell a masked
        # worker (whose w*matvec is zero but whose residual is not) from a
        # live one — see comm/base.Reducer.exchange.
        uu, cs = reducer.exchange(
            w * matvec(v), cs, slot="u",
            key=jax.random.fold_in(ki, 0), axis_name=axis_name,
            weight=worker_weight,
        )
        u = uu / (jnp.linalg.norm(uu) + _EPS)
        vv, cs = reducer.exchange(
            w * rmatvec(u), cs, slot="v",
            key=jax.random.fold_in(ki, 1), axis_name=axis_name,
            weight=worker_weight,
        )
        nv = jnp.linalg.norm(vv)
        v = vv / (nv + _EPS)
        return (u, v, nv, cs)

    u, v, sigma, comm_state = jax.lax.fori_loop(
        0, num_iters, body, (u0, v0, sigma0, comm_state)
    )
    return PowerResult(u=u, v=v, sigma=sigma), comm_state


def orthonormalize_block(b: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Orthonormalize the columns of ``b`` via Cholesky-QR on the (k,k) Gram.

    The Gram ``G = B^T B`` is tiny (k x k) and — in this codebase's BSP
    layout — computed on a block that is already replicated post-all-reduce,
    so the orthogonalization costs zero communication rounds (in a
    row-sharded layout it would cost one (k,k) all-reduce; see
    docs/ALGORITHMS.md). The jitter keeps the factorization defined for
    rank-deficient blocks; an all-zero block maps to an all-zero block.
    """
    k = b.shape[-1]
    g = b.T @ b
    jitter = eps * (jnp.trace(g) / k) + 1e-30
    chol = jnp.linalg.cholesky(g + jitter * jnp.eye(k, dtype=b.dtype))
    # B @ inv(L)^T via one triangular solve of the (k, n) system.
    return jax.scipy.linalg.solve_triangular(chol, b.T, lower=True).T


def block_power_step(
    matmat: Callable[[jax.Array], jax.Array],
    rmatmat: Callable[[jax.Array], jax.Array],
    q: jax.Array,
    *,
    reduce: Callable[[jax.Array], jax.Array] = lambda x: x,
) -> tuple:
    """One warm-started half-pair of block power iteration: ``p =
    orth(reduce(A q)); q' = reduce(A^T p)``. Returns ``(p, q')``.

    This is the shared primitive between the FW block LMO below and
    PowerSGD gradient compression (``optim/compression.py``): both do
    exactly one aggregated-matmat -> Gram-orthonormalize -> aggregated-
    rmatmat step per call, warm-starting ``q`` from the previous round.
    ``reduce`` is the aggregation (psum for the LMO, pmean for PowerSGD's
    averaged gradients; identity when serial)."""
    p = orthonormalize_block(reduce(matmat(q)))
    return p, reduce(rmatmat(p))


def block_power_iterations(
    matvec: Callable[[jax.Array], jax.Array],
    rmatvec: Callable[[jax.Array], jax.Array],
    v0: jax.Array,
    num_iters: int,
    *,
    axis_name: AxisName = None,
    worker_weight: Optional[jax.Array] = None,
    reducer=None,
    comm_state=None,
    key: Optional[jax.Array] = None,
    adapt_rtol: Optional[float] = None,
    adapt_ref: Optional[jax.Array] = None,
):
    """Distributed *block* power iteration: ``(d,k)``/``(m,k)`` probe blocks
    instead of vectors — the rank-k LMO engine of the ``block:k`` solver
    tier (BlockFW, arXiv:1708.02105).

    Per iteration: all-reduce the local block matvec (flattened through the
    ``Reducer`` contract, so int8/topk encodings compose unchanged),
    Cholesky-QR orthonormalize the replicated result against its (k,k)
    Gram, all-reduce the block rmatvec, read per-column sigmas off it, and
    orthonormalize again for the next round — exactly ``2 * num_iters``
    collective rounds, the same count as ``power_iterations`` with k-times
    wider payloads (``block_collective_rounds_contract``).

    ``v0`` is the (m, k) starting block — a previous epoch's converged
    probe for warm starts (it is re-orthonormalized here, so any
    nonzero-column block is a valid start). ``adapt_rtol`` enables the
    spectral-gap-adaptive stop: once the largest per-column sigma change of
    an iteration falls below ``adapt_rtol * max(adapt_ref, max sigma)``,
    the remaining iterations become ``lax.cond`` no-ops — the static HLO
    round count stays 2K, the executed matvecs and collectives stop.
    Callers pass ``adapt_ref`` as the scale on which the duality-gap
    certificate lives (the FW epoch uses ``|<W, grad>| / mu``), so
    iterations are spent only while they still move the certificate.

    Always returns ``(BlockPowerResult, comm_state)`` (``reducer=None``
    uses the exact dense psum with ``()`` state).
    """
    if num_iters < 1:
        raise ValueError(
            f"num_iters={num_iters}: block_power_iterations needs >= 1 "
            "iteration (0 returns a zero block and corrupts the caller)"
        )
    if v0.ndim != 2:
        raise ValueError(f"v0 must be (m, k), got shape {v0.shape}")
    if reducer is None:
        from ..comm.base import DenseReducer  # leaf import; no cycle

        reducer = DenseReducer()
    if key is None:
        key = jax.random.PRNGKey(0)
    m, k = v0.shape
    w = 1.0 if worker_weight is None else worker_weight
    matmat = jax.vmap(matvec, in_axes=1, out_axes=1)
    rmatmat = jax.vmap(rmatvec, in_axes=1, out_axes=1)
    d = matmat(v0).shape[0]  # shapes only; dead under jit (loop recomputes)
    if comm_state is None:
        comm_state = reducer.init_state(d * k, m * k)

    u0 = jnp.zeros((d, k), v0.dtype)
    sigma0 = jnp.zeros((k,), jnp.float32)
    va0 = v0 / (jnp.linalg.norm(v0, axis=0, keepdims=True) + _EPS)
    init = (u0, orthonormalize_block(v0), va0, sigma0, comm_state,
            jnp.zeros((), jnp.bool_), jnp.zeros((), jnp.int32))

    def live(i, c):
        _, v, _, sigma, cs, done, iters = c
        ki = jax.random.fold_in(key, i)
        uu, cs = reducer.exchange(
            (w * matmat(v)).reshape(-1), cs, slot="u",
            key=jax.random.fold_in(ki, 0), axis_name=axis_name,
            weight=worker_weight,
        )
        ub = orthonormalize_block(uu.reshape(d, k))
        vv, cs = reducer.exchange(
            (w * rmatmat(ub)).reshape(-1), cs, slot="v",
            key=jax.random.fold_in(ki, 1), axis_name=axis_name,
            weight=worker_weight,
        )
        vv = vv.reshape(m, k)
        sig = jnp.linalg.norm(vv, axis=0)
        v_atoms = vv / (sig[None, :] + _EPS)
        if adapt_rtol is not None:
            ref = jnp.max(sig)
            if adapt_ref is not None:
                ref = jnp.maximum(ref, adapt_ref)
            done = done | (
                jnp.max(jnp.abs(sig - sigma)) <= adapt_rtol * (ref + _EPS)
            )
        return (ub, orthonormalize_block(vv), v_atoms, sig, cs, done,
                iters + 1)

    def body(i, c):
        # Once the adaptive criterion fires, remaining iterations are
        # no-ops: the static collective count stays 2K (cond branches are
        # counted once by analysis/hlo), the executed work stops.
        return jax.lax.cond(c[5], lambda c: c, lambda c: live(i, c), c)

    u, v_next, v_atoms, sigma, comm_state, _, iters = jax.lax.fori_loop(
        0, num_iters, body, init
    )
    return (
        BlockPowerResult(u=u, v=v_atoms, sigma=sigma, probe=v_next,
                         iters=iters),
        comm_state,
    )


def power_method_dense(
    a: jax.Array,
    key: jax.Array,
    num_iters: int,
    *,
    axis_name: AxisName = None,
) -> PowerResult:
    """Power method on an explicit (possibly sharded-by-rows-of-n) matrix."""
    return power_iterations(
        lambda v: a @ v,
        lambda u: a.T @ u,
        sphere_vector(key, a.shape[1], a.dtype),
        num_iters,
        axis_name=axis_name,
    )


@functools.partial(jax.jit, static_argnames=("num_iters",))
def top_singular_pair(a: jax.Array, key: jax.Array, num_iters: int = 50) -> PowerResult:
    """Serial oracle used by tests and NAIVE-DFW (exact-ish for modest K)."""
    return power_method_dense(a, key, num_iters)
