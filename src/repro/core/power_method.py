"""Distributed power method — the heart of DFW-TRACE (paper Alg. 2, lines 5-10).

The paper's BSP exchange (workers send ``u_{k+1,j} = grad_j @ v_k`` to a master
which aggregates and broadcasts) maps onto SPMD as a ``psum`` over the data
mesh axes: every device holds an implicit shard ``A_j`` of the gradient
``A = sum_j A_j`` and only the O(d+m) iteration vectors cross the network.

All functions are pure and work both serially (``axis_name=None``) and inside
``shard_map`` (``axis_name='data'`` or ``('pod','data')``).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp

# The exact-psum master aggregate goes through the comm layer's chokepoint
# (never a raw lax.psum here — lint rule REP001): the Reducer subsystem owns
# every vector collective so encodings and wire-byte accounting stay in one
# place. comm never imports core, so this is cycle-free.
from ..comm.base import psum as _psum

AxisName = Optional[Union[str, Sequence[str]]]
_EPS = 1e-30


class PowerResult(NamedTuple):
    """Top singular triple estimate after K two-sided power iterations."""

    u: jax.Array  # (d,)  left singular vector estimate, unit norm
    v: jax.Array  # (m,)  right singular vector estimate, unit norm
    sigma: jax.Array  # ()  top singular value estimate (= ||A^T u|| >= 0)


def collective_rounds_contract(num_iters: int):
    """The paper's communication budget as a declared, checkable contract:
    K two-sided power iterations execute exactly 2K aggregation rounds
    (one all-reduce per matvec/rmatvec pair side), never 2K+1 — the
    carried-sigma invariant. Consumed by ``tests/test_power_method.py`` and
    ``tools/repro_contracts.py`` against the compiled HLO of a shard_map'd
    ``power_iterations``."""
    from ..analysis.contracts import Contract  # lazy: analysis is tooling

    return Contract(
        name=f"power_method.collective_rounds[K={num_iters}]",
        collective_counts={"all-reduce": 2.0 * num_iters},
    )


def sphere_vector(key: jax.Array, m: int, dtype=jnp.float32) -> jax.Array:
    """Uniform random vector on the unit (m-1)-sphere.

    The paper has all workers draw the *same* v0 via a shared seed; in SPMD the
    key is replicated so this holds by construction with zero communication.
    """
    v = jax.random.normal(key, (m,), dtype=dtype)
    return v / (jnp.linalg.norm(v) + _EPS)


def power_iterations(
    matvec: Callable[[jax.Array], jax.Array],
    rmatvec: Callable[[jax.Array], jax.Array],
    v0: jax.Array,
    num_iters: int,
    *,
    axis_name: AxisName = None,
    worker_weight: Optional[jax.Array] = None,
    reducer=None,
    comm_state=None,
    key: Optional[jax.Array] = None,
):
    """Run ``num_iters`` two-sided power iterations on the implicit operator.

    ``matvec(v)``/``rmatvec(u)`` compute the *local* contribution ``A_j v`` /
    ``A_j^T u``; this routine psums them over ``axis_name`` (paper's
    aggregate-and-broadcast) and normalizes. The estimate ``sigma = ||A^T u||``
    is the norm of the *last* aggregated ``rmatvec`` — carried out of the loop,
    never recomputed, so an epoch costs exactly ``2 * num_iters`` collective
    rounds (regression-pinned in tests/test_power_method.py).

    ``worker_weight`` implements straggler mitigation: a 0/1 (or fractional)
    scalar multiplying the local contribution. Because each iteration
    renormalizes, dropping workers only reorients the estimate toward the
    surviving data's gradient — an unbiased LMO for the surviving partition
    (same weighting argument the paper uses for SVA).

    ``reducer`` (a ``repro.comm.Reducer``) reroutes the two vector
    aggregations through a compressed collective. Default ``None`` preserves
    the exact-psum behavior bit for bit and returns a plain ``PowerResult``;
    with a reducer the return is ``(PowerResult, comm_state)`` where
    ``comm_state`` is the reducer's threaded per-worker state (pass the
    previous epoch's back in; ``None`` starts fresh via
    ``reducer.init_state``) and ``key`` feeds stochastic encodings (defaults
    to a constant key — pass a per-epoch key for unbiasedness across epochs).

    The two-sided iteration guarantees ``u^T A v = ||A^T u|| >= 0``, so the
    trace-norm LMO solution is always ``S* = -mu u v^T`` with no sign fix.
    """
    if num_iters < 1:
        raise ValueError(
            f"num_iters={num_iters}: power_iterations needs >= 1 iteration "
            "(0 returns u=0, sigma=0 and silently corrupts the caller)"
        )
    w = 1.0 if worker_weight is None else worker_weight
    d_probe = matvec(v0)  # shapes only; cheap under jit (dead if K>=1 reuses)
    u0 = jnp.zeros_like(d_probe)
    sigma0 = jnp.zeros((), jnp.float32)

    if reducer is None:

        def body(_, carry):
            _, v, _ = carry
            u = _psum(w * matvec(v), axis_name)
            u = u / (jnp.linalg.norm(u) + _EPS)
            vv = _psum(w * rmatvec(u), axis_name)
            nv = jnp.linalg.norm(vv)
            v = vv / (nv + _EPS)
            return (u, v, nv)

        u, v, sigma = jax.lax.fori_loop(0, num_iters, body, (u0, v0, sigma0))
        return PowerResult(u=u, v=v, sigma=sigma)

    if key is None:
        key = jax.random.PRNGKey(0)
    if comm_state is None:
        comm_state = reducer.init_state(u0.shape[0], v0.shape[0])

    def body(i, carry):
        _, v, _, cs = carry
        ki = jax.random.fold_in(key, i)
        # worker_weight rides along so stateful reducers can tell a masked
        # worker (whose w*matvec is zero but whose residual is not) from a
        # live one — see comm/base.Reducer.reduce.
        uu, cs = reducer.reduce(
            w * matvec(v), cs, slot="u",
            key=jax.random.fold_in(ki, 0), axis_name=axis_name,
            weight=worker_weight,
        )
        u = uu / (jnp.linalg.norm(uu) + _EPS)
        vv, cs = reducer.reduce(
            w * rmatvec(u), cs, slot="v",
            key=jax.random.fold_in(ki, 1), axis_name=axis_name,
            weight=worker_weight,
        )
        nv = jnp.linalg.norm(vv)
        v = vv / (nv + _EPS)
        return (u, v, nv, cs)

    u, v, sigma, comm_state = jax.lax.fori_loop(
        0, num_iters, body, (u0, v0, sigma0, comm_state)
    )
    return PowerResult(u=u, v=v, sigma=sigma), comm_state


def power_method_dense(
    a: jax.Array,
    key: jax.Array,
    num_iters: int,
    *,
    axis_name: AxisName = None,
) -> PowerResult:
    """Power method on an explicit (possibly sharded-by-rows-of-n) matrix."""
    return power_iterations(
        lambda v: a @ v,
        lambda u: a.T @ u,
        sphere_vector(key, a.shape[1], a.dtype),
        num_iters,
        axis_name=axis_name,
    )


@functools.partial(jax.jit, static_argnames=("num_iters",))
def top_singular_pair(a: jax.Array, key: jax.Array, num_iters: int = 50) -> PowerResult:
    """Serial oracle used by tests and NAIVE-DFW (exact-ish for modest K)."""
    return power_method_dense(a, key, num_iters)
