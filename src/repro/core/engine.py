"""Device-resident epoch engine: scan-compiled K(t) segments.

The DFW-Trace loop is hundreds to thousands of *cheap* O(d+m) epochs, so a
driver that pays one jit dispatch and four blocking ``float(...)`` transfers
per epoch is dominated by Python and PCIe, not by the algorithm. This engine
keeps whole runs on device:

1. **Segment plan.** ``plan_segments`` partitions the K(t) schedule into
   maximal constant-K runs (optionally capped at ``block_epochs``). A
   ``const:K`` schedule is one segment; ``log`` is O(log T) segments.
2. **Scan compilation.** Each segment executes as a single ``jax.lax.scan``
   over the unified ``EpochCarry`` — one dispatch per segment, with the
   per-epoch ``EpochAux`` rows written into the scan's preallocated
   on-device output buffers. Worker straggler masks are precomputed as a
   ``(num_epochs, nw)`` array and indexed by the carried epoch counter
   inside the scan. Segments sharing a (K, length) shape share one
   executable.
3. **Gap-certificate early stop.** The psum'd duality gap rides the scan
   carry as a ``done`` flag: once ``gap <= gap_tol`` every remaining epoch
   in the segment is a ``lax.cond`` no-op (static shapes preserved, compute
   skipped), and the host stops launching segments at the next boundary.
   ``epochs_run`` counts the epochs that actually executed.

Host transfers happen only at segment boundaries (and only when early
stopping or a callback needs them) plus one final history fetch — all via
explicit ``jax.device_get``, so a run under
``jax.transfer_guard_device_to_host("disallow")`` proves the loop is
device-resident (regression-pinned in ``tests/test_engine.py``).

``mode="legacy"`` reproduces the pre-engine driver — one dispatch per epoch
and four blocking scalar pulls — on the same unified carry; it exists as the
trajectory-equivalence oracle and the baseline ``benchmarks/engine_bench.py``
measures against.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..compat import shard_map_compat
from . import low_rank
from .frank_wolfe import (
    EpochAux,
    EpochCarry,
    init_carry,
    k_schedule,
    make_epoch_step,
)
from .power_method import AxisName

PyTree = Any


class Segment(NamedTuple):
    """A maximal run of epochs sharing one (static) power-iteration count."""

    start: int  # first epoch index
    length: int  # number of epochs
    k: int  # K(t) throughout the segment


def plan_segments(
    schedule: str, num_epochs: int, block_epochs: Optional[int] = None
) -> List[Segment]:
    """Partition ``[0, num_epochs)`` into maximal constant-K segments.

    ``block_epochs`` caps segment length: early stopping acts at segment
    granularity, so the cap bounds how many epochs a converged run can
    execute past its certificate (and how stale a progress callback gets).
    Equal-length blocks of the same K share one compiled executable, so
    chopping a long ``const:K`` run costs extra dispatches, not compiles.
    """
    if num_epochs < 1:
        raise ValueError(f"num_epochs={num_epochs}: need at least one epoch")
    if block_epochs is not None and block_epochs < 1:
        raise ValueError(f"block_epochs={block_epochs}: must be >= 1")
    sched = k_schedule(schedule)
    segments: List[Segment] = []
    t = 0
    while t < num_epochs:
        k = sched(t)
        end = t + 1
        while (
            end < num_epochs
            and sched(end) == k
            and (block_epochs is None or end - t < block_epochs)
        ):
            end += 1
        segments.append(Segment(start=t, length=end - t, k=k))
        t = end
    return segments


def resolve_max_rank(max_rank: Optional[int], num_epochs: int) -> int:
    """Factored-iterate capacity. One factor is appended per epoch and
    ``low_rank.fw_update`` clamps out-of-range writes silently, so
    undersizing would corrupt the returned iterate — reject it up front.
    (Shared by the serial and sharded drivers: one capacity contract.)"""
    if max_rank is None:
        return num_epochs
    if max_rank < num_epochs:
        raise ValueError(
            f"max_rank={max_rank} < num_epochs={num_epochs}: every "
            "epoch appends one factor, so the iterate store would overflow"
        )
    return max_rank


@dataclasses.dataclass
class EngineResult:
    """``history`` lists are truncated to ``epochs_run``. ``stats`` counts
    the engine's interactions with the runtime — the quantities the
    dispatch/sync regression tests pin:

    - ``segments_planned`` / ``segments_run``: plan size vs segments
      actually launched (early stop skips the tail),
    - ``dispatches``: jitted calls issued,
    - ``compilations``: distinct executables built (segments sharing a
      (K, length) shape reuse one),
    - ``host_syncs``: explicit ``jax.device_get`` round-trips (legacy mode
      counts its four blocking per-epoch scalar pulls here).
    """

    carry: EpochCarry
    history: Dict[str, list]
    epochs_run: int
    stats: Dict[str, int]


def _segment_step(
    task,
    mu: float,
    k: int,
    length: int,
    *,
    step_size: str,
    axis_name: AxisName,
    reducer,
    gap_tol: Optional[float],
    has_masks: bool,
) -> Callable:
    """One segment as a pure function: ``length`` epochs under ``lax.scan``.

    Signature (before any shard_map wrapping):
    ``seg(carry, done, epochs_run[, masks]) -> (carry, done, epochs_run, aux)``
    where ``aux`` leaves are ``(length,)`` — the scan's preallocated
    on-device history rows — and ``masks`` is the full ``(num_epochs, nw)``
    straggler-weight array, indexed at ``[carry.t, 0]`` inside the scan
    (inside shard_map every worker holds its own ``(num_epochs, 1)`` column).
    Epochs after the gap certificate fires are ``lax.cond`` no-ops emitting
    NaN aux rows (truncated away by the host).
    """
    epoch = make_epoch_step(
        task, mu, k, step_size=step_size, axis_name=axis_name, reducer=reducer
    )
    tol = jnp.float32(-jnp.inf if gap_tol is None else gap_tol)

    def segment(carry, done, epochs_run, masks=None):
        def body(c, _):
            def live(c):
                carry, done, epochs_run = c
                w = masks[carry.t, 0] if has_masks else None
                carry, aux = epoch(carry, worker_weight=w)
                return (carry, done | (aux.gap <= tol), epochs_run + 1), aux

            def skip(c):
                nan = jnp.float32(jnp.nan)
                return c, EpochAux(loss=nan, gap=nan, sigma=nan, gamma=nan)

            done = c[1]
            return jax.lax.cond(done, skip, live, c)

        (carry, done, epochs_run), aux = jax.lax.scan(
            body, (carry, done, epochs_run), None, length=length
        )
        return carry, done, epochs_run, aux

    return segment


def sharded_carry_spec(
    axis_or_axes, state_spec: PyTree, comm_state_example: PyTree = ()
):
    """shard_map PartitionSpecs for an ``EpochCarry``: task state rows
    sharded over the data axes, iterate/counter/key replicated, and every
    reducer-state leaf carried with a *leading worker axis* sharded like the
    data rows (dense's ``()`` has no leaves — encoding-agnostic).

    ``comm_state_example`` is one worker's (unstacked) reducer state."""
    from jax.sharding import PartitionSpec as P

    ax = axis_or_axes
    return EpochCarry(
        state=state_spec,
        iterate=low_rank.FactoredIterate(u=P(), s=P(), v=P(), alpha=P(), count=P()),
        comm_state=jax.tree.map(lambda _: P(ax), comm_state_example),
        t=P(),
        key=P(),
    )


def strip_worker_axis(carry: EpochCarry) -> EpochCarry:
    """Inside a shard_map region: drop the leading worker axis off the comm
    leaves — a worker owns its (1, ...) slice of the stacked reducer state."""
    return carry._replace(
        comm_state=jax.tree.map(lambda a: a[0], carry.comm_state)
    )


def restore_worker_axis(carry: EpochCarry) -> EpochCarry:
    return carry._replace(
        comm_state=jax.tree.map(lambda a: a[None], carry.comm_state)
    )


def shard_map_segment_wrapper(
    mesh,
    axis_or_axes,
    state_spec: PyTree,
    *,
    comm_state_example: PyTree = (),
    has_masks: bool = False,
) -> Callable[[Callable], Callable]:
    """Build the canonical ``segment_wrapper``: shard_map with the task
    state row-sharded, iterate/scalars/key replicated, straggler masks
    column-sharded, and reducer state carried with a leading worker axis
    (sharded like the data rows) that is stripped inside the region.
    """
    from jax.sharding import PartitionSpec as P

    ax = axis_or_axes
    carry_spec = sharded_carry_spec(ax, state_spec, comm_state_example)
    aux_spec = EpochAux(P(), P(), P(), P())

    def wrap(seg_fn):
        def step(carry, done, epochs_run, *masks):
            carry, done, epochs_run, aux = seg_fn(
                strip_worker_axis(carry), done, epochs_run, *masks
            )
            return restore_worker_axis(carry), done, epochs_run, aux

        mask_specs = (P(None, ax),) if has_masks else ()
        return shard_map_compat(
            step,
            mesh,
            in_specs=(carry_spec, P(), P()) + mask_specs,
            out_specs=(carry_spec, P(), P(), aux_spec),
        )

    return wrap


_HISTORY_KEYS = ("loss", "gap", "sigma", "gamma")


def run_epochs(
    task,
    state: PyTree,
    *,
    mu: float,
    num_epochs: int,
    key: jax.Array,
    schedule: str = "const:2",
    step_size: str = "default",
    axis_name: AxisName = None,
    reducer=None,
    comm_state: Optional[PyTree] = None,
    iterate: Optional[low_rank.FactoredIterate] = None,
    max_rank: Optional[int] = None,
    masks: Optional[jax.Array] = None,
    gap_tol: Optional[float] = None,
    block_epochs: Optional[int] = None,
    segment_wrapper: Optional[Callable[[Callable], Callable]] = None,
    callback: Optional[Callable[[int, EpochAux], None]] = None,
    mode: str = "scan",
) -> EngineResult:
    """Run up to ``num_epochs`` DFW-Trace epochs, device-resident.

    ``comm_state`` defaults to ``reducer.init_state(task.d, task.m)`` (one
    worker's state); a sharded driver passes its worker-stacked version,
    matching whatever its ``segment_wrapper`` strips/restores. ``iterate``
    defaults to a fresh ``low_rank.init`` with ``max_rank`` capacity
    (validated >= num_epochs). ``masks`` is the full ``(num_epochs, nw)``
    straggler-weight schedule or ``None`` for unweighted epochs.

    ``mode="scan"`` (production): one dispatch per segment, host transfers
    at boundaries only. ``mode="legacy"``: the pre-engine loop — per-epoch
    dispatch plus four blocking scalar pulls — same math, same carry, kept
    as the equivalence oracle and overhead baseline.
    """
    if mode not in ("scan", "legacy"):
        raise ValueError(f"mode={mode!r}: expected 'scan' or 'legacy'")
    if reducer is None:
        from ..comm.base import DenseReducer

        reducer = DenseReducer()
    if comm_state is None:
        comm_state = reducer.init_state(task.d, task.m)
    if iterate is None:
        iterate = low_rank.init(
            resolve_max_rank(max_rank, num_epochs), task.d, task.m
        )
    if masks is not None:
        if masks.shape[0] != num_epochs:
            raise ValueError(
                f"masks has {masks.shape[0]} rows for {num_epochs} epochs"
            )
        if masks.shape[1] > 1 and segment_wrapper is None:
            # The scan body reads masks[t, 0]: each worker's own column after
            # shard_map slices the (num_epochs, nw) array. Without a wrapper
            # there is one "worker", and silently using column 0 would make a
            # multi-worker mask schedule measure nothing.
            raise ValueError(
                f"masks has {masks.shape[1]} worker columns but no "
                "segment_wrapper shards them; pass a shard_map wrapper "
                "(engine.shard_map_segment_wrapper) or a single-column mask"
            )

    segments = plan_segments(
        schedule, num_epochs, 1 if mode == "legacy" else block_epochs
    )
    stats = {
        "segments_planned": len(segments),
        "segments_run": 0,
        "dispatches": 0,
        "compilations": 0,
        "host_syncs": 0,
    }
    has_masks = masks is not None
    wrapper = segment_wrapper if segment_wrapper is not None else (lambda f: f)

    compiled: Dict[tuple, Callable] = {}

    def get_compiled(seg: Segment) -> Callable:
        sig = (seg.k, seg.length)
        if sig not in compiled:
            fn = _segment_step(
                task, mu, seg.k, seg.length,
                step_size=step_size, axis_name=axis_name, reducer=reducer,
                gap_tol=gap_tol, has_masks=has_masks,
            )
            compiled[sig] = jax.jit(wrapper(fn))
            stats["compilations"] += 1
        return compiled[sig]

    carry = init_carry(state, iterate, key, comm_state)
    done = jnp.zeros((), jnp.bool_)
    nrun = jnp.zeros((), jnp.int32)
    history: Dict[str, list] = {k: [] for k in _HISTORY_KEYS}
    history["k"] = []

    if mode == "legacy":
        # Pre-engine behavior: one dispatch + four blocking float() pulls
        # per epoch (each an implicit device->host transfer, like the old
        # driver's `float(aux.loss)` lines).
        epochs_run = 0
        for seg in segments:
            args = (carry, done, nrun) + ((masks,) if has_masks else ())
            carry, done, nrun, aux = get_compiled(seg)(*args)
            stats["dispatches"] += 1
            stats["segments_run"] += 1
            row = [float(aux.loss[0]), float(aux.gap[0]),
                   float(aux.sigma[0]), float(aux.gamma[0])]
            stats["host_syncs"] += 4
            for name, val in zip(_HISTORY_KEYS, row):
                history[name].append(val)
            history["k"].append(seg.k)
            epochs_run += 1
            if callback is not None:
                callback(seg.start, jax.device_get(aux))
                stats["host_syncs"] += 1
            if gap_tol is not None and row[1] <= gap_tol:
                break
        return EngineResult(
            carry=carry, history=history, epochs_run=epochs_run, stats=stats
        )

    # (Segment, host EpochAux | None, device EpochAux) per segment run; the
    # host slot is filled when a callback already fetched the block, so the
    # final history assembly never transfers the same rows twice.
    aux_blocks: List[tuple] = []
    for seg in segments:
        args = (carry, done, nrun) + ((masks,) if has_masks else ())
        carry, done, nrun, aux = get_compiled(seg)(*args)
        stats["dispatches"] += 1
        stats["segments_run"] += 1
        host_aux = None
        if callback is not None:
            host_aux = jax.device_get(aux)
            stats["host_syncs"] += 1
            callback(seg.start, host_aux)
        aux_blocks.append((seg, host_aux, aux))
        if gap_tol is not None:
            # The only mid-run sync: one scalar at the segment boundary,
            # deciding whether to launch the next segment.
            stats["host_syncs"] += 1
            if bool(jax.device_get(done)):
                break

    pending = [a for _, h, a in aux_blocks if h is None]
    fetched, epochs_run = jax.device_get((pending, nrun))
    stats["host_syncs"] += 1
    epochs_run = int(epochs_run)
    fetched = iter(fetched)
    for seg, host_aux, _ in aux_blocks:
        block = host_aux if host_aux is not None else next(fetched)
        for name, col in zip(_HISTORY_KEYS, block):
            history[name].extend(float(v) for v in col)
        history["k"].extend([seg.k] * seg.length)
    for name in history:
        del history[name][epochs_run:]
    return EngineResult(
        carry=carry, history=history, epochs_run=epochs_run, stats=stats
    )
