"""Device-resident epoch engine: scan-compiled K(t) segments.

The DFW-Trace loop is hundreds to thousands of *cheap* O(d+m) epochs, so a
driver that pays one jit dispatch and four blocking ``float(...)`` transfers
per epoch is dominated by Python and PCIe, not by the algorithm. This engine
keeps whole runs on device:

1. **Segment plan.** ``plan_segments`` partitions the K(t) schedule into
   maximal constant-K runs (optionally capped at ``block_epochs``). A
   ``const:K`` schedule is one segment; ``log`` is O(log T) segments.
2. **Scan compilation.** Each segment executes as a single ``jax.lax.scan``
   over the unified ``EpochCarry`` — one dispatch per segment, with the
   per-epoch ``EpochAux`` rows written into the scan's preallocated
   on-device output buffers. Worker straggler masks are precomputed as a
   ``(num_epochs, nw)`` array and indexed by the carried epoch counter
   inside the scan. Segments sharing a (K, length) shape share one
   executable.
3. **Gap-certificate early stop.** The psum'd duality gap rides the scan
   carry as a ``done`` flag: once ``gap <= gap_tol`` every remaining epoch
   in the segment is a ``lax.cond`` no-op (static shapes preserved, compute
   skipped), and the host stops launching segments at the next boundary.
   ``epochs_run`` counts the epochs that actually executed.

Host transfers happen only at segment boundaries (and only when early
stopping or a callback needs them) plus one final history fetch — all via
explicit ``jax.device_get``, so a run under
``jax.transfer_guard_device_to_host("disallow")`` proves the loop is
device-resident (regression-pinned in ``tests/test_engine.py``).

``mode="legacy"`` reproduces the pre-engine driver — one dispatch per epoch
and four blocking scalar pulls — on the same unified carry; it exists as the
trajectory-equivalence oracle and the baseline ``benchmarks/engine_bench.py``
measures against.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..compat import shard_map_compat
from ..obs import Telemetry
from . import low_rank
from .frank_wolfe import (
    EpochAux,
    EpochCarry,
    init_carry,
    init_probe,
    k_schedule,
    make_epoch_step,
    parse_solver,
)
from .power_method import AxisName

PyTree = Any


class Segment(NamedTuple):
    """A maximal run of epochs sharing one (static) power-iteration count."""

    start: int  # first epoch index
    length: int  # number of epochs
    k: int  # K(t) throughout the segment


def plan_segments(
    schedule: str,
    num_epochs: int,
    block_epochs: Optional[int] = None,
    *,
    start: int = 0,
) -> List[Segment]:
    """Partition ``[start, num_epochs)`` into maximal constant-K segments.

    ``block_epochs`` caps segment length: early stopping acts at segment
    granularity, so the cap bounds how many epochs a converged run can
    execute past its certificate (and how stale a progress callback gets).
    Equal-length blocks of the same K share one compiled executable, so
    chopping a long ``const:K`` run costs extra dispatches, not compiles.

    ``start`` > 0 is the resume case: checkpoints are written at segment
    boundaries, and because maximality (and the block cap) are computed
    from each segment's own start, planning from a boundary of the full
    plan reproduces exactly that plan's remaining segments — a resumed run
    dispatches the same (K, length) executables the uninterrupted run
    would have, which is what makes bit-exact resume possible.
    """
    if num_epochs < 1:
        raise ValueError(f"num_epochs={num_epochs}: need at least one epoch")
    if block_epochs is not None and block_epochs < 1:
        raise ValueError(f"block_epochs={block_epochs}: must be >= 1")
    if not 0 <= start < num_epochs:
        raise ValueError(
            f"start={start}: must lie in [0, num_epochs={num_epochs})"
        )
    sched = k_schedule(schedule)
    segments: List[Segment] = []
    t = start
    while t < num_epochs:
        k = sched(t)
        end = t + 1
        while (
            end < num_epochs
            and sched(end) == k
            and (block_epochs is None or end - t < block_epochs)
        ):
            end += 1
        segments.append(Segment(start=t, length=end - t, k=k))
        t = end
    return segments


def resolve_max_rank(
    max_rank: Optional[int], num_epochs: int, atoms_per_epoch: int = 1
) -> int:
    """Factored-iterate capacity. ``atoms_per_epoch`` factors are appended
    per epoch (1 for rank1, k for ``block:k``) and ``low_rank.fw_update``
    clamps out-of-range writes silently, so undersizing would corrupt the
    returned iterate — reject it up front. (Shared by the serial and sharded
    drivers: one capacity contract.)"""
    need = num_epochs * atoms_per_epoch
    if max_rank is None:
        return need
    if max_rank < need:
        raise ValueError(
            f"max_rank={max_rank} < num_epochs*atoms={need}: every "
            f"epoch appends {atoms_per_epoch} factor(s), so the iterate "
            "store would overflow"
        )
    return max_rank


def dispatch_contract(
    *,
    segments: int = 1,
    max_compilations: Optional[int] = 2,
    name: Optional[str] = None,
):
    """The engine's reason to exist, declared as a checkable contract: a run
    over ``segments`` planned segments costs at most ``segments + 1`` jitted
    dispatches (one scan per segment + the driver's final-loss eval), at
    most O(1) explicit host syncs, and — under ``contract.guard()`` — zero
    implicit device->host transfers. ``max_compilations`` defaults to 2
    (the single-segment ``const:K`` case: one scan executable + the final
    loss eval); pass ``None`` for schedules whose distinct (K, length)
    signature count isn't pinned. Consumed by ``tests/test_engine.py`` (the
    serial, log-schedule, and 8-way pins) and ``tools/repro_contracts.py``
    against ``FitResult.stats``."""
    from ..analysis.contracts import Contract  # lazy: analysis is tooling

    return Contract(
        name=name or f"engine.dispatch[segments={segments}]",
        max_dispatches=segments + 1,
        max_compilations=max_compilations,
        max_host_syncs=2,
        no_host_transfers=True,
    )


@dataclasses.dataclass
class EngineResult:
    """``history`` lists are truncated to ``epochs_run``. ``stats`` counts
    the engine's interactions with the runtime — the quantities the
    dispatch/sync regression tests pin:

    - ``segments_planned`` / ``segments_run``: plan size vs segments
      actually launched (early stop skips the tail),
    - ``dispatches``: jitted calls issued,
    - ``compilations``: distinct executables built (segments sharing a
      (K, length) shape reuse one),
    - ``host_syncs``: explicit ``jax.device_get`` round-trips (legacy mode
      counts its four blocking per-epoch scalar pulls here).
    """

    carry: EpochCarry
    history: Dict[str, list]
    epochs_run: int
    stats: Dict[str, int]


def _segment_step(
    task,
    mu: float,
    k: int,
    length: int,
    *,
    step_size: str,
    axis_name: AxisName,
    reducer,
    gap_tol: Optional[float],
    has_masks: bool,
    solver="rank1",
) -> Callable:
    """One segment as a pure function: ``length`` epochs under ``lax.scan``.

    Signature (before any shard_map wrapping):
    ``seg(carry, done, epochs_run[, masks]) -> (carry, done, epochs_run, aux)``
    where ``aux`` leaves are ``(length,)`` — the scan's preallocated
    on-device history rows — and ``masks`` is the full ``(num_epochs, nw)``
    straggler-weight array, indexed at ``[carry.t, 0]`` inside the scan
    (inside shard_map every worker holds its own ``(num_epochs, 1)`` column).
    Epochs after the gap certificate fires are ``lax.cond`` no-ops emitting
    NaN aux rows (truncated away by the host).
    """
    epoch = make_epoch_step(
        task, mu, k, step_size=step_size, axis_name=axis_name,
        reducer=reducer, solver=solver,
    )
    tol = jnp.float32(-jnp.inf if gap_tol is None else gap_tol)

    def segment(carry, done, epochs_run, masks=None):
        def body(c, _):
            def live(c):
                carry, done, epochs_run = c
                w = masks[carry.t, 0] if has_masks else None
                carry, aux = epoch(carry, worker_weight=w)
                return (carry, done | (aux.gap <= tol), epochs_run + 1), aux

            def skip(c):
                nan = jnp.float32(jnp.nan)
                return c, EpochAux(
                    loss=nan, gap=nan, sigma=nan, gamma=nan, piters=nan
                )

            done = c[1]
            return jax.lax.cond(done, skip, live, c)

        (carry, done, epochs_run), aux = jax.lax.scan(
            body, (carry, done, epochs_run), None, length=length
        )
        return carry, done, epochs_run, aux

    return segment


def sharded_carry_spec(
    axis_or_axes,
    state_spec: PyTree,
    comm_state_example: PyTree = (),
    probe_example: PyTree = (),
    *,
    per_node_iterate: bool = False,
):
    """shard_map PartitionSpecs for an ``EpochCarry``: task state rows
    sharded over the data axes, iterate/counter/key replicated, and every
    reducer-state leaf carried with a *leading worker axis* sharded like the
    data rows (dense's ``()`` has no leaves — encoding-agnostic). The block
    solver's warm-start probe is replicated like the iterate (``()`` for
    rank1 — zero extra leaves).

    ``per_node_iterate=True`` (gossip topologies) gives the factored iterate
    the same leading-worker-axis treatment as the reducer state: every
    worker evolves its *own* inexact-consensus iterate, so the driver stacks
    the leaves to ``(nw, ...)`` and shard_map hands each worker its slice.

    ``comm_state_example`` is one worker's (unstacked) reducer state;
    ``probe_example`` the replicated probe block (or ``()``)."""
    from jax.sharding import PartitionSpec as P

    ax = axis_or_axes
    it_spec = P(ax) if per_node_iterate else P()
    return EpochCarry(
        state=state_spec,
        iterate=low_rank.FactoredIterate(
            u=it_spec, s=it_spec, v=it_spec, alpha=it_spec, count=it_spec
        ),
        comm_state=jax.tree.map(lambda _: P(ax), comm_state_example),
        t=P(),
        key=P(),
        probe=jax.tree.map(lambda _: P(), probe_example),
    )


def strip_worker_axis(
    carry: EpochCarry, *, per_node_iterate: bool = False
) -> EpochCarry:
    """Inside a shard_map region: drop the leading worker axis off the comm
    leaves — a worker owns its (1, ...) slice of the stacked reducer state.
    With ``per_node_iterate`` the factored-iterate leaves are stacked the
    same way and stripped too."""
    carry = carry._replace(
        comm_state=jax.tree.map(lambda a: a[0], carry.comm_state)
    )
    if per_node_iterate:
        carry = carry._replace(
            iterate=jax.tree.map(lambda a: a[0], carry.iterate)
        )
    return carry


def restore_worker_axis(
    carry: EpochCarry, *, per_node_iterate: bool = False
) -> EpochCarry:
    carry = carry._replace(
        comm_state=jax.tree.map(lambda a: a[None], carry.comm_state)
    )
    if per_node_iterate:
        carry = carry._replace(
            iterate=jax.tree.map(lambda a: a[None], carry.iterate)
        )
    return carry


def shard_map_segment_wrapper(
    mesh,
    axis_or_axes,
    state_spec: PyTree,
    *,
    comm_state_example: PyTree = (),
    probe_example: PyTree = (),
    has_masks: bool = False,
    per_node_iterate: bool = False,
) -> Callable[[Callable], Callable]:
    """Build the canonical ``segment_wrapper``: shard_map with the task
    state row-sharded, iterate/scalars/key/probe replicated, straggler masks
    column-sharded, and reducer state carried with a leading worker axis
    (sharded like the data rows) that is stripped inside the region.
    ``per_node_iterate`` extends that leading-axis treatment to the factored
    iterate (gossip topologies; see ``sharded_carry_spec``).
    """
    from jax.sharding import PartitionSpec as P

    ax = axis_or_axes
    carry_spec = sharded_carry_spec(
        ax, state_spec, comm_state_example, probe_example,
        per_node_iterate=per_node_iterate,
    )
    aux_spec = EpochAux(P(), P(), P(), P(), P())

    def wrap(seg_fn):
        def step(carry, done, epochs_run, *masks):
            carry, done, epochs_run, aux = seg_fn(
                strip_worker_axis(carry, per_node_iterate=per_node_iterate),
                done, epochs_run, *masks
            )
            return (
                restore_worker_axis(carry, per_node_iterate=per_node_iterate),
                done, epochs_run, aux,
            )

        mask_specs = (P(None, ax),) if has_masks else ()
        return shard_map_compat(
            step,
            mesh,
            in_specs=(carry_spec, P(), P()) + mask_specs,
            out_specs=(carry_spec, P(), P(), aux_spec),
        )

    return wrap


_HISTORY_KEYS = ("loss", "gap", "sigma", "gamma")


def _assemble_history(
    prefix: Dict[str, list], aux_blocks: List[tuple], upto: int
) -> Dict[str, list]:
    """Prefix history + every fetched aux block, truncated to ``upto``
    executed epochs (rows past an early stop are NaN no-op fillers). All
    blocks must carry their host copy — callers fetch before assembling."""
    hist = {name: list(prefix[name]) for name in (*_HISTORY_KEYS, "k")}
    for seg, host_aux, _ in aux_blocks:
        for name, col in zip(_HISTORY_KEYS, host_aux):
            hist[name].extend(float(v) for v in col)
        hist["k"].extend([seg.k] * seg.length)
    for name in hist:
        del hist[name][upto:]
    return hist


def run_epochs(
    task,
    state: PyTree,
    *,
    mu: float,
    num_epochs: int,
    key: jax.Array,
    schedule: str = "const:2",
    step_size: str = "default",
    axis_name: AxisName = None,
    reducer=None,
    comm_state: Optional[PyTree] = None,
    iterate: Optional[low_rank.FactoredIterate] = None,
    max_rank: Optional[int] = None,
    masks: Optional[jax.Array] = None,
    gap_tol: Optional[float] = None,
    block_epochs: Optional[int] = None,
    segment_wrapper: Optional[Callable[[Callable], Callable]] = None,
    callback: Optional[Callable[[int, EpochAux], None]] = None,
    mode: str = "scan",
    start_t: int = 0,
    initial_history: Optional[Dict[str, list]] = None,
    checkpointer=None,
    telemetry: Optional[Telemetry] = None,
    num_workers: int = 1,
    solver="rank1",
    probe: Optional[PyTree] = None,
) -> EngineResult:
    """Run up to ``num_epochs`` DFW-Trace epochs, device-resident.

    ``comm_state`` defaults to ``reducer.init_state(task.d, task.m)`` (one
    worker's state); a sharded driver passes its worker-stacked version,
    matching whatever its ``segment_wrapper`` strips/restores. ``iterate``
    defaults to a fresh ``low_rank.init`` with ``max_rank`` capacity
    (validated >= num_epochs). ``masks`` is the full ``(num_epochs, nw)``
    straggler-weight schedule or ``None`` for unweighted epochs.

    ``mode="scan"`` (production): one dispatch per segment, host transfers
    at boundaries only. ``mode="legacy"``: the pre-engine loop — per-epoch
    dispatch plus four blocking scalar pulls — same math, same carry, kept
    as the equivalence oracle and overhead baseline.

    **Checkpointing.** ``checkpointer`` (``repro.checkpoint.dfw.
    RunCheckpointer`` or anything duck-compatible) makes the run durable:
    on the segment boundaries the checkpointer *wants*, the engine fetches
    the carry + the not-yet-fetched aux history with ONE explicit batched
    ``device_get`` and hands them over for an async write — dispatch counts
    are unchanged, boundaries it doesn't want stay sync-free (unless
    ``gap_tol``/callback already sync there), and the hot path never blocks
    on disk (the D2H snapshot is the only added cost). The epoch-t
    checkpoint holds everything the remaining epochs read, so a later run
    can resume from it.

    **Resume.** ``start_t`` (a segment boundary reached by a previous run —
    any checkpoint step qualifies) starts the carry at epoch ``start_t``
    instead of 0; ``state``/``iterate``/``comm_state``/``key`` must then be
    the restored carry fields, ``initial_history`` the restored per-epoch
    history (length ``start_t``), and ``masks``/``num_epochs``/``schedule``
    the full-run values — the plan is recomputed from ``start_t`` and the
    same executables re-dispatch, reproducing the uninterrupted trajectory
    bit-for-bit (pinned in ``tests/test_checkpoint_resume.py``).

    **Telemetry.** ``telemetry`` (``repro.obs.Telemetry``; inert no-op when
    None) records compile/dispatch/segment spans, per-executable comm cost
    (analytic ``Reducer.wire_bytes`` vs dense logical bytes, scaled by
    ``num_workers``, plus an HLO walk once per compile when the handle
    wants it), per-epoch loss/gap/sigma/gamma counter samples, and the
    early-stop instant. Every scalar rides a ``device_get`` the engine
    already performs — enabling telemetry adds zero host syncs and zero
    dispatches, which the contract pins in ``tests/test_engine.py`` verify
    with an enabled handle under the transfer guard.
    """
    if mode not in ("scan", "legacy"):
        raise ValueError(f"mode={mode!r}: expected 'scan' or 'legacy'")
    if not 0 <= start_t < num_epochs:
        raise ValueError(
            f"start_t={start_t}: must lie in [0, num_epochs={num_epochs}) — "
            "a run checkpointed at or past num_epochs has nothing left to do"
        )
    if initial_history is not None:
        for name, vals in initial_history.items():
            if len(vals) != start_t:
                raise ValueError(
                    f"initial_history[{name!r}] has {len(vals)} entries for "
                    f"start_t={start_t}; pass the restored prefix unmodified"
                )
    sspec = parse_solver(solver)
    if sspec.kind == "block" and sspec.k > min(task.d, task.m):
        raise ValueError(
            f"solver block:{sspec.k}: block width exceeds "
            f"min(d={task.d}, m={task.m})"
        )
    k_block = sspec.k if sspec.kind == "block" else 1
    if reducer is None:
        from ..comm.base import DenseReducer

        reducer = DenseReducer()
    if comm_state is None:
        # Block mode flattens (d,k)/(m,k) blocks through the reducer, so
        # stateful encodings (topk residuals) must be sized for the
        # flattened payload.
        comm_state = reducer.init_state(task.d * k_block, task.m * k_block)
    if iterate is None:
        iterate = low_rank.init(
            resolve_max_rank(max_rank, num_epochs, k_block), task.d, task.m
        )
    if sspec.kind == "block":
        if probe is None:
            probe = init_probe(sspec, task.m)
    else:
        probe = () if probe is None else probe
    if masks is not None:
        if masks.shape[0] != num_epochs:
            raise ValueError(
                f"masks has {masks.shape[0]} rows for {num_epochs} epochs"
            )
        if masks.shape[1] > 1 and segment_wrapper is None:
            # The scan body reads masks[t, 0]: each worker's own column after
            # shard_map slices the (num_epochs, nw) array. Without a wrapper
            # there is one "worker", and silently using column 0 would make a
            # multi-worker mask schedule measure nothing.
            raise ValueError(
                f"masks has {masks.shape[1]} worker columns but no "
                "segment_wrapper shards them; pass a shard_map wrapper "
                "(engine.shard_map_segment_wrapper) or a single-column mask"
            )

    segments = plan_segments(
        schedule, num_epochs, 1 if mode == "legacy" else block_epochs,
        start=start_t,
    )
    stats = {
        "segments_planned": len(segments),
        "segments_run": 0,
        "dispatches": 0,
        "compilations": 0,
        "host_syncs": 0,
    }
    has_masks = masks is not None
    wrapper = segment_wrapper if segment_wrapper is not None else (lambda f: f)
    tel = telemetry if telemetry is not None else Telemetry.noop()

    compiled: Dict[tuple, Callable] = {}

    def get_compiled(seg: Segment, args: tuple) -> Callable:
        sig = (seg.k, seg.length)
        if sig not in compiled:
            fn = _segment_step(
                task, mu, seg.k, seg.length,
                step_size=step_size, axis_name=axis_name, reducer=reducer,
                gap_tol=gap_tol, has_masks=has_masks, solver=sspec,
            )
            jitted = jax.jit(wrapper(fn))
            if tel.wants_hlo:
                # Ahead-of-time compile so the post-SPMD HLO is in hand for
                # the one-time comm walk; the executable itself dispatches,
                # so the compile is still counted (and paid) exactly once.
                # jax.jit is kept on the non-HLO path because its call cache
                # is independent of lower().compile() — mixing them would
                # compile twice.
                t0 = tel.now_us()
                exe = jitted.lower(*args).compile()
                tel.complete("engine.compile", "engine", t0,
                             tel.now_us() - t0, k=seg.k, length=seg.length)
                _emit_executable_cost(seg, exe)
                compiled[sig] = exe
            else:
                compiled[sig] = jitted
            stats["compilations"] += 1
        return compiled[sig]

    def _emit_executable_cost(seg: Segment, exe) -> None:
        """One HLO walk per executable (never per step): wire-level
        collective bytes/counts straight from the compiled module."""
        try:
            from ..analysis import hlo as hlo_lib

            info = hlo_lib.analyze(exe.as_text())
        except Exception:  # pragma: no cover - HLO text formats drift
            return
        tel.event(
            "comm.executable", "comm", k=seg.k, length=seg.length,
            hlo_collective_bytes=info["collective_bytes_total"],
            hlo_collective_count={k: v for k, v in info["collective_count"].items()},
            hlo_flops=info["flops"],
        )

    # Analytic per-segment comm cost: 2*K *exchanges* per epoch (K for
    # d-vectors + K for m-vectors), wire bytes from the reducer's own
    # accounting, logical bytes at the dense-f32 convention. The block
    # solver keeps the exchange count and widens each payload by k
    # (flattened (d,k)/(m,k) blocks through the same reducer). A topology
    # (``comm.Topology`` quacks like a Reducer here) may spend several
    # collective rounds per exchange (gossip's R mixing rounds), which
    # ``rounds_per_exchange`` scales into the round count.
    def _comm_cost(seg: Segment) -> Dict[str, float]:
        rpe = int(getattr(reducer, "rounds_per_exchange", 1))
        rounds = 2 * seg.k * seg.length * rpe
        logical = 8.0 * (task.d + task.m) * k_block * seg.k * seg.length
        wire = float(
            seg.k * seg.length * (
                reducer.wire_bytes(task.d * k_block, num_workers)
                + reducer.wire_bytes(task.m * k_block, num_workers)
            )
        )
        return {"rounds": rounds, "logical_bytes": logical, "wire_bytes": wire}

    # Per-hop byte split (topologies only: hier's intra/inter, gossip's
    # neighbor links, flat's single global hop). Empty for plain reducers.
    def _hop_cost(seg: Segment) -> Dict[str, float]:
        hop_fn = getattr(reducer, "hop_wire_bytes", None)
        if hop_fn is None:
            return {}
        out: Dict[str, float] = {}
        for dim in (task.d * k_block, task.m * k_block):
            for hop, nbytes in hop_fn(dim).items():
                out[hop] = out.get(hop, 0.0) + float(seg.k * seg.length * nbytes)
        return out

    carry = init_carry(state, iterate, key, comm_state, t=start_t, probe=probe)
    done = jnp.zeros((), jnp.bool_)
    nrun = jnp.full((), start_t, jnp.int32)
    history: Dict[str, list] = {
        k: list(initial_history[k]) if initial_history is not None else []
        for k in (*_HISTORY_KEYS, "k")
    }

    # Lazy one-time host copy of the mask schedule for checkpoint payloads.
    host_masks_cache: List[Any] = []

    def _host_masks():
        if masks is None:
            return None
        if not host_masks_cache:
            with tel.span("engine.fetch", "engine", kind="masks"):
                host_masks_cache.append(jax.device_get(masks))
            stats["host_syncs"] += 1
            # Straggler accounting rides this one-time fetch (checkpoint
            # payloads are the only consumer that forces it — when nothing
            # fetches the masks, the counts are deliberately not observed
            # rather than paying a sync for them).
            hm = host_masks_cache[0]
            if tel.enabled:
                alive_hist = tel.registry.histogram("engine.alive_workers")
                alive = [int((row > 0).sum()) for row in hm]
                for a in alive:
                    alive_hist.observe(a)
                tel.event(
                    "engine.straggler_masks", "engine",
                    epochs=len(alive), num_workers=int(hm.shape[1]),
                    min_alive=min(alive) if alive else None,
                    mean_alive=sum(alive) / len(alive) if alive else None,
                )
        return host_masks_cache[0]

    if mode == "legacy":
        # Pre-engine behavior: one dispatch + four blocking float() pulls
        # per epoch (each an implicit device->host transfer, like the old
        # driver's `float(aux.loss)` lines). Boundaries are every epoch, so
        # a checkpointer here saves (at most) once per epoch.
        epochs_run = start_t
        for i, seg in enumerate(segments):
            args = (carry, done, nrun) + ((masks,) if has_masks else ())
            t_disp = tel.now_us()
            carry, done, nrun, aux = get_compiled(seg, args)(*args)
            stats["dispatches"] += 1
            stats["segments_run"] += 1
            row = [float(aux.loss[0]), float(aux.gap[0]),
                   float(aux.sigma[0]), float(aux.gamma[0])]
            stats["host_syncs"] += 4
            t_end = tel.now_us()
            tel.complete("engine.segment", "engine", t_disp, t_end - t_disp,
                         start=seg.start, length=seg.length, k=seg.k)
            for name, val in zip(_HISTORY_KEYS, row):
                tel.counter_sample(f"dfw.{name}", val, ts_us=t_end)
            for name, val in zip(_HISTORY_KEYS, row):
                history[name].append(val)
            history["k"].append(seg.k)
            epochs_run += 1
            if callback is not None:
                callback(seg.start, jax.device_get(aux))
                stats["host_syncs"] += 1
            stop = gap_tol is not None and row[1] <= gap_tol
            if checkpointer is not None:
                last = stop or i == len(segments) - 1
                if checkpointer.want(i, last):
                    host_carry = jax.device_get(carry)
                    stats["host_syncs"] += 1
                    checkpointer.save_segment(
                        t=epochs_run, carry=host_carry, history=history,
                        masks=_host_masks(), done=stop,
                    )
            if stop:
                tel.event("engine.early_stop", "engine", epoch=epochs_run,
                          gap=row[1], gap_tol=gap_tol)
                break
        return EngineResult(
            carry=carry, history=history, epochs_run=epochs_run, stats=stats
        )

    # (Segment, host EpochAux | None, device EpochAux) per segment run; the
    # host slot is filled when a callback or checkpoint already fetched the
    # block, so the final history assembly never transfers the same rows
    # twice. ``seg_ts`` is the parallel dispatch-time list (us) and
    # ``recorded`` the blocks whose telemetry has been emitted — both ride
    # alongside rather than inside the tuples so ``_assemble_history``'s
    # 3-tuple unpacking stays untouched.
    aux_blocks: List[tuple] = []
    seg_ts: List[float] = []
    recorded: set = set()

    def _record_block(idx: int, t_end_us: float) -> None:
        """Telemetry for a block whose host aux just landed: the segment
        span (dispatch -> data on host), the comm-exchange span with
        analytic byte accounting, and per-epoch scalar samples timestamped
        by linear interpolation across the span. Pure bookkeeping on
        already-fetched host values — no device access."""
        if idx in recorded or not tel.enabled:
            return
        recorded.add(idx)
        seg, host_aux, _ = aux_blocks[idx]
        t0 = seg_ts[idx]
        dur = max(t_end_us - t0, 0.0)
        tel.complete("engine.segment", "engine", t0, dur,
                     start=seg.start, length=seg.length, k=seg.k)
        cost = _comm_cost(seg)
        tel.complete("comm.exchange", "comm", t0, dur,
                     spec=getattr(reducer, "spec", None),
                     num_workers=num_workers, **cost)
        reg = tel.registry
        reg.counter("comm.rounds").inc(cost["rounds"])
        reg.counter("comm.logical_bytes").inc(cost["logical_bytes"])
        reg.counter("comm.wire_bytes").inc(cost["wire_bytes"])
        hops = _hop_cost(seg)
        if hops:
            # Topology-mode accounting: one span naming the graph plus a
            # per-hop byte counter split (comm.hop_bytes.intra/inter/...).
            tel.complete(
                "comm.topology", "comm", t0, dur,
                topology=getattr(reducer, "spec", None),
                rounds_per_exchange=int(
                    getattr(reducer, "rounds_per_exchange", 1)
                ),
                **{f"bytes_{h}": b for h, b in sorted(hops.items())},
            )
            for h, b in hops.items():
                reg.counter(f"comm.hop_bytes.{h}").inc(b)
        if sspec.kind == "block":
            reg.gauge("dfw.block.k").set(k_block)
        for j in range(seg.length):
            vals = [float(col[j]) for col in host_aux]
            if math.isnan(vals[0]):  # lax.cond no-op filler past early stop
                continue
            ts = t0 + dur * (j + 1) / seg.length
            for name, val in zip(_HISTORY_KEYS, vals):
                tel.counter_sample(f"dfw.{name}", val, ts_us=ts)
                reg.gauge(f"dfw.{name}").set(val)
            if sspec.kind == "block":
                # Executed block power iterations (host aux's piters column
                # — rides the fetch the engine already performs, zero added
                # syncs; < K per epoch when the adaptive stop fired).
                reg.counter("dfw.block.power_iters").inc(
                    float(host_aux.piters[j])
                )
            reg.counter("engine.epochs").inc()

    for i, seg in enumerate(segments):
        args = (carry, done, nrun) + ((masks,) if has_masks else ())
        exe = get_compiled(seg, args)
        t_disp = tel.now_us()
        carry, done, nrun, aux = exe(*args)
        tel.complete("engine.dispatch", "engine", t_disp,
                     tel.now_us() - t_disp, start=seg.start,
                     length=seg.length, k=seg.k)
        stats["dispatches"] += 1
        stats["segments_run"] += 1
        host_aux = None
        host_done = None
        if callback is not None or (checkpointer is not None and gap_tol is not None):
            # The light boundary fetch: aux rows + the two scalars — it
            # serves the callback and the early-stop check in one sync.
            # Without a callback or gap_tol, boundaries the checkpointer
            # does NOT want stay sync-free, preserving the dispatch
            # pipelining and the batched end-of-run aux fetch.
            with tel.span("engine.fetch", "engine", kind="boundary"):
                host_aux, host_done, host_nrun = jax.device_get((aux, done, nrun))
            stats["host_syncs"] += 1
            host_done = bool(host_done)
            if callback is not None:
                callback(seg.start, host_aux)
        aux_blocks.append((seg, host_aux, aux))
        seg_ts.append(t_disp)
        if host_aux is not None:
            _record_block(i, tel.now_us())
        if checkpointer is not None:
            last = bool(host_done) or i == len(segments) - 1
            if checkpointer.want(i, last):
                # One batched sync: the carry (the payload) plus every aux
                # block not yet on host (skipped boundaries included) plus
                # the scalars — the checkpoint needs the full history-so-far
                # anyway, and the blocks are reused by the final assembly.
                pending_idx = [
                    j for j, (_, h, _) in enumerate(aux_blocks) if h is None
                ]
                with tel.span("engine.fetch", "engine", kind="checkpoint"):
                    host_carry, pend, host_done, host_nrun = jax.device_get(
                        (carry, [aux_blocks[j][2] for j in pending_idx], done, nrun)
                    )
                stats["host_syncs"] += 1
                host_done = bool(host_done)
                for j, h in zip(pending_idx, pend):
                    aux_blocks[j] = (aux_blocks[j][0], h, aux_blocks[j][2])
                t_fetch = tel.now_us()
                for j in pending_idx:
                    _record_block(j, t_fetch)
                t_now = int(host_nrun)
                checkpointer.save_segment(
                    t=t_now, carry=host_carry,
                    history=_assemble_history(history, aux_blocks, t_now),
                    masks=_host_masks(), done=host_done,
                )
        if gap_tol is not None:
            if host_done is None:
                # The only mid-run sync: one scalar at the segment boundary,
                # deciding whether to launch the next segment.
                stats["host_syncs"] += 1
                with tel.span("engine.fetch", "engine", kind="done-flag"):
                    host_done = bool(jax.device_get(done))
            if host_done:
                break

    pending = [a for _, h, a in aux_blocks if h is None]
    with tel.span("engine.fetch", "engine", kind="final"):
        fetched, epochs_run = jax.device_get((pending, nrun))
    stats["host_syncs"] += 1
    t_final = tel.now_us()
    epochs_run = int(epochs_run)
    it = iter(fetched)
    aux_blocks = [
        (seg, h if h is not None else next(it), a) for seg, h, a in aux_blocks
    ]
    for j in range(len(aux_blocks)):
        _record_block(j, t_final)
    history = _assemble_history(history, aux_blocks, epochs_run)
    if gap_tol is not None and epochs_run < num_epochs:
        gap_hist = history.get("gap") or [float("nan")]
        tel.event("engine.early_stop", "engine", epoch=epochs_run,
                  gap=gap_hist[-1], gap_tol=gap_tol)
    return EngineResult(
        carry=carry, history=history, epochs_run=epochs_run, stats=stats
    )
