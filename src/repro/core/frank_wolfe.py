"""DFW-TRACE driver (paper Algorithm 2).

``make_epoch_step`` builds one jit-able FW epoch: distributed power method on
the implicit gradient -> step size (default 2/(t+2) or closed-form line
search) -> sufficient-information update + factored-iterate append. The same
function runs serially (axis_name=None) or inside shard_map over the data mesh
axes — the paper's BSP master is just ``psum``. The multi-device driver that
does the wrapping (mesh build, row-wise state sharding, worker sampling,
Pallas-kernelized matvecs) lives in ``launch/dfw.py``; ``fit`` below is the
serial/single-process driver.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import low_rank
from .power_method import AxisName, PowerResult, power_iterations, sphere_vector
from .trace_norm import duality_gap

PyTree = Any


class EpochAux(NamedTuple):
    loss: jax.Array  # F(W^t) (pre-update), psum'd
    gap: jax.Array  # duality-gap estimate at W^t
    sigma: jax.Array  # power-method top-singular-value estimate
    gamma: jax.Array  # step size actually taken


# ---------------------------------------------------------------------------
# K(t) schedules (paper Thm 2 + experimental settings §5)
# ---------------------------------------------------------------------------


def k_schedule(name: str) -> Callable[[int], int]:
    """Power-iteration schedules. Names mirror the paper's variants:

    - ``const:K``   DFW-TRACE-K (K(t) = K; paper uses 1 and 2)
    - ``log``       DFW-TRACE-log, K(t) = floor(1 + ln(t+1))
    - ``log_half``  K(t) = floor(1 + 0.5 ln(t+1))  (paper's logistic setting)
    - ``linear:c``  Thm 2 part 1 regime, K(t) = 1 + ceil(c (t+2))

    Every schedule must yield K(t) >= 1: zero power iterations returns the
    u=0, sigma=0 placeholder from ``power_iterations`` and silently corrupts
    both the FW update and the duality gap, so K=0 configurations are
    rejected here rather than failing downstream.
    """
    if name.startswith("const:"):
        k = int(name.split(":")[1])
        if k < 1:
            raise ValueError(
                f"K schedule {name!r}: K must be >= 1 (K=0 yields a zero LMO "
                "direction and a meaningless duality gap)"
            )
        return lambda t: k
    if name == "log":
        return lambda t: int(1 + math.log(t + 1))
    if name == "log_half":
        return lambda t: max(1, int(1 + 0.5 * math.log(t + 1)))
    if name.startswith("linear:"):
        c = float(name.split(":")[1])
        if c <= 0:
            raise ValueError(
                f"K schedule {name!r}: slope c must be > 0 so K(t) >= 1"
            )
        return lambda t: 1 + int(math.ceil(c * (t + 2)))
    raise ValueError(f"unknown K schedule: {name!r}")


# ---------------------------------------------------------------------------
# One FW epoch
# ---------------------------------------------------------------------------


def _psum(x, axis_name: AxisName):
    return x if axis_name is None else jax.lax.psum(x, axis_name)


def make_epoch_step(
    task,
    mu: float,
    num_power_iters: int,
    *,
    step_size: str = "default",
    axis_name: AxisName = None,
    reducer=None,
) -> Callable:
    """Returns ``epoch(state, it, t, key, worker_weight=1.) -> (state, it, aux)``.

    ``num_power_iters`` is static (compile-time); the driver re-jits per
    distinct K(t) value — a handful of compilations for the log schedule.
    ``worker_weight`` is the straggler mask (see power_method docstring).

    ``reducer`` (``repro.comm.Reducer``) reroutes the power method's *vector*
    collectives through a compressed encoding. The scalar psums below — loss,
    <W, grad>, the line-search numerator/denominator — always stay exact:
    they are O(1) on the wire, and corrupting them would bias the step size
    and the duality-gap certificate rather than just the LMO direction. With
    a reducer the epoch signature gains a threaded per-worker state:
    ``epoch(state, it, t, key, worker_weight, comm_state) ->
    (state, it, aux, comm_state)`` (default ``None`` keeps the legacy 3-tuple
    contract bit for bit).
    """
    if step_size not in ("default", "linesearch"):
        raise ValueError(step_size)
    if step_size == "linesearch" and not hasattr(task, "linesearch_terms"):
        raise ValueError(f"{type(task).__name__} has no closed-form line search")
    if num_power_iters < 1:
        raise ValueError(
            f"num_power_iters={num_power_iters}: at least one power iteration "
            "is required (K=0 would feed a zero singular direction to the LMO)"
        )

    def epoch(
        state: PyTree,
        it: low_rank.FactoredIterate,
        t: jax.Array,
        key: jax.Array,
        worker_weight: Optional[jax.Array] = None,
        comm_state: PyTree = None,
    ):
        t = jnp.asarray(t, jnp.float32)
        # All shards derive the same v0 from the replicated key (paper's
        # shared-seed trick: zero communication).
        v0 = sphere_vector(jax.random.fold_in(key, jnp.asarray(t, jnp.int32)), task.m)
        if reducer is None:
            res: PowerResult = power_iterations(
                partial(task.matvec, state),
                partial(task.rmatvec, state),
                v0,
                num_power_iters,
                axis_name=axis_name,
                worker_weight=worker_weight,
            )
        else:
            # Distinct stream from v0's: fold the epoch index, then a tag.
            ckey = jax.random.fold_in(
                jax.random.fold_in(key, jnp.asarray(t, jnp.int32)), 0xC033
            )
            res, comm_state = power_iterations(
                partial(task.matvec, state),
                partial(task.rmatvec, state),
                v0,
                num_power_iters,
                axis_name=axis_name,
                worker_weight=worker_weight,
                reducer=reducer,
                comm_state=comm_state,
                key=ckey,
            )

        w = 1.0 if worker_weight is None else worker_weight
        loss = _psum(w * task.local_loss(state), axis_name)
        inner = _psum(w * task.inner_w_grad(state), axis_name)
        gap = duality_gap(inner, res.sigma, mu)

        if step_size == "linesearch":
            numer, denom = task.linesearch_terms(state, res.u, res.v, mu)
            numer = _psum(w * numer, axis_name)
            denom = _psum(w * denom, axis_name)
            gamma = jnp.clip(numer / jnp.maximum(denom, 1e-30), 0.0, 1.0)
        else:
            gamma = 2.0 / (t + 2.0)

        state = task.update(state, res.u, res.v, gamma, mu)
        it = low_rank.fw_update(it, res.u, res.v, gamma, mu)
        aux = EpochAux(loss=loss, gap=gap, sigma=res.sigma, gamma=gamma)
        if reducer is None:
            return state, it, aux
        return state, it, aux, comm_state

    return epoch


# ---------------------------------------------------------------------------
# Serial / single-process driver (tests, examples, benchmarks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    """``history`` entries are *pre-update* measurements (see ``fit``);
    ``final_loss`` is F at the *returned* iterate — use it when reporting
    the quality of the fitted model."""

    iterate: low_rank.FactoredIterate
    state: PyTree
    history: Dict[str, list]
    final_loss: float = float("nan")


def fit(
    task,
    state: PyTree,
    *,
    mu: float,
    num_epochs: int,
    key: jax.Array,
    schedule: str = "const:2",
    step_size: str = "default",
    axis_name: AxisName = None,
    epoch_wrapper: Optional[Callable[[Callable], Callable]] = None,
    callback: Optional[Callable[[int, EpochAux], None]] = None,
    reducer=None,
) -> FitResult:
    """Run DFW-TRACE for ``num_epochs``.

    **History contract.** ``history[key][t]`` records epoch t's measurements
    at W^t *before* that epoch's update — the loss/gap the power method and
    step size were computed against (matching the paper's per-epoch
    trajectories). The loss of the *returned* iterate W^{num_epochs} never
    appears in ``history``; it is exposed as ``FitResult.final_loss``
    (the psum'd ``task.local_loss`` of the returned state). Benchmarks that
    report "final loss" must use ``final_loss``, not ``history["loss"][-1]``
    (which is one epoch stale).

    ``epoch_wrapper`` contract: a function ``wrap(step) -> step'`` applied to
    each freshly built epoch *before* ``jax.jit`` (one wrap per distinct K(t)
    value). ``step'`` must preserve the positional signature
    ``(state, iterate, t, key) -> (state, iterate, aux)`` with ``t`` a f32
    scalar and ``key`` a replicated PRNG key; identity by default. The
    canonical non-trivial wrapper is shard_map over the data mesh with the
    task state row-sharded and iterate/scalars replicated — that is what
    ``launch/dfw.py`` (and ``core/dfw_head.sharded_fit``) install, paired
    with ``axis_name`` naming the mesh axes so the epoch's psums resolve.
    Callers needing extra per-epoch inputs (e.g. the worker-sampling masks of
    the paper's straggler mode) should drive ``make_epoch_step`` directly, as
    ``launch/dfw.fit`` does, rather than thread them through this loop.

    ``reducer`` routes the power method's vector collectives through a
    compressed encoding (``repro.comm``); serially this *simulates* the
    compression noise of a distributed run (axis_name=None sums one worker),
    which is what the convergence-vs-bits benchmarks sweep. The reducer's
    per-worker state is threaded across epochs here; ``epoch_wrapper`` (if
    any) must then preserve the extended 6-in/4-out epoch signature."""
    sched = k_schedule(schedule)
    it = low_rank.init(num_epochs, task.d, task.m)
    compiled: Dict[int, Callable] = {}
    history: Dict[str, list] = {"loss": [], "gap": [], "sigma": [], "gamma": [], "k": []}
    comm_state = None if reducer is None else reducer.init_state(task.d, task.m)

    for t in range(num_epochs):
        k = sched(t)
        if k not in compiled:
            step = make_epoch_step(
                task, mu, k, step_size=step_size, axis_name=axis_name,
                reducer=reducer,
            )
            if epoch_wrapper is not None:
                step = epoch_wrapper(step)
            compiled[k] = jax.jit(step)
        if reducer is None:
            state, it, aux = compiled[k](state, it, jnp.float32(t), key)
        else:
            state, it, aux, comm_state = compiled[k](
                state, it, jnp.float32(t), key, None, comm_state
            )
        if callback is not None:
            callback(t, aux)
        history["loss"].append(float(aux.loss))
        history["gap"].append(float(aux.gap))
        history["sigma"].append(float(aux.sigma))
        history["gamma"].append(float(aux.gamma))
        history["k"].append(k)
    # Loss at the *returned* iterate (cheap: one O(n_j) reduction outside the
    # epoch; on sharded state the plain sum is already the global loss).
    final_loss = float(jax.jit(task.local_loss)(state))
    return FitResult(iterate=it, state=state, history=history, final_loss=final_loss)
