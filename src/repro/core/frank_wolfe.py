"""DFW-TRACE driver (paper Algorithm 2).

``make_epoch_step`` builds one jit-able FW epoch: distributed power method on
the implicit gradient -> step size (default 2/(t+2) or closed-form line
search) -> sufficient-information update + factored-iterate append. The same
function runs serially (axis_name=None) or inside shard_map over the data mesh
axes — the paper's BSP master is just ``psum``.

**Unified carry.** Every epoch consumes and produces one ``EpochCarry``
``(state, iterate, comm_state, t, key)``. ``comm_state`` is always present —
an empty pytree ``()`` for the exact-psum dense reducer — so there is a single
epoch signature regardless of the collective encoding; no caller branches on
whether a reducer is installed.

Execution lives in ``core/engine.py``: the schedule K(t) is partitioned into
maximal constant-K segments and each segment runs as one ``jax.lax.scan`` over
epochs, so a whole ``const:K`` run is a single jit dispatch with host
transfers only at segment boundaries. ``fit`` below is the serial /
single-process driver on top of that engine; the multi-worker driver (mesh
build, row-wise state sharding, worker sampling, Pallas-kernelized matvecs)
lives in ``launch/dfw.py``.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp

from . import low_rank
from .power_method import (
    AxisName,
    block_power_iterations,
    orthonormalize_block,
    power_iterations,
    sphere_vector,
)
from .trace_norm import duality_gap

# Scalar psums (loss, <W,grad>, line-search terms) stay *exact* by design —
# see comm/base.py — but still route through the comm chokepoint rather than
# raw lax.psum (lint rule REP001), so collective call sites stay auditable.
from ..comm.base import pmax as _pmax
from ..comm.base import psum as _psum

# Solver-spec grammar lives in the shared ``repro.specs`` module (one
# SpecError style across the solver/comm/topology axes); re-exported here
# because this module coined ``parse_solver`` and call sites import it from
# here.
from ..specs import SolverSpec, parse_solver  # noqa: F401

PyTree = Any


class EpochAux(NamedTuple):
    loss: jax.Array  # F(W^t) (pre-update), psum'd
    gap: jax.Array  # duality-gap estimate at W^t
    sigma: jax.Array  # power-method top-singular-value estimate
    gamma: jax.Array  # step size actually taken
    piters: jax.Array  # power iterations actually executed (float32 scalar)


class EpochCarry(NamedTuple):
    """Everything one FW epoch threads to the next — the single epoch
    signature shared by the serial and sharded drivers.

    ``comm_state`` is the reducer's per-worker state pytree (``()`` for the
    dense exact-psum reducer — always present so the carry's structure never
    depends on the collective encoding). ``t`` is the on-device epoch counter
    (int32, so it can live inside ``lax.scan``); ``key`` is the replicated
    run PRNG key — each epoch folds ``t`` in, never splits it, so the carry
    key is constant across epochs (the paper's shared-seed trick).
    ``probe`` is the block solver's warm-start carry — the previous epoch's
    converged (m, k) right singular block, replicated, handed to the next
    epoch's block power iteration at zero communication cost. For the rank1
    solver it is the empty pytree ``()``, so rank1 carries (and their v1
    checkpoints, which restore leaves by order) keep their exact leaf layout.
    """

    state: PyTree  # task sufficient-information state (per-worker shard)
    iterate: low_rank.FactoredIterate  # replicated factored W
    comm_state: PyTree  # reducer per-worker state; () when dense
    t: jax.Array  # () int32 epoch counter
    key: jax.Array  # replicated PRNG key
    probe: PyTree = ()  # block-solver warm-start (m, k) block; () for rank1


def init_carry(
    state: PyTree,
    iterate: low_rank.FactoredIterate,
    key: jax.Array,
    comm_state: PyTree = (),
    t: int = 0,
    probe: PyTree = (),
) -> EpochCarry:
    """Carry at epoch ``t`` (0 for a fresh run; a checkpoint's saved epoch
    counter when resuming), comm state defaulting to dense's ()."""
    return EpochCarry(
        state=state, iterate=iterate, comm_state=comm_state,
        t=jnp.full((), t, jnp.int32), key=key, probe=probe,
    )


# ---------------------------------------------------------------------------
# Solver tiers
# ---------------------------------------------------------------------------

#: Relative tolerance for the spectral-gap-adaptive block power iteration:
#: once no estimated singular value moved by more than ADAPT_RTOL relative to
#: the gap certificate's scale, further iterations cannot change the FW step
#: materially and the remaining K budget is skipped on device.
ADAPT_RTOL = 0.05


def solver_probe_shape(spec, m: int) -> Optional[tuple]:
    """Shape of the warm-start probe leaf carried in ``EpochCarry.probe`` for
    this solver, or ``None`` when the solver carries no probe (rank1)."""
    s = parse_solver(spec)
    return (m, s.k) if s.kind == "block" else None


def init_probe(spec, m: int, key: Optional[jax.Array] = None) -> PyTree:
    """Cold-start probe for a fresh run: a deterministic orthonormal (m, k)
    block for the block solver (built from ``key`` when given, else from a
    fixed seed so every worker agrees without communication), ``()`` for
    rank1."""
    shape = solver_probe_shape(spec, m)
    if shape is None:
        return ()
    k = shape[1]
    if key is None:
        key = jax.random.PRNGKey(0x5EED)
    cols = jnp.stack(
        [sphere_vector(jax.random.fold_in(key, 101 + j), m) for j in range(k)],
        axis=1,
    )
    return orthonormalize_block(cols)


# ---------------------------------------------------------------------------
# K(t) schedules (paper Thm 2 + experimental settings §5)
# ---------------------------------------------------------------------------


def k_schedule(name: str) -> Callable[[int], int]:
    """Power-iteration schedules. Names mirror the paper's variants:

    - ``const:K``   DFW-TRACE-K (K(t) = K; paper uses 1 and 2)
    - ``log``       DFW-TRACE-log, K(t) = floor(1 + ln(t+1))
    - ``log_half``  K(t) = floor(1 + 0.5 ln(t+1))  (paper's logistic setting)
    - ``linear:c``  Thm 2 part 1 regime, K(t) = 1 + ceil(c (t+2))

    Every schedule must yield K(t) >= 1: zero power iterations returns the
    u=0, sigma=0 placeholder from ``power_iterations`` and silently corrupts
    both the FW update and the duality gap, so K=0 configurations are
    rejected here rather than failing downstream.
    """
    if name.startswith("const:"):
        k = int(name.split(":")[1])
        if k < 1:
            raise ValueError(
                f"K schedule {name!r}: K must be >= 1 (K=0 yields a zero LMO "
                "direction and a meaningless duality gap)"
            )
        return lambda t: k
    if name == "log":
        return lambda t: int(1 + math.log(t + 1))
    if name == "log_half":
        return lambda t: max(1, int(1 + 0.5 * math.log(t + 1)))
    if name.startswith("linear:"):
        c = float(name.split(":")[1])  # REP002-ok: parsing a schedule string
        if c <= 0:
            raise ValueError(
                f"K schedule {name!r}: slope c must be > 0 so K(t) >= 1"
            )
        return lambda t: 1 + int(math.ceil(c * (t + 2)))
    raise ValueError(f"unknown K schedule: {name!r}")


# ---------------------------------------------------------------------------
# One FW epoch
# ---------------------------------------------------------------------------


def make_epoch_step(
    task,
    mu: float,
    num_power_iters: int,
    *,
    step_size: str = "default",
    axis_name: AxisName = None,
    reducer=None,
    solver="rank1",
) -> Callable:
    """Returns ``epoch(carry, worker_weight=None) -> (carry, aux)``.

    ``num_power_iters`` is static (compile-time); the engine compiles one
    scan per distinct K(t) segment — a handful of compilations for the log
    schedule. ``worker_weight`` is the straggler mask (see power_method
    docstring); ``None`` means full participation.

    ``reducer`` (``repro.comm.Reducer``) selects the encoding of the power
    method's *vector* collectives; ``None`` means the exact f32 psum
    (``comm.DenseReducer``), whose per-worker state is the empty pytree — the
    carry structure is identical under every encoding. The scalar psums below
    — loss, <W, grad>, the line-search numerator/denominator — always stay
    exact: they are O(1) on the wire, and corrupting them would bias the step
    size and the duality-gap certificate rather than just the LMO direction.

    ``solver`` selects the LMO tier (see ``parse_solver``): ``"rank1"`` is
    the paper's single-atom power method; ``"block:K[:adapt][:cold]"`` is
    the BlockFW tier — a rank-K block power iteration whose k atoms are
    blended into one feasible direction and appended together, with the
    converged right block carried in ``EpochCarry.probe`` as next epoch's
    warm start.
    """
    if step_size not in ("default", "linesearch"):
        raise ValueError(step_size)
    if step_size == "linesearch" and not hasattr(task, "linesearch_terms"):
        raise ValueError(f"{type(task).__name__} has no closed-form line search")
    if num_power_iters < 1:
        raise ValueError(
            f"num_power_iters={num_power_iters}: at least one power iteration "
            "is required (K=0 would feed a zero singular direction to the LMO)"
        )
    sspec = parse_solver(solver)
    if reducer is None:
        from ..comm.base import DenseReducer  # leaf import; no cycle

        reducer = DenseReducer()
    # Per-node topologies (comm.GossipTopology) leave every worker with its
    # own inexact-consensus LMO direction, so sigma/gap become per-node
    # quantities. The aux stays replicated (engine out_specs demand it) by
    # taking the pmax: gap <= tol then certifies *every* node's iterate —
    # the conservative decentralized stopping rule.
    per_node = bool(getattr(reducer, "per_node", False))  # REP002-ok: host attribute
    if per_node and sspec.kind == "block":
        raise ValueError(
            "per-node topologies (gossip) support only the rank1 solver: the "
            "block tier orthonormalizes against a consensus block, which a "
            "master-less exchange cannot provide — use topology='flat' or "
            "'hier:g' with solver='block:k'"
        )

    def epoch(carry: EpochCarry, worker_weight: Optional[jax.Array] = None):
        state, it = carry.state, carry.iterate
        ti = jnp.asarray(carry.t, jnp.int32)
        t = ti.astype(jnp.float32)
        # All shards derive the same v0 from the replicated key (paper's
        # shared-seed trick: zero communication). The reducer key is a
        # distinct stream from v0's: fold the epoch index, then a tag.
        ekey = jax.random.fold_in(carry.key, ti)
        ckey = jax.random.fold_in(ekey, 0xC033)
        w = 1.0 if worker_weight is None else worker_weight
        loss = _psum(w * task.local_loss(state), axis_name)
        inner = _psum(w * task.inner_w_grad(state), axis_name)

        if sspec.kind == "block":
            k = sspec.k
            # Fresh random columns every epoch; the carried probe (when warm)
            # replaces them entirely. Mixing a small random component back in
            # would also work but breaks block:1 == rank1 equivalence.
            rand0 = jnp.stack(
                [sphere_vector(jax.random.fold_in(ekey, 101 + j), task.m)
                 for j in range(k)],
                axis=1,
            )
            if sspec.cold or not isinstance(carry.probe, jax.Array):
                v0 = rand0
            else:
                # Warm start from last epoch's converged right block; any
                # numerically dead column (all-zero from init skeletons)
                # falls back to its random column.
                col_norm = jnp.linalg.norm(carry.probe, axis=0, keepdims=True)
                v0 = jnp.where(col_norm > 1e-6, carry.probe, rand0)
            res, comm_state = block_power_iterations(
                partial(task.matvec, state),
                partial(task.rmatvec, state),
                v0,
                num_power_iters,
                axis_name=axis_name,
                worker_weight=worker_weight,
                reducer=reducer,
                comm_state=carry.comm_state,
                key=ckey,
                adapt_rtol=ADAPT_RTOL if sspec.adaptive else None,
                # Scale for "did refinement stop mattering": the gap
                # certificate is inner + mu*sigma_max, so changes small
                # relative to |inner|/mu (or sigma itself) can't move it.
                adapt_ref=jnp.abs(inner) / mu,
            )
            sigma_max = jnp.max(res.sigma)
            gap = duality_gap(inner, sigma_max, mu)
            # Blend the k atoms into one feasible direction
            # S = -mu sum_j c_j u_j v_j^T with c = sigma / sum(sigma):
            # the trace-ball-normalized top-k projection of -grad
            # (sum c = 1 keeps ||S||_* <= mu). Fold c into u's columns so
            # tasks see the same (u, v) signature as rank1.
            c = res.sigma / (jnp.sum(res.sigma) + 1e-30)
            u_c = res.u * c[None, :]
            if step_size == "linesearch":
                numer, denom = task.linesearch_terms(state, u_c, res.v, mu)
                numer = _psum(w * numer, axis_name)
                denom = _psum(w * denom, axis_name)
                gamma = jnp.clip(numer / jnp.maximum(denom, 1e-30), 0.0, 1.0)
            else:
                gamma = 2.0 / (t + 2.0)
            state = task.update(state, u_c, res.v, gamma, mu)
            it = low_rank.fw_update_block(it, res.u, res.v, c, gamma, mu)
            aux = EpochAux(
                loss=loss, gap=gap, sigma=sigma_max, gamma=gamma,
                piters=res.iters.astype(jnp.float32),
            )
            return EpochCarry(
                state=state, iterate=it, comm_state=comm_state,
                t=ti + 1, key=carry.key, probe=res.probe,
            ), aux

        v0 = sphere_vector(ekey, task.m)
        res, comm_state = power_iterations(
            partial(task.matvec, state),
            partial(task.rmatvec, state),
            v0,
            num_power_iters,
            axis_name=axis_name,
            worker_weight=worker_weight,
            reducer=reducer,
            comm_state=carry.comm_state,
            key=ckey,
        )

        gap = duality_gap(inner, res.sigma, mu)
        sigma = res.sigma
        if per_node:
            # inner/loss are exact global psums (already replicated); only
            # the gossip-estimated sigma — and hence the gap — differs per
            # node. pmax makes both replicated: the recorded gap upper-bounds
            # every node's certificate, so early stop fires only when ALL
            # nodes are within tol.
            gap = _pmax(gap, axis_name)
            sigma = _pmax(sigma, axis_name)

        if step_size == "linesearch":
            numer, denom = task.linesearch_terms(state, res.u, res.v, mu)
            numer = _psum(w * numer, axis_name)
            denom = _psum(w * denom, axis_name)
            gamma = jnp.clip(numer / jnp.maximum(denom, 1e-30), 0.0, 1.0)
        else:
            gamma = 2.0 / (t + 2.0)

        state = task.update(state, res.u, res.v, gamma, mu)
        it = low_rank.fw_update(it, res.u, res.v, gamma, mu)
        aux = EpochAux(
            loss=loss, gap=gap, sigma=sigma, gamma=gamma,
            piters=jnp.full((), num_power_iters, jnp.float32),
        )
        return EpochCarry(
            state=state, iterate=it, comm_state=comm_state,
            t=ti + 1, key=carry.key, probe=carry.probe,
        ), aux

    return epoch


# ---------------------------------------------------------------------------
# Serial / single-process driver (tests, examples, benchmarks)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FitResult:
    """``history`` entries are *pre-update* measurements (see ``fit``);
    ``final_loss`` is F at the *returned* iterate — use it when reporting
    the quality of the fitted model. ``epochs_run`` < the requested epoch
    count when the gap certificate stopped the run early; histories are
    truncated to it. ``stats`` are the engine's dispatch/compile/host-sync
    counters (see ``core/engine.py``)."""

    iterate: low_rank.FactoredIterate
    state: PyTree
    history: Dict[str, list]
    final_loss: float = float("nan")
    epochs_run: int = 0
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)


def fit(
    task,
    state: PyTree,
    *,
    mu: float,
    num_epochs: int,
    key: jax.Array,
    schedule: str = "const:2",
    step_size: str = "default",
    axis_name: AxisName = None,
    segment_wrapper: Optional[Callable[[Callable], Callable]] = None,
    callback: Optional[Callable[[int, EpochAux], None]] = None,
    reducer=None,
    max_rank: Optional[int] = None,
    gap_tol: Optional[float] = None,
    block_epochs: Optional[int] = None,
    mode: str = "scan",
    iterate: Optional[low_rank.FactoredIterate] = None,
    comm_state: Optional[PyTree] = None,
    start_t: int = 0,
    initial_history: Optional[Dict[str, list]] = None,
    checkpointer=None,
    telemetry=None,
    num_workers: int = 1,
    solver: str = "rank1",
    probe: PyTree = None,
) -> FitResult:
    """Run DFW-TRACE for up to ``num_epochs`` on the device-resident engine.

    **History contract.** ``history[key][t]`` records epoch t's measurements
    at W^t *before* that epoch's update — the loss/gap the power method and
    step size were computed against (matching the paper's per-epoch
    trajectories). The loss of the *returned* iterate never appears in
    ``history``; it is exposed as ``FitResult.final_loss`` (the psum'd
    ``task.local_loss`` of the returned state). Benchmarks that report
    "final loss" must use ``final_loss``, not ``history["loss"][-1]``
    (which is one epoch stale).

    ``max_rank`` sizes the factored-iterate store (one factor is appended
    per epoch, so it must be >= ``num_epochs``; default exactly
    ``num_epochs``) — the same capacity contract ``launch/dfw.DFWConfig``
    exposes.

    ``gap_tol`` stops the run once the psum'd duality-gap certificate
    satisfies ``gap <= gap_tol`` (paper Thm 2's stopping rule), checked on
    device every epoch and acted on at segment granularity;
    ``FitResult.epochs_run`` records how many epochs actually executed and
    all histories are truncated to it. ``block_epochs`` caps the scan
    segment length, bounding how many epochs can run past the certificate.

    ``callback(start_t, aux_block)`` fires once per **segment** (not per
    epoch): ``aux_block`` is an ``EpochAux`` of host numpy arrays covering
    epochs ``start_t .. start_t + len - 1``; rows after an early stop are
    NaN. Per-epoch callbacks would force a device->host sync every epoch —
    exactly the overhead the engine exists to remove. Each callback
    invocation does force one segment-boundary sync, so leave it ``None``
    on the hot path.

    ``segment_wrapper`` contract: ``wrap(seg_fn) -> seg_fn'`` applied to
    each segment function before ``jax.jit`` (one wrap per distinct
    (K, length) pair). The canonical non-trivial wrapper is shard_map over
    the data mesh — see ``engine.shard_map_segment_wrapper``, which
    ``core/dfw_head.sharded_fit`` and ``launch/dfw.fit`` install, paired
    with ``axis_name`` naming the mesh axes so the epoch's psums resolve.

    ``reducer`` routes the power method's vector collectives through a
    compressed encoding (``repro.comm``); serially this *simulates* the
    compression noise of a distributed run (axis_name=None sums one worker),
    which is what the convergence-vs-bits benchmarks sweep. ``None`` is the
    exact dense psum. ``mode="legacy"`` runs the pre-engine per-epoch
    dispatch loop (one jit call + four blocking scalar transfers per epoch)
    — kept as the equivalence/off-device-overhead baseline; ``"scan"`` is
    the production path.

    ``checkpointer`` (``repro.checkpoint.dfw.RunCheckpointer``) saves the
    full run carry asynchronously at segment boundaries; to resume, pass
    the restored carry fields back in — ``state``/``iterate``/
    ``comm_state``/``key`` from the checkpoint, ``start_t`` its epoch,
    ``initial_history`` its history — and the run continues bit-exactly
    (see ``core/engine.run_epochs`` and ``tests/test_checkpoint_resume``;
    ``launch/dfw.fit`` wires this end to end via ``DFWConfig.resume_from``).

    ``telemetry`` (``repro.obs.Telemetry``; inert default) is handed to the
    engine for its zero-sync span/metric stream and brackets the final-loss
    eval here; ``num_workers`` only scales the analytic comm byte
    accounting — it never changes the math.

    ``solver`` selects the LMO tier (``parse_solver`` grammar). For the
    block tier, ``probe`` optionally resumes the warm-start block from a
    checkpoint (``None`` cold-starts deterministically); an epoch appends
    k factors, so ``max_rank`` defaults to ``num_epochs * k``.
    """
    from .engine import run_epochs  # local import: engine builds on this module
    from ..obs import Telemetry

    tel = telemetry if telemetry is not None else Telemetry.noop()
    eres = run_epochs(
        task,
        state,
        mu=mu,
        num_epochs=num_epochs,
        key=key,
        schedule=schedule,
        step_size=step_size,
        axis_name=axis_name,
        reducer=reducer,
        iterate=iterate,
        comm_state=comm_state,
        max_rank=max_rank,
        gap_tol=gap_tol,
        block_epochs=block_epochs,
        segment_wrapper=segment_wrapper,
        callback=callback,
        mode=mode,
        start_t=start_t,
        initial_history=initial_history,
        checkpointer=checkpointer,
        telemetry=tel,
        num_workers=num_workers,
        solver=solver,
        probe=probe,
    )
    if checkpointer is not None:
        # Join the last async write so its failure surfaces with the run,
        # not silently at interpreter exit.
        with tel.span("checkpoint.join", "checkpoint"):
            checkpointer.wait()
    # Loss at the *returned* iterate (cheap: one O(n_j) reduction outside the
    # epoch; on sharded state the plain sum is already the global loss).
    with tel.span("engine.final_loss", "engine"):
        final_loss = float(jax.device_get(jax.jit(task.local_loss)(eres.carry.state)))
    eres.stats["dispatches"] += 1
    eres.stats["host_syncs"] += 1
    eres.stats["compilations"] += 1
    if tel.enabled:
        tel.registry.gauge("dfw.final_loss").set(final_loss)
    return FitResult(
        iterate=eres.carry.iterate,
        state=eres.carry.state,
        history=eres.history,
        final_loss=final_loss,
        epochs_run=eres.epochs_run,
        stats=eres.stats,
    )
