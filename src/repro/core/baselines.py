"""Baseline distributed FW strategies from paper §3.1.

NAIVE-DFW : psum the full dense d x m local gradients (O(N d m) communication),
            then solve the LMO exactly on the aggregate.
SVA       : each worker solves the LMO on its *local* gradient, the master
            averages the singular vectors (n_j-weighted, sign-fixed). O(N(d+m))
            communication but biased — no convergence guarantee.

Both share the FW update/bookkeeping with the main driver so benchmark curves
are apples-to-apples.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from . import low_rank
from .frank_wolfe import EpochAux, _psum
from .power_method import AxisName
from .trace_norm import duality_gap


def _exact_top_pair(g: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Exact LMO via SVD (the 'master' computation of NAIVE-DFW)."""
    u, s, vt = jnp.linalg.svd(g, full_matrices=False)
    return u[:, 0], vt[0, :], s[0]


def _sign_fix(vec: jax.Array) -> jax.Array:
    """Resolve SVD sign ambiguity: make the largest-|entry| positive (Bro et al., 2008)."""
    i = jnp.argmax(jnp.abs(vec))
    return vec * jnp.sign(vec[i])


def make_naive_epoch_step(
    task, mu: float, *, step_size: str = "default", axis_name: AxisName = None
) -> Callable:
    """NAIVE-DFW epoch. The ``psum`` of ``local_grad`` IS the O(dm) cost."""

    def epoch(state, it, t, key, worker_weight=None):
        t = jnp.asarray(t, jnp.float32)
        g = _psum(task.local_grad(state), axis_name)  # (d, m): the expensive hop
        u, v, sigma = _exact_top_pair(g)
        # Two-sided convention u^T g v >= 0 so that S* = -mu u v^T:
        u = u * jnp.sign(u @ g @ v)

        loss = _psum(task.local_loss(state), axis_name)
        inner = _psum(task.inner_w_grad(state), axis_name)
        gap = duality_gap(inner, sigma, mu)

        if step_size == "linesearch":
            numer, denom = task.linesearch_terms(state, u, v, mu)
            numer, denom = _psum(numer, axis_name), _psum(denom, axis_name)
            gamma = jnp.clip(numer / jnp.maximum(denom, 1e-30), 0.0, 1.0)
        else:
            gamma = 2.0 / (t + 2.0)

        state = task.update(state, u, v, gamma, mu)
        it = low_rank.fw_update(it, u, v, gamma, mu)
        return state, it, EpochAux(
            loss=loss, gap=gap, sigma=sigma, gamma=gamma,
            piters=jnp.zeros((), jnp.float32),
        )

    return epoch


def make_sva_epoch_step(
    task,
    mu: float,
    *,
    step_size: str = "default",
    axis_name: AxisName = None,
    local_weight: Optional[float] = None,
) -> Callable:
    """SVA epoch. ``local_weight`` is n_j (defaults to the local shard size,
    uniform partitions); vectors are weight-averaged after sign fixing."""

    def epoch(state, it, t, key, worker_weight=None):
        t = jnp.asarray(t, jnp.float32)
        g_local = task.local_grad(state)
        n_j = jnp.asarray(
            local_weight if local_weight is not None else g_local.shape[0], jnp.float32
        )
        u_j, v_j, sigma_j = _exact_top_pair(g_local)
        u_j, v_j = _sign_fix(u_j), _sign_fix(v_j)

        u = _psum(n_j * u_j, axis_name)
        v = _psum(n_j * v_j, axis_name)
        u = u / (jnp.linalg.norm(u) + 1e-30)
        v = v / (jnp.linalg.norm(v) + 1e-30)
        # sigma estimate for gap reporting: u^T (sum_j g_j) v via matvec chain
        sigma = jnp.abs(jnp.dot(u, _psum(task.matvec(state, v), axis_name)))
        # orient the averaged pair so u^T A v >= 0
        u = u * jnp.sign(jnp.dot(u, _psum(task.matvec(state, v), axis_name)))

        loss = _psum(task.local_loss(state), axis_name)
        inner = _psum(task.inner_w_grad(state), axis_name)
        gap = duality_gap(inner, sigma, mu)

        if step_size == "linesearch":
            numer, denom = task.linesearch_terms(state, u, v, mu)
            numer, denom = _psum(numer, axis_name), _psum(denom, axis_name)
            gamma = jnp.clip(numer / jnp.maximum(denom, 1e-30), 0.0, 1.0)
        else:
            gamma = 2.0 / (t + 2.0)

        state = task.update(state, u, v, gamma, mu)
        it = low_rank.fw_update(it, u, v, gamma, mu)
        return state, it, EpochAux(
            loss=loss, gap=gap, sigma=sigma, gamma=gamma,
            piters=jnp.zeros((), jnp.float32),
        )

    return epoch
