"""Paper tasks (§2.3) with Appendix-B *sufficient information* updates.

Each task exposes the implicit-gradient operator interface consumed by the FW
driver and the distributed power method. State lives per-worker (per mesh
shard of the sample axis n); the driver psums the O(d+m) vectors.

Interface (duck-typed; see ``frank_wolfe.DFWTask``):
    init_state(X, Y)      -> state pytree (local shard)
    matvec(state, v)      -> local  grad_j @ v          (d,)
    rmatvec(state, u)     -> local  grad_j^T @ u        (m,)
    update(state,u,v,g,mu)-> state after W <- (1-g)W - g*mu u v^T
    local_loss(state)     -> local loss contribution    ()
    inner_w_grad(state)   -> local <W, grad_j>          ()   (duality gap)
    local_grad(state)     -> dense local gradient (d,m)      (baselines only)
    linesearch(...)       -> optional closed-form step (MTLS only)
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp


def _uvt(xu: jax.Array, v: jax.Array) -> jax.Array:
    """The atom's action on the sample axis: ``outer(xu, v)`` for a rank-1
    (vector) atom, ``xu @ v.T`` for a rank-k block whose u columns already
    carry the blend weights (the ``block:k`` solver's ``S = -mu sum_j c_j
    u_j v_j^T`` with ``c`` folded into ``u``). ndim is static, so the
    rank-1 path stays byte-identical to the pre-block code."""
    if xu.ndim == 1:
        return jnp.outer(xu, v)
    return xu @ v.T


def _entrywise_uv(
    u: jax.Array, v: jax.Array, rows: jax.Array, cols: jax.Array
) -> jax.Array:
    """(sum_j u[rows, j] * v[cols, j]) — the atom evaluated on a COO entry
    shard; reduces to ``u[rows] * v[cols]`` for rank-1 vectors."""
    if u.ndim == 1:
        return jnp.take(u, rows) * jnp.take(v, cols)
    return jnp.sum(
        jnp.take(u, rows, axis=0) * jnp.take(v, cols, axis=0), axis=-1
    )


# ---------------------------------------------------------------------------
# Multi-task least squares:  F(W) = 1/2 ||XW - Y||_F^2
# ---------------------------------------------------------------------------


class MTLSState(NamedTuple):
    """Low-rank ('sufficient information') representation, paper App. B.

    Stores the residual R = X W - Y instead of the d x m gradient; every
    FW quantity is a chain of matvecs through X and R. Memory O(n_j(d+m)).
    """

    x: jax.Array  # (n_j, d)
    y: jax.Array  # (n_j, m)
    r: jax.Array  # (n_j, m) residual X W - Y


@dataclasses.dataclass(frozen=True)
class MultiTaskLeastSquares:
    d: int
    m: int

    def init_state(self, x: jax.Array, y: jax.Array) -> MTLSState:
        # W^0 = 0  =>  R = -Y
        return MTLSState(x=x, y=y, r=-y)

    # grad = X^T R ; never materialized.
    def matvec(self, s: MTLSState, v: jax.Array) -> jax.Array:
        return s.x.T @ (s.r @ v)

    def rmatvec(self, s: MTLSState, u: jax.Array) -> jax.Array:
        return s.r.T @ (s.x @ u)

    def update(self, s: MTLSState, u, v, gamma, mu) -> MTLSState:
        # R' = X[(1-g)W + g S] - Y = (1-g)R - g Y - g mu (X u) v^T
        # (block atoms: u (d,k) with blend weights folded in, v (m,k))
        xu = s.x @ u
        r = (1.0 - gamma) * s.r - gamma * s.y - (gamma * mu) * _uvt(xu, v)
        return MTLSState(x=s.x, y=s.y, r=r)

    def local_loss(self, s: MTLSState) -> jax.Array:
        return 0.5 * jnp.sum(s.r * s.r)

    def inner_w_grad(self, s: MTLSState) -> jax.Array:
        # <W, X^T R> = <X W, R> = <R + Y, R>
        return jnp.sum((s.r + s.y) * s.r)

    def local_grad(self, s: MTLSState) -> jax.Array:
        return s.x.T @ s.r

    def linesearch_terms(self, s: MTLSState, u, v, mu):
        """Local (numerator, denominator) of the closed-form step (App. B):

        gamma* = <-grad, D> / <X^T X D, D>,  D = S - W,
        computed via X D = -mu (X u) v^T - (R + Y)  — all O(n_j(d+m)).
        Returns local contributions; caller psums then divides.
        """
        xd = -(mu) * _uvt(s.x @ u, v) - (s.r + s.y)
        numer = -jnp.sum(s.r * xd)
        denom = jnp.sum(xd * xd)
        return numer, denom


class MTLSDenseState(NamedTuple):
    """Dense sufficient information (paper App. B, 'dense' column):
    (X^T X, X^T Y, grad). Memory O(d^2 + dm); epoch cost independent of n_j.
    Preferable when n_j >> max(d, m)."""

    xtx: jax.Array  # (d, d) fixed
    xty: jax.Array  # (d, m) fixed
    g: jax.Array  # (d, m) local gradient X^T X W - X^T Y


@dataclasses.dataclass(frozen=True)
class MultiTaskLeastSquaresDense:
    d: int
    m: int

    def init_state(self, x: jax.Array, y: jax.Array) -> MTLSDenseState:
        xty = x.T @ y
        return MTLSDenseState(xtx=x.T @ x, xty=xty, g=-xty)

    def matvec(self, s: MTLSDenseState, v: jax.Array) -> jax.Array:
        return s.g @ v

    def rmatvec(self, s: MTLSDenseState, u: jax.Array) -> jax.Array:
        return s.g.T @ u

    def update(self, s: MTLSDenseState, u, v, gamma, mu) -> MTLSDenseState:
        # grad' = (1-g) grad + g (X^T X S - X^T Y),  X^T X S = -mu (X^T X u) v^T
        rank1 = -(mu) * jnp.outer(s.xtx @ u, v)
        g = (1.0 - gamma) * s.g + gamma * (rank1 - s.xty)
        return MTLSDenseState(xtx=s.xtx, xty=s.xty, g=g)

    def local_grad(self, s: MTLSDenseState) -> jax.Array:
        return s.g


# ---------------------------------------------------------------------------
# Multinomial logistic regression:
#   F(W) = sum_i [ logsumexp(x_i W) - (x_i W)_{y_i} ]
# ---------------------------------------------------------------------------


class LogisticState(NamedTuple):
    x: jax.Array  # (n_j, d)
    y: jax.Array  # (n_j,) int labels
    z: jax.Array  # (n_j, m) logits X W  (low-rank-updated)


@dataclasses.dataclass(frozen=True)
class MultinomialLogistic:
    d: int
    m: int

    def init_state(self, x: jax.Array, y: jax.Array) -> LogisticState:
        return LogisticState(x=x, y=y, z=jnp.zeros((x.shape[0], self.m), x.dtype))

    def _probs(self, s: LogisticState) -> jax.Array:
        return jax.nn.softmax(s.z, axis=-1)

    # grad = X^T (P - H); H is one-hot(y). Never materialized.
    def matvec(self, s: LogisticState, v: jax.Array) -> jax.Array:
        pv = self._probs(s) @ v - v[s.y]  # (n_j,)
        return s.x.T @ pv

    def rmatvec(self, s: LogisticState, u: jax.Array) -> jax.Array:
        t = s.x @ u  # (n_j,)
        return self._probs(s).T @ t - jnp.zeros((self.m,), t.dtype).at[s.y].add(t)

    def update(self, s: LogisticState, u, v, gamma, mu) -> LogisticState:
        z = (1.0 - gamma) * s.z - (gamma * mu) * _uvt(s.x @ u, v)
        return LogisticState(x=s.x, y=s.y, z=z)

    def local_loss(self, s: LogisticState) -> jax.Array:
        lse = jax.scipy.special.logsumexp(s.z, axis=-1)
        return jnp.sum(lse - jnp.take_along_axis(s.z, s.y[:, None], axis=-1)[:, 0])

    def inner_w_grad(self, s: LogisticState) -> jax.Array:
        # <W, X^T(P-H)> = <Z, P - H>
        p = self._probs(s)
        zy = jnp.take_along_axis(s.z, s.y[:, None], axis=-1)[:, 0]
        return jnp.sum(s.z * p) - jnp.sum(zy)

    def local_grad(self, s: LogisticState) -> jax.Array:
        p = self._probs(s)
        h = jax.nn.one_hot(s.y, self.m, dtype=p.dtype)
        return s.x.T @ (p - h)

    def errors(self, s: LogisticState, top_k: int = 5) -> jax.Array:
        """Local count of top-k misclassifications (paper's error metric)."""
        _, idx = jax.lax.top_k(s.z, top_k)
        hit = jnp.any(idx == s.y[:, None], axis=-1)
        return jnp.sum(~hit)


# ---------------------------------------------------------------------------
# Matrix completion:  F(W) = 1/2 sum_{(i,j) in Omega} (W_ij - M_ij)^2
# ---------------------------------------------------------------------------


class MCState(NamedTuple):
    """Sparse sufficient information (paper App. B, completion column).

    A worker stores only its shard of observed entries in COO layout plus the
    residual *on those entries* — never the d x m matrix. Every FW quantity is
    a segment-gather/scatter chain over the entry axis, so per-worker memory
    and per-epoch compute are O(|Omega_j| + d + m).

    ``weight`` is a {0, 1} padding mask: the distributed driver pads shards to
    equal entry counts (static shapes under shard_map) with weight-0 dummy
    entries. ``resid`` is stored *pre-masked* (``weight * (W_ij - M_ij)``), so
    padding entries contribute exactly zero to every reduction and matvec.
    """

    rows: jax.Array  # (p_j,) int32 global row index of each observed entry
    cols: jax.Array  # (p_j,) int32 global column index
    vals: jax.Array  # (p_j,) observed values M_ij (arbitrary on padding)
    resid: jax.Array  # (p_j,) weight * (W_ij - M_ij)
    weight: jax.Array  # (p_j,) {0,1} mask; 0 marks padding entries


def pack_observations(
    rows, cols, vals, weight=None
) -> Tuple[jax.Array, jax.Array]:
    """Pack COO observations into the generic ``(x, y)`` driver arrays.

    Returns ``idx`` (p, 2) int32 = [row, col] and ``yw`` (p, 2) f32 =
    [value, weight] — the shapes ``MatrixCompletion.init_state`` consumes and
    ``launch/dfw.shard_rowwise`` shards along the entry axis.
    """
    rows = jnp.asarray(rows, jnp.int32)
    cols = jnp.asarray(cols, jnp.int32)
    vals = jnp.asarray(vals, jnp.float32)
    w = jnp.ones_like(vals) if weight is None else jnp.asarray(weight, jnp.float32)
    return jnp.stack([rows, cols], axis=1), jnp.stack([vals, w], axis=1)


@dataclasses.dataclass(frozen=True)
class MatrixCompletion:
    """Paper §2.3 task 3. The gradient is supported on observed entries only:
    ``grad = P_Omega(W - M)``, a sparse matrix with the residuals as values —
    matvec/rmatvec are scatter-reductions over the entry shard (App. B)."""

    d: int
    m: int

    def init_state(self, idx: jax.Array, yw: jax.Array) -> MCState:
        # W^0 = 0  =>  resid = weight * (0 - M)
        rows = idx[:, 0].astype(jnp.int32)
        cols = idx[:, 1].astype(jnp.int32)
        vals = yw[:, 0]
        weight = yw[:, 1]
        return MCState(rows=rows, cols=cols, vals=vals,
                       resid=-weight * vals, weight=weight)

    # grad @ v: scatter resid_e * v[col_e] into rows. Never materialized.
    def matvec(self, s: MCState, v: jax.Array) -> jax.Array:
        return jax.ops.segment_sum(
            s.resid * jnp.take(v, s.cols), s.rows, num_segments=self.d
        )

    def rmatvec(self, s: MCState, u: jax.Array) -> jax.Array:
        return jax.ops.segment_sum(
            s.resid * jnp.take(u, s.rows), s.cols, num_segments=self.m
        )

    def update(self, s: MCState, u, v, gamma, mu) -> MCState:
        # W' = (1-g)W - g mu u v^T on the observed entries:
        # resid' = (1-g) resid - g w M - g mu w u[rows] v[cols]
        # (block atoms sum their k columns entrywise — see _entrywise_uv)
        uv = s.weight * _entrywise_uv(u, v, s.rows, s.cols)
        resid = (1.0 - gamma) * s.resid - gamma * s.weight * s.vals - (gamma * mu) * uv
        return s._replace(resid=resid)

    def local_loss(self, s: MCState) -> jax.Array:
        # weight^2 == weight for a {0,1} mask, so resid^2 is already masked
        return 0.5 * jnp.sum(s.resid * s.resid)

    def inner_w_grad(self, s: MCState) -> jax.Array:
        # <W, grad> over observed entries; W_ij = resid + M_ij there, and
        # padding terms vanish with resid == 0.
        return jnp.sum((s.resid + s.weight * s.vals) * s.resid)

    def local_grad(self, s: MCState) -> jax.Array:
        """Dense d x m gradient P_Omega(W - M) — baselines/tests only."""
        return jnp.zeros((self.d, self.m), s.resid.dtype).at[s.rows, s.cols].add(
            s.resid
        )

    def linesearch_terms(self, s: MCState, u, v, mu):
        """Local (numerator, denominator) of the exact step for the quadratic
        objective: gamma* = <-grad, D> / ||P_Omega(D)||^2 with D = S - W,
        restricted to the entry shard (all O(p_j))."""
        # w * D_ij = -mu w u_i v_j - w W_ij, with w W_ij = resid + w M_ij
        dw = -(mu) * s.weight * _entrywise_uv(u, v, s.rows, s.cols) - (
            s.resid + s.weight * s.vals
        )
        numer = -jnp.sum(s.resid * dw)
        denom = jnp.sum(dw * dw)
        return numer, denom

    def rmse(self, s: MCState) -> jax.Array:
        """Local RMSE over this shard's (non-padding) observed entries."""
        return jnp.sqrt(
            jnp.sum(s.resid * s.resid) / jnp.maximum(jnp.sum(s.weight), 1.0)
        )
