"""DFW-TRACE as a first-class framework feature: trace-norm-constrained
classifier / LM-head training on top of any backbone in the model zoo.

This is exactly the paper's ImageNet experiment (frozen ResNet50 features ->
trace-norm multinomial logistic head) transposed to the LM zoo: the backbone
produces d_model features per token; DFW-TRACE learns the (d_model x vocab)
head under ||W||_* <= mu with O(d+V) communication per power iteration.

Distributed execution: features/labels are sharded over the data axes; the
epoch step is the core frank_wolfe epoch wrapped in shard_map (see
``sharded_fit``). The head after T epochs has rank <= T — a certified
low-rank head, storable in factored form.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import lm

from . import engine, frank_wolfe, low_rank, tasks


def extract_features(
    params, batches, cfg, *, max_tokens: Optional[int] = None
) -> Tuple[jax.Array, jax.Array]:
    """Frozen-backbone feature extraction: (X (n, d_model), y (n,))."""
    feats, labels = [], []
    fwd = jax.jit(lambda p, b: lm.forward(p, b, cfg, mode="hidden")["hidden"])
    for batch in batches:
        h = fwd(params, batch)  # (B, S, D)
        b, s, d = h.shape
        feats.append(h.reshape(b * s, d))
        labels.append(jnp.reshape(batch["labels"], (-1,)))
    x = jnp.concatenate(feats)
    y = jnp.concatenate(labels)
    if max_tokens is not None:
        x, y = x[:max_tokens], y[:max_tokens]
    return x.astype(jnp.float32), y


@dataclasses.dataclass
class HeadFitResult:
    iterate: low_rank.FactoredIterate  # factored head, rank <= epochs
    history: Dict[str, list]  # pre-update per-epoch trajectory
    final_loss: float = float("nan")  # loss of the returned head

    def head_matrix(self) -> jax.Array:
        return low_rank.materialize(self.iterate)


def train_head(
    x: jax.Array,  # (n, d) features
    y: jax.Array,  # (n,) int labels
    num_classes: int,
    *,
    mu: float = 30.0,
    num_epochs: int = 50,
    schedule: str = "const:2",
    key: Optional[jax.Array] = None,
) -> HeadFitResult:
    """Single-process DFW-TRACE head fit (paper Fig. 3 setting)."""
    task = tasks.MultinomialLogistic(d=x.shape[1], m=num_classes)
    state = task.init_state(x, y)
    res = frank_wolfe.fit(
        task, state, mu=mu, num_epochs=num_epochs,
        key=key if key is not None else jax.random.PRNGKey(0),
        schedule=schedule, step_size="default",
    )
    return HeadFitResult(iterate=res.iterate, history=res.history,
                         final_loss=res.final_loss)


def sharded_fit(
    mesh: Mesh,
    x: jax.Array,
    y: jax.Array,
    num_classes: int,
    *,
    data_axes=("data",),
    mu: float = 30.0,
    num_epochs: int = 20,
    schedule: str = "const:2",
    key: Optional[jax.Array] = None,
    gap_tol: Optional[float] = None,
    block_epochs: Optional[int] = None,
    checkpointer=None,
    resume=None,
) -> HeadFitResult:
    """DFW-TRACE with the sample axis sharded over ``data_axes`` — the
    production path the multi-pod dry-run lowers. Every epoch's cross-device
    traffic is 2*K psums of (d + m) floats (paper Table 1). Execution is the
    device-resident engine: each constant-K(t) segment is one ``lax.scan``
    inside shard_map, so a ``const:K`` head fit is a single jit dispatch;
    ``gap_tol`` stops on the duality-gap certificate at segment granularity.

    Long head fits are durable like any other DFW-Trace run:
    ``checkpointer`` (``repro.checkpoint.RunCheckpointer``) saves the carry
    at segment boundaries, and ``resume`` (a ``repro.checkpoint.
    RunSnapshot``, e.g. from ``checkpoint.restore_run`` with the sharded
    ``LogisticState`` as ``state_like``) continues a previous fit from its
    saved epoch — the restored global state is re-placed onto *this* mesh,
    so resuming onto a different worker count is the elastic path.
    """
    task = tasks.MultinomialLogistic(d=x.shape[1], m=num_classes)
    ax = data_axes if len(data_axes) > 1 else data_axes[0]
    state_specs = tasks.LogisticState(x=P(ax), y=P(ax), z=P(ax))
    wrapper = engine.shard_map_segment_wrapper(mesh, ax, state_specs)

    state = task.init_state(
        jax.device_put(x, NamedSharding(mesh, P(ax))),
        jax.device_put(y, NamedSharding(mesh, P(ax))),
    )
    iterate, start_t, initial_history = None, 0, None
    if resume is not None:
        state = jax.tree.map(
            lambda a, s: jax.device_put(jnp.asarray(a), NamedSharding(mesh, s)),
            resume.carry.state, state_specs,
        )
        key = jnp.asarray(resume.carry.key)
        start_t, initial_history = resume.t, resume.history
        # Capacity must hold the checkpoint's live factors even when the
        # checkpoint already covers (or exceeds) the requested budget — the
        # finished-run return below still needs the unpacked iterate.
        iterate = resume.unpack_iterate(
            engine.resolve_max_rank(None, max(num_epochs, start_t))
        )
        if start_t >= num_epochs:
            # The checkpoint already covers the requested budget (the final
            # boundary is always saved): return it rather than asking the
            # engine for zero epochs.
            final_loss = float(jax.device_get(jax.jit(task.local_loss)(state)))
            return HeadFitResult(iterate=iterate, history=resume.history,
                                 final_loss=final_loss)
    if checkpointer is not None:
        # Same contract as launch/dfw's drivers: the store is this run's
        # timeline — steps past start_t (all steps, for a fresh fit) would
        # shadow the new history on a later default latest-step restore.
        checkpointer.store.discard_after(start_t)
    res = frank_wolfe.fit(
        task, state, mu=mu, num_epochs=num_epochs,
        key=key if key is not None else jax.random.PRNGKey(0),
        schedule=schedule, step_size="default",
        axis_name=ax,
        segment_wrapper=wrapper,
        gap_tol=gap_tol,
        block_epochs=block_epochs,
        iterate=iterate,
        start_t=start_t,
        initial_history=initial_history,
        checkpointer=checkpointer,
    )
    return HeadFitResult(iterate=res.iterate, history=res.history,
                         final_loss=res.final_loss)


def top_k_error(
    it: low_rank.FactoredIterate, x: jax.Array, y: jax.Array, k: int = 5
) -> float:
    """Paper's top-5 misclassification metric, factored-head evaluation."""
    logits = low_rank.right_multiply(it, x)
    _, idx = jax.lax.top_k(logits, k)
    hit = jnp.any(idx == y[:, None], axis=-1)
    return float(jax.device_get(1.0 - jnp.mean(hit.astype(jnp.float32))))
