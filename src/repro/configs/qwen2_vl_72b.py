"""qwen2-vl-72b [vlm] — M-RoPE (t,h,w)=(16,24,24), dynamic resolution;
backbone only, vision frontend is a stub per assignment. [arXiv:2409.12191; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    d_ff=29568, vocab_size=152064,
    qkv_bias=True, rope_theta=1e6, mrope_sections=(16, 24, 24),
    vision_tokens=1024,
)

SMOKE = ModelConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    qkv_bias=True, mrope_sections=(2, 3, 3), vision_tokens=16,
    dtype="float32", remat="none", seq_chunk=64,
)
