"""qwen2-1.5b [dense] — GQA kv=2, QKV bias, tied embeddings. [arXiv:2407.10671; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b", family="dense",
    num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
    d_ff=8960, vocab_size=151936,
    qkv_bias=True, tie_embeddings=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke", family="dense",
    num_layers=2, d_model=48, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256,
    qkv_bias=True, tie_embeddings=True, dtype="float32", remat="none", seq_chunk=64,
)
