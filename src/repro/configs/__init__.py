"""Architecture registry: one module per assigned arch, exact public configs.

Each module defines CONFIG (full-size, dry-run only) and SMOKE (reduced,
same family/topology, runnable on CPU).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.models.config import ModelConfig

ARCH_IDS: List[str] = [
    "codeqwen1_5_7b",
    "starcoder2_7b",
    "qwen2_1_5b",
    "qwen2_5_14b",
    "arctic_480b",
    "llama4_scout_17b_a16e",
    "qwen2_vl_72b",
    "hubert_xlarge",
    "zamba2_2_7b",
    "rwkv6_7b",
]

# CLI-friendly aliases (dashes/dots as published)
ALIASES: Dict[str, str] = {
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "starcoder2-7b": "starcoder2_7b",
    "qwen2-1.5b": "qwen2_1_5b",
    "qwen2.5-14b": "qwen2_5_14b",
    "arctic-480b": "arctic_480b",
    "llama4-scout-17b-a16e": "llama4_scout_17b_a16e",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "hubert-xlarge": "hubert_xlarge",
    "zamba2-2.7b": "zamba2_2_7b",
    "rwkv6-7b": "rwkv6_7b",
}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE if smoke else mod.CONFIG


def all_configs(smoke: bool = False) -> Dict[str, ModelConfig]:
    return {a: get_config(a, smoke) for a in ARCH_IDS}
