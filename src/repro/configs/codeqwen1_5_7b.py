"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA kv=32, QKV bias, SwiGLU).
[hf:Qwen/CodeQwen1.5-7B; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416,
    qkv_bias=True, rope_theta=1e6,
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    qkv_bias=True, dtype="float32", remat="none", seq_chunk=64,
)
