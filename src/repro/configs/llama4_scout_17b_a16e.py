"""llama4-scout-17b-a16e [moe] — MoE 16e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048,
    num_experts=16, experts_per_token=1, rope_theta=5e5,
)

SMOKE = ModelConfig(
    name="llama4-scout-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=128, vocab_size=256,
    num_experts=4, experts_per_token=1,
    dtype="float32", remat="none", seq_chunk=64,
)
