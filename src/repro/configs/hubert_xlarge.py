"""hubert-xlarge [audio] — encoder-only (non-causal), gelu FFN, frame-embedding
frontend stub (conv feature extractor output dim 512). [arXiv:2106.07447; unverified]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", family="audio",
    num_layers=48, d_model=1280, num_heads=16, num_kv_heads=16,
    d_ff=5120, vocab_size=504,
    mlp_type="gelu", causal=False, frontend_dim=512,
)

SMOKE = ModelConfig(
    name="hubert-xlarge-smoke", family="audio",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=32,
    mlp_type="gelu", causal=False, frontend_dim=24,
    dtype="float32", remat="none", seq_chunk=64,
)
