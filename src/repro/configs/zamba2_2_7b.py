"""zamba2-2.7b [hybrid] — Mamba2 backbone + one SHARED attention block applied
every 6 mamba layers (9 applications over 54 layers). [arXiv:2411.15242; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, hybrid_block=6,
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    ssm_state=16, ssm_head_dim=16, hybrid_block=2,
    dtype="float32", remat="none", seq_chunk=64, ssm_chunk=32,
)
