"""rwkv6-7b (Finch) [ssm] — attention-free, data-dependent per-channel decay.
[arXiv:2404.05892; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=0, num_kv_heads=0,
    d_ff=14336, vocab_size=65536,
)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke", family="ssm",
    num_layers=2, d_model=128, num_heads=0, num_kv_heads=0,
    d_ff=256, vocab_size=256,
    dtype="float32", remat="none", seq_chunk=64, ssm_chunk=32,
)
