"""starcoder2-7b [dense] — GQA kv=4, RoPE, gelu FFN (4x). [arXiv:2402.19173; hf]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-7b", family="dense",
    num_layers=32, d_model=4608, num_heads=36, num_kv_heads=4,
    d_ff=18432, vocab_size=49152,
    mlp_type="gelu", rope_theta=1e5,
)

SMOKE = ModelConfig(
    name="starcoder2-7b-smoke", family="dense",
    num_layers=2, d_model=72, num_heads=6, num_kv_heads=2,
    d_ff=288, vocab_size=256,
    mlp_type="gelu", dtype="float32", remat="none", seq_chunk=64,
)
