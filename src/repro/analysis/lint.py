"""Repo-specific static lint: the ``REPxxx`` rules.

DFW-Trace's efficiency claims are *invariants of the code's shape*, not just
of its outputs: vector collectives only ever go through the ``repro.comm``
Reducer layer (so every driver inherits compressed/exact encodings and the
wire-byte accounting), device values never leak to host implicitly (the
engine's dispatch/sync pins rely on every transfer being an explicit
``jax.device_get``), every Pallas kernel ships with a reference fallback,
and jitted entry points don't recompile per call. Generic linters cannot see
any of this — these rules encode it, so a regression is caught at lint time
in *any* file, not only where a test happens to pin it.

Rules (see ``docs/ANALYSIS.md`` for the full catalog and rationale):

- **REP001** raw ``jax.lax`` collective (``psum``/``all_gather``/…) outside
  ``repro/comm`` — everything else must go through the Reducer contract.
- **REP002** implicit host-sync idiom (``float()``/``bool()``/``.item()``/
  ``np.asarray`` on a computed value) in a hot-path module without an
  explicit ``jax.device_get`` boundary in the same expression.
- **REP003** a ``kernels/<name>/`` package missing the kernel/ops/ref trio,
  or whose ``ops.py`` does not route to the reference off-TPU.
- **REP004** a jitted function that Python-branches on a parameter not
  declared in ``static_argnames``/``static_argnums`` (recompile hazard —
  the branch re-traces on every new value).
- **REP005** ``print``/f-string on a traced value inside a jitted function
  (stale debug output at best, a tracer leak at worst; use
  ``jax.debug.print``).
- **REP007** import of a *retired* module (a deleted compat shim, e.g.
  ``repro.launch.hlo_analysis``) — the table in ``_RETIRED_MODULES`` names
  the replacement, and the rule keeps the dead path from growing back.

**Suppression.** A finding is silenced by an inline justification comment on
the flagged line — ``# REP002-ok: <why this one is intentional>`` — or by an
entry in the checked-in baseline (``tools/repro_lint_baseline.json``), which
freezes *existing* debt without hiding new violations. Baseline entries are
keyed by (rule, file, source-line text), not line numbers, so unrelated
edits don't churn them; the CLI (``tools/repro_lint.py``) fails only on
findings not covered by either mechanism.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Callable, Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Findings, rules, suppression
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation. ``snippet`` (the stripped source line) is part of
    the identity used for baseline matching — stable under line-number churn.
    """

    code: str
    path: str  # posix path relative to the lint root
    line: int  # 1-indexed
    message: str
    snippet: str

    @property
    def fingerprint(self) -> Tuple[str, str, str]:
        return (self.code, self.path, self.snippet)

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.code} {self.message}"


@dataclasses.dataclass(frozen=True)
class Rule:
    code: str
    summary: str
    check: Callable[["FileContext"], Iterator[Finding]]


RULES: Dict[str, Rule] = {}


def _rule(code: str, summary: str):
    def deco(fn):
        RULES[code] = Rule(code, summary, fn)
        return fn

    return deco


_ALLOW_RE = re.compile(r"#\s*(REP\d{3})-ok:\s*\S")


class FileContext:
    """Parsed view of one file handed to every rule."""

    def __init__(self, path: str, text: str):
        self.path = path  # posix, relative to lint root
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text)
        self.parts = tuple(Path(path).parts)
        self._jitted: Optional[List[Tuple[ast.AST, frozenset]]] = None

    def snippet(self, line: int) -> str:
        if 1 <= line <= len(self.lines):
            return self.lines[line - 1].strip()
        return ""

    def allowed(self, code: str, line: int) -> bool:
        """Inline suppression: ``# REPxxx-ok: <reason>`` on the flagged line,
        or alone on the line above when the flagged line has no room. The
        reason is mandatory — a bare marker does not suppress."""
        for src in (self.snippet(line), self.snippet(line - 1)):
            m = _ALLOW_RE.search(src)
            if m and m.group(1) == code:
                return True
        return False

    def finding(self, code: str, node_or_line, message: str) -> Optional[Finding]:
        line = getattr(node_or_line, "lineno", node_or_line)
        if self.allowed(code, line):
            return None
        return Finding(code, self.path, line, message, self.snippet(line))

    # -- shared jit-decoration analysis (REP004 / REP005) -------------------
    def jitted_functions(self) -> List[Tuple[ast.AST, frozenset]]:
        """Function defs decorated with ``jax.jit`` (directly or through
        ``functools.partial(jax.jit, ...)``), paired with the set of
        parameter names declared static."""
        if self._jitted is None:
            self._jitted = []
            for node in ast.walk(self.tree):
                if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    continue
                for deco in node.decorator_list:
                    static = _jit_static_params(deco, node)
                    if static is not None:
                        self._jitted.append((node, static))
                        break
        return self._jitted


def _is_jax_jit(node: ast.AST) -> bool:
    if isinstance(node, ast.Attribute) and node.attr == "jit":
        return True
    return isinstance(node, ast.Name) and node.id == "jit"


def _param_names(fn: ast.AST) -> List[str]:
    a = fn.args
    return [p.arg for p in (*a.posonlyargs, *a.args, *a.kwonlyargs)]


def _jit_static_params(deco: ast.AST, fn: ast.AST) -> Optional[frozenset]:
    """If ``deco`` is a jit decoration, the static parameter names; else
    None. Handles ``@jax.jit`` and ``@[functools.]partial(jax.jit, ...)``."""
    if _is_jax_jit(deco):
        return frozenset()
    if not isinstance(deco, ast.Call):
        return None
    callee = deco.func
    is_partial = (
        isinstance(callee, ast.Name) and callee.id == "partial"
    ) or (isinstance(callee, ast.Attribute) and callee.attr == "partial")
    if is_partial and deco.args and _is_jax_jit(deco.args[0]):
        kwargs = deco.keywords
    elif _is_jax_jit(callee):  # @jax.jit(static_argnames=...)
        kwargs = deco.keywords
    else:
        return None
    static: set = set()
    params = _param_names(fn)
    for kw in kwargs:
        if kw.arg == "static_argnames":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, str):
                    static.add(c.value)
        elif kw.arg == "static_argnums":
            for c in ast.walk(kw.value):
                if isinstance(c, ast.Constant) and isinstance(c.value, int):
                    if 0 <= c.value < len(params):
                        static.add(params[c.value])
    return frozenset(static)


def _names_in(node: ast.AST) -> set:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _contains_device_get(node: ast.AST) -> bool:
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr == "device_get":
            return True
        if isinstance(n, ast.Name) and n.id == "device_get":
            return True
    return False


# ---------------------------------------------------------------------------
# REP001 — raw collectives outside repro/comm
# ---------------------------------------------------------------------------

_COLLECTIVE_NAMES = frozenset(
    {"psum", "psum_scatter", "pmax", "pmin", "pmean", "all_gather",
     "all_to_all", "ppermute", "pshuffle"}
)


def _in_comm_layer(ctx: FileContext) -> bool:
    return "comm" in ctx.parts


@_rule("REP001", "raw jax.lax collective outside the repro/comm Reducer layer")
def _check_rep001(ctx: FileContext) -> Iterator[Finding]:
    if _in_comm_layer(ctx):
        return
    for node in ast.walk(ctx.tree):
        name = None
        if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
            fn = node.func
            if fn.attr in _COLLECTIVE_NAMES:
                root = fn.value
                if (isinstance(root, ast.Name) and root.id == "lax") or (
                    isinstance(root, ast.Attribute) and root.attr == "lax"
                ):
                    name = fn.attr
        elif isinstance(node, ast.ImportFrom) and node.module and (
            node.module == "jax.lax" or node.module.endswith(".lax")
        ):
            hit = [a.name for a in node.names if a.name in _COLLECTIVE_NAMES]
            if hit:
                name = "/".join(hit)
        if name is None:
            continue
        f = ctx.finding(
            "REP001", node,
            f"raw collective `{name}` outside repro/comm — route it through "
            "a comm.Reducer (or comm.base.psum/pmax) so every driver "
            "inherits the encoding and wire-byte accounting",
        )
        if f:
            yield f


# ---------------------------------------------------------------------------
# REP002 — implicit host syncs in hot paths
# ---------------------------------------------------------------------------

_HOT_DIRS = frozenset({"core", "serve", "kernels", "comm"})
_HOT_FILES = frozenset({"dfw.py"})
_NP_ALIASES = frozenset({"np", "numpy", "onp"})


def _in_hot_path(ctx: FileContext) -> bool:
    return bool(_HOT_DIRS & set(ctx.parts[:-1])) or ctx.parts[-1] in _HOT_FILES


def _is_computed(node: ast.AST) -> bool:
    """Anything but a literal/bare name — the shapes float()/bool() host
    pulls hide behind (attribute chains, subscripts, calls, arithmetic)."""
    return not isinstance(node, (ast.Constant, ast.Name))


@_rule("REP002", "implicit device->host sync in a hot path (no device_get boundary)")
def _check_rep002(ctx: FileContext) -> Iterator[Finding]:
    if not _in_hot_path(ctx):
        return
    # A function that performs an explicit jax.device_get established its
    # host boundary: float()/np.asarray on the fetched values afterwards is
    # host-side work, not an implicit sync. Findings are suppressed inside
    # such functions; the rule bites where no explicit boundary exists.
    boundary_fns = [
        fn
        for fn in ast.walk(ctx.tree)
        if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef))
        and _contains_device_get(fn)
    ]

    def inside_boundary(node: ast.AST) -> bool:
        return any(
            fn.lineno <= node.lineno <= max(
                (n.lineno for n in ast.walk(fn) if hasattr(n, "lineno")),
                default=fn.lineno,
            )
            for fn in boundary_fns
        )

    for node in ast.walk(ctx.tree):
        if not isinstance(node, ast.Call):
            continue
        msg = None
        if isinstance(node.func, ast.Name) and node.func.id in ("float", "bool"):
            if len(node.args) == 1 and _is_computed(node.args[0]):
                msg = (
                    f"`{node.func.id}(...)` on a computed value blocks on an "
                    "implicit device->host transfer"
                )
        elif isinstance(node.func, ast.Attribute) and node.func.attr == "item":
            if not node.args:
                msg = "`.item()` blocks on an implicit device->host transfer"
        elif (
            isinstance(node.func, ast.Attribute)
            and node.func.attr == "asarray"
            and isinstance(node.func.value, ast.Name)
            and node.func.value.id in _NP_ALIASES
        ):
            msg = (
                "`np.asarray(...)` on a device value is an implicit "
                "device->host transfer"
            )
        if msg is None or _contains_device_get(node) or inside_boundary(node):
            continue
        f = ctx.finding(
            "REP002", node,
            msg + "; fetch through an explicit jax.device_get boundary (or "
            "justify with `# REP002-ok: ...` if the value is host data)",
        )
        if f:
            yield f


# ---------------------------------------------------------------------------
# REP004 — recompilation hazards at jit boundaries
# ---------------------------------------------------------------------------


@_rule("REP004", "jitted function Python-branches on a non-static parameter")
def _check_rep004(ctx: FileContext) -> Iterator[Finding]:
    for fn, static in ctx.jitted_functions():
        params = set(_param_names(fn)) - static
        if not params:
            continue
        for node in ast.walk(fn):
            if isinstance(node, (ast.If, ast.While)):
                hit = sorted(_names_in(node.test) & params)
                if hit:
                    f = ctx.finding(
                        "REP004", node,
                        f"`{fn.name}` is jitted but branches on parameter(s) "
                        f"{', '.join(hit)} not in static_argnames — every "
                        "new value re-traces (recompile hazard); declare "
                        "them static or branch with lax.cond",
                    )
                    if f:
                        yield f


# ---------------------------------------------------------------------------
# REP005 — print / f-string on traced values inside jit
# ---------------------------------------------------------------------------


@_rule("REP005", "print/f-string on a traced value inside a jitted function")
def _check_rep005(ctx: FileContext) -> Iterator[Finding]:
    for fn, static in ctx.jitted_functions():
        traced = set(_param_names(fn)) - static
        for node in ast.walk(fn):
            if (
                isinstance(node, ast.Call)
                and isinstance(node.func, ast.Name)
                and node.func.id == "print"
            ):
                f = ctx.finding(
                    "REP005", node,
                    f"`print` inside jitted `{fn.name}` runs once at trace "
                    "time, not per call — use jax.debug.print",
                )
                if f:
                    yield f
            elif isinstance(node, ast.JoinedStr):
                hit = sorted(
                    n
                    for v in node.values
                    if isinstance(v, ast.FormattedValue)
                    for n in _names_in(v.value) & traced
                )
                if hit:
                    f = ctx.finding(
                        "REP005", node,
                        f"f-string in jitted `{fn.name}` formats traced "
                        f"parameter(s) {', '.join(hit)} — this stringifies "
                        "the tracer at trace time, not the runtime value",
                    )
                    if f:
                        yield f


# ---------------------------------------------------------------------------
# REP006 — bare print in library code (route through the obs event log)
# ---------------------------------------------------------------------------


def _is_main_guard(test: ast.AST) -> bool:
    """``if __name__ == "__main__":`` (either comparison order)."""
    if not isinstance(test, ast.Compare):
        return False
    sides = [test.left, *test.comparators]
    return any(isinstance(s, ast.Name) and s.id == "__name__" for s in sides)


@_rule("REP006", "bare print( in library code — route output through repro.obs")
def _check_rep006(ctx: FileContext) -> Iterator[Finding]:
    # CLI/tooling surfaces where the terminal IS the interface are exempt:
    # tools/ and examples/ trees wholesale, plus `main()` bodies and
    # `if __name__ == "__main__":` blocks anywhere.
    if "tools" in ctx.parts or "examples" in ctx.parts:
        return
    exempt: List[Tuple[int, int]] = []
    for node in ast.walk(ctx.tree):
        if (
            isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and node.name == "main"
        ):
            exempt.append((node.lineno, node.end_lineno or node.lineno))
    for node in ctx.tree.body:
        if isinstance(node, ast.If) and _is_main_guard(node.test):
            exempt.append((node.lineno, node.end_lineno or node.lineno))
    # A print inside a jitted function is REP005's trace-time finding;
    # flagging it here too would double-report the same line.
    for fn, _ in ctx.jitted_functions():
        exempt.append((fn.lineno, fn.end_lineno or fn.lineno))
    for node in ast.walk(ctx.tree):
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "print"
        ):
            if any(a <= node.lineno <= b for a, b in exempt):
                continue
            f = ctx.finding(
                "REP006", node,
                "bare `print(...)` in library code — record it on the obs "
                "event log (repro.obs.Telemetry.event / registry) so run "
                "output lands in the JSONL/Chrome-trace sinks, or justify "
                "the CLI surface with `# REP006-ok: ...`",
            )
            if f:
                yield f


# Modules that have been deleted after a deprecation window.  Keyed by the
# module basename (the last dotted component) so every import spelling —
# absolute, relative, `from pkg import name` — resolves to the same entry;
# the value is (retired dotted path, replacement dotted path).  Future
# retirements just append a row; REP007 keeps the dead path from growing back.
_RETIRED_MODULES: Dict[str, Tuple[str, str]] = {
    "hlo_analysis": ("repro.launch.hlo_analysis", "repro.analysis.hlo"),
}


@_rule("REP007", "import of a retired module (deleted compat shim)")
def _check_rep007(ctx: FileContext) -> Iterator[Finding]:
    for node in ast.walk(ctx.tree):
        hit: Optional[str] = None
        if isinstance(node, ast.Import):
            for alias in node.names:
                if alias.name.split(".")[-1] in _RETIRED_MODULES:
                    hit = alias.name.split(".")[-1]
        elif isinstance(node, ast.ImportFrom):
            # `from repro.launch.hlo_analysis import analyze` (any relative
            # depth) and `from repro.launch import hlo_analysis` both count;
            # `from repro.analysis import hlo as hlo_analysis` does not —
            # only the real module name matters, not the local alias.
            mod = node.module or ""
            if mod.split(".")[-1] in _RETIRED_MODULES:
                hit = mod.split(".")[-1]
            else:
                for alias in node.names:
                    if alias.name in _RETIRED_MODULES:
                        hit = alias.name
        if hit is None:
            continue
        retired, replacement = _RETIRED_MODULES[hit]
        f = ctx.finding(
            "REP007", node,
            f"`{retired}` was retired — import `{replacement}` instead "
            "(the compat re-export was deleted after its deprecation "
            "window; resurrecting the old path splits the import graph)",
        )
        if f:
            yield f


# ---------------------------------------------------------------------------
# REP003 — kernel package trio (project-level rule)
# ---------------------------------------------------------------------------

_REP003_SUMMARY = "kernels/<name>/ must ship kernel.py + ops.py + ref.py, ops routing to ref off-TPU"


def check_kernel_trios(files: Iterable[Path], root: Path) -> Iterator[Finding]:
    """Group the scanned files by ``.../kernels/<name>/`` package and check
    each ships the kernel/ops/ref trio with ops gating on the backend."""
    by_pkg: Dict[Path, set] = {}
    for f in files:
        parts = f.parts
        if "kernels" in parts[:-1]:
            pkg = f.parent
            if pkg.name != "kernels":  # a kernels/<name>/ package, not the root
                by_pkg.setdefault(pkg, set()).add(f.name)
    for pkg, names in sorted(by_pkg.items()):
        rel = pkg.relative_to(root).as_posix()
        missing = sorted({"kernel.py", "ops.py", "ref.py"} - names)
        if missing:
            yield Finding(
                "REP003", rel, 1,
                f"kernel package is missing {', '.join(missing)} — every "
                "kernel ships the kernel/ops/ref trio so non-TPU backends "
                "and the parity tests always have a reference path",
                pkg.name,
            )
            continue
        ops = (pkg / "ops.py").read_text()
        routes_ref = re.search(r"\bref\s*\.|import\s+ref\b", ops)
        gates = ("use_pallas" in ops) or ("default_backend" in ops)
        if not (routes_ref and gates):
            yield Finding(
                "REP003", f"{rel}/ops.py", 1,
                "ops.py must dispatch to the ref implementation off-TPU "
                "(a `use_pallas`/`default_backend` gate falling back to "
                "`ref.*`) — found no such routing",
                "ops.py",
            )


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------


def lint_file(path: Path, root: Path) -> List[Finding]:
    rel = path.relative_to(root).as_posix()
    try:
        ctx = FileContext(rel, path.read_text())
    except SyntaxError as e:  # surfaced as a finding, not a crash
        return [Finding("REP000", rel, e.lineno or 1, f"syntax error: {e.msg}", "")]
    out: List[Finding] = []
    for rule in RULES.values():
        out.extend(rule.check(ctx))
    return out


def lint_paths(paths: Sequence[Path], root: Optional[Path] = None) -> List[Finding]:
    """Lint every ``.py`` file under ``paths``; findings carry posix paths
    relative to ``root`` (default: the common parent, so fixture trees in
    tests report stable relative paths)."""
    files: List[Path] = []
    for p in paths:
        p = Path(p).resolve()
        if p.is_dir():
            files.extend(
                f for f in sorted(p.rglob("*.py")) if "__pycache__" not in f.parts
            )
        else:
            files.append(p)
    if root is None:
        root = Path(__file__).resolve().parents[3]  # repo root (src/repro/analysis/..)
    root = Path(root).resolve()
    findings: List[Finding] = []
    for f in files:
        findings.extend(lint_file(f, root))
    findings.extend(check_kernel_trios(files, root))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return findings


# ---------------------------------------------------------------------------
# Baseline: freeze existing debt, fail on anything new
# ---------------------------------------------------------------------------

BASELINE_VERSION = 1


def load_baseline(path: Path) -> Dict[Tuple[str, str, str], dict]:
    """Baseline entries keyed by fingerprint. Missing file = empty baseline."""
    if not Path(path).exists():
        return {}
    data = json.loads(Path(path).read_text())
    if data.get("version") != BASELINE_VERSION:
        raise ValueError(
            f"baseline {path}: version {data.get('version')!r} != "
            f"{BASELINE_VERSION} — regenerate with --update-baseline"
        )
    out = {}
    for e in data["entries"]:
        out[(e["code"], e["path"], e["snippet"])] = e
    return out


def diff_baseline(
    findings: Sequence[Finding], baseline: Dict[Tuple[str, str, str], dict]
) -> Tuple[List[Finding], List[dict]]:
    """(new_findings, stale_entries): a finding is *new* when its fingerprint
    exceeds the baselined count; an entry is *stale* when the debt it froze
    no longer exists (prompting a baseline shrink, never a failure)."""
    counts: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        counts[f.fingerprint] = counts.get(f.fingerprint, 0) + 1
    new: List[Finding] = []
    seen: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        seen[f.fingerprint] = seen.get(f.fingerprint, 0) + 1
        budget = baseline.get(f.fingerprint, {}).get("count", 0)
        if seen[f.fingerprint] > budget:
            new.append(f)
    stale = [
        e
        for fp, e in baseline.items()
        if counts.get(fp, 0) < e.get("count", 0)
    ]
    return new, stale


def write_baseline(
    path: Path,
    findings: Sequence[Finding],
    old: Optional[Dict[Tuple[str, str, str], dict]] = None,
) -> None:
    """Freeze the current findings. Justifications (``why``) survive from the
    previous baseline; new entries get an explicit review marker."""
    old = old or {}
    grouped: Dict[Tuple[str, str, str], int] = {}
    for f in findings:
        grouped[f.fingerprint] = grouped.get(f.fingerprint, 0) + 1
    entries = []
    for (code, fpath, snippet), count in sorted(grouped.items()):
        prev = old.get((code, fpath, snippet), {})
        entries.append(
            {
                "code": code,
                "path": fpath,
                "snippet": snippet,
                "count": count,
                "why": prev.get("why", "UNREVIEWED — justify or fix"),
            }
        )
    Path(path).write_text(
        json.dumps({"version": BASELINE_VERSION, "entries": entries}, indent=2)
        + "\n"
    )
