"""Post-SPMD HLO text analysis: FLOPs, dot memory traffic, collective bytes.

(Formerly ``repro.launch.hlo_analysis``; it moved here when the declarative
contract checker ``repro.analysis.contracts`` was built on top of it — the
walker is a correctness tool, not an execution-layer one. The compat
re-export at the old path has been deleted; lint rule ``REP007`` keeps it
from coming back.)

Why not just ``compiled.cost_analysis()``: XLA's HloCostAnalysis visits each
while-loop (lax.scan) body ONCE, so a 48-layer scanned model reports ~1/48 of
its real FLOPs. This walker parses ``compiled.as_text()`` (the partitioned,
per-device module), builds the computation call graph, extracts loop trip
counts from the loop-condition compare constants, and multiplies.

Per-device quantities returned:
  flops            — 2*M*N*K summed over dot ops (+ trivial conv terms)
  dot_bytes        — operand+output bytes of every dot (each matmul streams
                     its tiles through VMEM once; upper bound that ignores
                     fusion, lower bound that ignores spills)
  collective_bytes — wire bytes per device by collective type, with ring
                     factors: all-reduce 2x, all-gather/reduce-scatter 1x
                     (of the large shape), all-to-all & permute 1x
  collective_count — op counts by type (executed, i.e. trip-multiplied)

The walker also parses each collective's ``replica_groups`` (both the
explicit ``{{0,1},{2,3}}`` and the iota ``[G,S]<=[N]`` HLO spellings), which
is what distinguishes a hierarchical topology's cheap intra-group psum from
its expensive inter-group exchange. ``partition_crossing_bytes`` classifies
every collective's wire bytes against a device partition (e.g. hosts):
bytes of collectives whose replica groups stay inside one cell are
``local``, the rest ``crossing`` — the measured quantity behind the
``benchmarks/gossip_consensus.py`` inter-byte gate.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)\(")
_CALLED_RE = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_OPERANDS_RE = re.compile(r"\(\s*(%[\w.\-]+(?:\s*,\s*%[\w.\-]+)*)?\s*\)")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s32\[\]\s+constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(([^)]*)\),?.*direction=(LT|LE|GT|GE)")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_GROUPS_EXPLICIT_RE = re.compile(r"replica_groups=\{(\{[^{}]*\}(?:,\{[^{}]*\})*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]<=\[(\d+)\](\S*)")
_PERMUTE_PAIRS_RE = re.compile(r"source_target_pairs=\{((?:\{\d+,\d+\},?)*)\}")


def parse_replica_groups(line: str) -> Optional[List[List[int]]]:
    """The replica groups of one collective op line, or ``None`` when the op
    carries none (= one group spanning every participant).

    Handles the explicit form ``replica_groups={{0,1,2,3},{4,5,6,7}}`` and
    the iota form ``replica_groups=[G,S]<=[N]`` (G groups of S consecutive
    ids). An iota spelling with a trailing reshape/transpose suffix is not
    decoded — returned as ``None`` rather than guessed wrong. For a
    ``collective-permute`` the ``source_target_pairs`` are returned as
    2-element groups, so crossing classification sees every hop."""
    m = _GROUPS_EXPLICIT_RE.search(line)
    if m:
        groups = []
        for grp in re.findall(r"\{([^{}]*)\}", m.group(1)):
            ids = [int(v) for v in grp.split(",") if v.strip() != ""]
            if ids:
                groups.append(ids)
        return groups or None
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        g, s, n, suffix = int(m.group(1)), int(m.group(2)), int(m.group(3)), m.group(4)
        if suffix or g * s != n:
            return None
        return [[grp * s + j for j in range(s)] for grp in range(g)]
    m = _PERMUTE_PAIRS_RE.search(line)
    if m:
        pairs = re.findall(r"\{(\d+),(\d+)\}", m.group(1))
        return [[int(a), int(b)] for a, b in pairs] or None
    return None


def _groups_key(groups: Optional[List[List[int]]]) -> str:
    if groups is None:
        return "all"
    return ";".join(",".join(str(i) for i in g) for g in groups)


def _shape_bytes(shape_str: str) -> int:
    """Bytes of 'f32[1,2,3]' or a tuple '(f32[..], bf16[..])'."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(shape_str: str) -> List[int]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return []
    return [int(d) for d in m.group(2).split(",") if d]


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    coll_count: Dict[str, float] = dataclasses.field(default_factory=dict)
    # (op_type, shape_str) -> [executed_count, wire_bytes_total]
    coll_detail: Dict[Tuple[str, str], List[float]] = dataclasses.field(
        default_factory=dict
    )
    # (op_type, shape_str, groups_key) -> [executed_count, wire_bytes_total]
    coll_groups: Dict[Tuple[str, str, str], List[float]] = dataclasses.field(
        default_factory=dict
    )

    def add(self, other: "Costs", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.dot_bytes += other.dot_bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        for k, v in other.coll_count.items():
            self.coll_count[k] = self.coll_count.get(k, 0.0) + v * mult
        for k, (c, b) in other.coll_detail.items():
            cur = self.coll_detail.setdefault(k, [0.0, 0.0])
            cur[0] += c * mult
            cur[1] += b * mult
        for k, (c, b) in other.coll_groups.items():
            cur = self.coll_groups.setdefault(k, [0.0, 0.0])
            cur[0] += c * mult
            cur[1] += b * mult


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        self._parse(text)

    def _parse(self, text: str) -> None:
        cur = None
        for line in text.splitlines():
            stripped = line.strip()
            m = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*(\([^)]*\))?\s*->.*\{", stripped)
            if m and not stripped.startswith("%"):
                pass
            header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.*\{\s*$", stripped)
            if header:
                cur = header.group(2)
                self.computations[cur] = []
                if header.group(1):
                    self.entry = cur
                continue
            if stripped == "}":
                cur = None
                continue
            if cur is not None and stripped:
                self.computations[cur].append(stripped)

    # -- trip counts ------------------------------------------------------
    def trip_count(self, cond_comp: str) -> float:
        """Loop bound from the condition computation. XLA often hides the
        compare inside a wrapped fusion, so the robust extraction is: the
        largest scalar s32 constant in the condition body (loop bounds dwarf
        the 0/1 step constants). Falls back to 1."""
        lines = self.computations.get(cond_comp, [])
        best = 0
        for ln in lines:
            m = _CONST_RE.search(ln)
            if m:
                best = max(best, int(m.group(2)))
        return float(best) if best > 0 else 1.0

    # -- cost walk ---------------------------------------------------------
    def _own_and_children(self, comp: str) -> Tuple[Costs, List[Tuple[str, float]]]:
        costs = Costs()
        children: List[Tuple[str, float]] = []
        shapes: Dict[str, str] = {}
        lines = self.computations.get(comp, [])
        # first pass: op -> shape
        for ln in lines:
            m = _OP_RE.match(ln)
            if m:
                shapes[m.group(1)] = m.group(2)
        for ln in lines:
            m = _OP_RE.match(ln)
            if not m:
                continue
            name, shape_str, op = m.groups()
            if op == "dot":
                out_dims = _shape_dims(shape_str)
                out_elems = 1
                for d in out_dims:
                    out_elems *= d
                # contracted size: lhs elements / (out elems sans rhs free)…
                # robust route: lhs shape * rhs shape / out shape gives
                # (contract^2 * batch) — instead read contracting dims:
                k = self._dot_contract_size(ln, shapes)
                costs.flops += 2.0 * out_elems * k
                costs.dot_bytes += _shape_bytes(shape_str) + sum(
                    _shape_bytes(shapes.get(o, "")) for o in self._operands(ln)
                )
            elif op == "convolution":
                # depthwise/small convs in this codebase: bound by output*kernel
                out_elems = 1
                for d in _shape_dims(shape_str):
                    out_elems *= d
                costs.flops += 2.0 * out_elems * 8  # kernel<=4, 2 ops
            elif op in COLLECTIVES:
                nbytes = _shape_bytes(shape_str)
                if op == "all-reduce":
                    wire = 2.0 * nbytes
                elif op == "reduce-scatter":
                    ops_ = self._operands(ln)
                    wire = float(sum(_shape_bytes(shapes.get(o, "")) for o in ops_) or nbytes)
                else:  # all-gather / all-to-all / collective-permute
                    wire = float(nbytes)
                costs.coll_bytes[op] = costs.coll_bytes.get(op, 0.0) + wire
                costs.coll_count[op] = costs.coll_count.get(op, 0.0) + 1.0
                det = costs.coll_detail.setdefault((op, shape_str), [0.0, 0.0])
                det[0] += 1.0
                det[1] += wire
                gkey = _groups_key(parse_replica_groups(ln))
                grp = costs.coll_groups.setdefault(
                    (op, shape_str, gkey), [0.0, 0.0])
                grp[0] += 1.0
                grp[1] += wire
            if op == "while":
                called = _CALLED_RE.findall(ln)
                cond = body = None
                for c in called:
                    if "cond" in c or c.endswith("condition"):
                        cond = cond or c
                for mm in re.finditer(r"(condition|body)=%?([\w.\-]+)", ln):
                    if mm.group(1) == "condition":
                        cond = mm.group(2)
                    else:
                        body = mm.group(2)
                trips = self.trip_count(cond) if cond else 1.0
                if body:
                    children.append((body, trips))
            elif op in ("fusion", "call", "conditional", "reduce", "map",
                        "reduce-window", "scatter", "select-and-scatter", "sort",
                        "custom-call"):
                for c in _CALLED_RE.findall(ln):
                    children.append((c, 1.0))
                mb = _BRANCHES_RE.search(ln)
                if mb:
                    for c in mb.group(1).split(","):
                        children.append((c.strip().lstrip("%"), 1.0))
        return costs, children

    def _operands(self, line: str) -> List[str]:
        """Operand names of an op line: the %refs inside 'op(...)' only
        (never the metadata)."""
        m = _OP_RE.match(line)
        if not m:
            return []
        op = m.group(3)
        idx = line.find(op + "(", m.end(3) - len(op) - 1)
        if idx < 0:
            idx = line.find(op + "(")
        start = idx + len(op) + 1
        end = line.find(")", start)
        if end < 0:
            end = len(line)
        return re.findall(r"%([\w.\-]+)", line[start:end])

    def _dot_contract_size(self, line: str, shapes: Dict[str, str]) -> float:
        mc = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
        ops = self._operands(line)
        if not mc or not ops:
            return 1.0
        lhs_dims = _shape_dims(shapes.get(ops[0], ""))
        k = 1.0
        for d in mc.group(1).split(","):
            if d and int(d) < len(lhs_dims):
                k *= lhs_dims[int(d)]
        return k

    def total_costs(self) -> Costs:
        memo: Dict[str, Costs] = {}

        def walk(comp: str) -> Costs:
            if comp in memo:
                return memo[comp]
            memo[comp] = Costs()  # cycle guard
            own, children = self._own_and_children(comp)
            total = Costs()
            total.add(own)
            for child, mult in children:
                if child in self.computations:
                    total.add(walk(child), mult)
            memo[comp] = total
            return total

        entry = self.entry or max(self.computations, key=lambda c: len(self.computations[c]))
        return walk(entry)


def analyze(hlo_text: str, top_k: int = 12) -> Dict:
    mod = HloModule(hlo_text)
    c = mod.total_costs()
    top = sorted(c.coll_detail.items(), key=lambda kv: -kv[1][1])[:top_k]
    return {
        "flops": c.flops,
        "dot_bytes": c.dot_bytes,
        "collective_bytes": c.coll_bytes,
        "collective_bytes_total": float(sum(c.coll_bytes.values())),
        "collective_count": c.coll_count,
        "top_collectives": [
            {"op": op, "shape": shape, "count": cnt, "wire_bytes": b}
            for (op, shape), (cnt, b) in top
        ],
        "collective_groups": [
            {"op": op, "shape": shape, "groups": gkey,
             "count": cnt, "wire_bytes": b}
            for (op, shape, gkey), (cnt, b) in sorted(
                c.coll_groups.items(), key=lambda kv: -kv[1][1])
        ],
    }


def partition_crossing_bytes(
    hlo_text: str, partition: List[List[int]]
) -> Dict:
    """Classify every collective's wire bytes against a device partition.

    ``partition`` is a list of disjoint device-id cells (e.g. the per-host
    groups ``[[0,1,2,3],[4,5,6,7]]``). A collective whose every replica
    group stays inside one cell is ``local`` — it never touches the
    boundary; everything else (including collectives with no
    ``replica_groups``, which span all participants) is ``crossing`` and
    contributes its full wire bytes. That makes ``crossing`` an upper bound
    on inter-cell traffic — the right *relative* measure for comparing
    topologies compiled at identical sizes, which is how the
    ``gossip_consensus`` benchmark gates the hier inter-byte saving.

    Returns ``{"crossing": bytes, "local": bytes, "crossing_count": n,
    "local_count": n, "by_op": {op: crossing_bytes}}``.
    """
    cell_of: Dict[int, int] = {}
    for ci, cell in enumerate(partition):
        for dev in cell:
            cell_of[int(dev)] = ci
    c = HloModule(hlo_text).total_costs()
    out = {"crossing": 0.0, "local": 0.0,
           "crossing_count": 0.0, "local_count": 0.0}
    by_op: Dict[str, float] = {}
    for (op, _shape, gkey), (cnt, wire) in c.coll_groups.items():
        if gkey == "all":
            local = len(partition) <= 1
        else:
            local = True
            for grp in gkey.split(";"):
                cells = {cell_of.get(int(i), -1) for i in grp.split(",")}
                if len(cells) > 1:
                    local = False
                    break
        if local:
            out["local"] += wire
            out["local_count"] += cnt
        else:
            out["crossing"] += wire
            out["crossing_count"] += cnt
            by_op[op] = by_op.get(op, 0.0) + wire
    out["by_op"] = by_op
    return out
