"""Declarative HLO/dispatch contracts — the executable form of the paper's
cost model.

DFW-Trace's performance claims are statements about *compiled artifacts and
runtime counters*, not about Python: an epoch with K power iterations costs
exactly 2K collective rounds (paper Alg. 2 + the carried-sigma fix), a
``const:K`` run is one scan dispatch, serving never materializes the d x m
matrix, and nothing crosses device->host implicitly. A ``Contract`` states
those bounds once, next to the code that owns them (``core/power_method.
collective_rounds_contract``, ``core/engine.dispatch_contract``,
``serve.ServingEngine.contract``), and the test suites + ``make analyze``
check the *same* declaration — replacing the copy-pasted HLO walks and
stats asserts that used to live in each test file.

Checking has three independent surfaces, used as the clause mix demands:

- ``check_hlo(fn_or_compiled, *args)`` lowers/compiles (or takes an already
  compiled executable / raw HLO text), walks the post-SPMD module via
  ``analysis.hlo``, and asserts the collective-count and forbidden-shape
  clauses against what XLA actually emitted.
- ``check_stats(stats)`` asserts the dispatch/compile/host-sync caps against
  the runtime counters the engine/serving layers maintain.
- ``guard()`` is the transfer-discipline context: inside it, any implicit
  device->host transfer raises (``jax.transfer_guard_device_to_host``).

All violations raise ``ContractViolation`` (an ``AssertionError``) naming
the contract, the clause, and observed-vs-allowed.

``python tools/repro_contracts.py`` (the ``make analyze`` tier 2) verifies
every declared contract at probe scale on 8 fake CPU devices.
"""
from __future__ import annotations

import contextlib
import dataclasses
import re
from typing import Any, Dict, Mapping, Optional, Sequence, Tuple

from . import hlo


class ContractViolation(AssertionError):
    """A compiled artifact or runtime counter broke a declared invariant."""


def _as_hlo_text(target: Any, *args, **kwargs) -> str:
    """HLO text of ``target``: raw text, a compiled executable (anything
    with ``as_text``), or a callable to ``jit(...).lower(*args).compile()``
    (args may be concrete arrays or ``jax.ShapeDtypeStruct``s)."""
    if isinstance(target, str):
        return target
    if hasattr(target, "as_text"):
        return target.as_text()
    if callable(target):
        import jax

        return jax.jit(target).lower(*args, **kwargs).compile().as_text()
    raise TypeError(
        f"cannot extract HLO from {type(target).__name__}; pass HLO text, a "
        "compiled executable, or a callable + example args"
    )


def measure(target: Any, *args, **kwargs) -> Dict:
    """``analysis.hlo.analyze`` of ``target``'s post-SPMD module — the
    measurement half of a contract check, exposed for relational tests
    (e.g. dense-vs-int8 wire-byte ratios) that compare two measurements
    rather than assert one bound."""
    return hlo.analyze(_as_hlo_text(target, *args, **kwargs))


def _shape_pattern(dims: Sequence[int]) -> re.Pattern:
    # f32[40,28]{1,0} / bf16[40,28] — any dtype, optional layout suffix.
    body = ",".join(str(int(d)) for d in dims)
    return re.compile(r"\b\w+\[" + body + r"\]")


@dataclasses.dataclass(frozen=True)
class Contract:
    """One layer's declared cost/discipline invariants.

    HLO clauses (checked by ``check_hlo``):

    - ``collective_counts``: the executed (trip-multiplied) per-type
      collective counts must equal this mapping exactly — e.g. the power
      method's ``{"all-reduce": 2K}``.
    - ``max_collective_rounds``: total executed collectives <= bound (use
      when the mix is flexible but the round budget is not).
    - ``forbid_shapes``: no op in the compiled module may produce a tensor
      of any of these shapes — e.g. ``((d, m), (m, d))`` pins factor-form
      serving to never densify the iterate.

    Counter clauses (checked by ``check_stats`` against the engine/serving
    ``stats`` dicts): ``max_dispatches``, ``max_compilations``,
    ``max_host_syncs``.

    ``no_host_transfers`` is the transfer-guard discipline: run the
    workload under ``with contract.guard():`` and any implicit
    device->host pull raises at the offending line.

    Telemetry clauses (checked by ``check_telemetry`` against a
    ``repro.obs.Telemetry`` handle): ``max_noop_span_us`` caps the
    amortized cost of entering+exiting one ``span()`` on the handle, and
    ``max_events`` caps its recorded event count — together they pin the
    disabled default to "free and silent" (``repro.obs.noop_contract``).
    """

    name: str
    collective_counts: Optional[Mapping[str, float]] = None
    max_collective_rounds: Optional[float] = None
    forbid_shapes: Tuple[Tuple[int, ...], ...] = ()
    max_dispatches: Optional[int] = None
    max_compilations: Optional[int] = None
    max_host_syncs: Optional[int] = None
    no_host_transfers: bool = False
    max_noop_span_us: Optional[float] = None
    max_events: Optional[int] = None

    # ------------------------------------------------------------- helpers
    def _fail(self, clause: str, detail: str):
        raise ContractViolation(f"contract {self.name!r}: {clause}: {detail}")

    # ----------------------------------------------------------------- hlo
    def check_hlo(self, target: Any, *args, **kwargs) -> Dict:
        """Assert the HLO clauses against ``target``'s compiled module;
        returns the ``analysis.hlo.analyze`` dict for further inspection."""
        text = _as_hlo_text(target, *args, **kwargs)
        analysis = hlo.analyze(text)
        counts = analysis["collective_count"]
        if self.collective_counts is not None:
            want = {k: float(v) for k, v in self.collective_counts.items()}
            if counts != want:
                self._fail(
                    "collective_counts",
                    f"compiled module executes {counts or '{}'}, declared {want}",
                )
        if self.max_collective_rounds is not None:
            total = sum(counts.values())
            if total > self.max_collective_rounds:
                self._fail(
                    "max_collective_rounds",
                    f"{total} executed collectives > {self.max_collective_rounds}"
                    f" (by type: {counts})",
                )
        for dims in self.forbid_shapes:
            pat = _shape_pattern(dims)
            for line in text.splitlines():
                stripped = line.strip()
                m = pat.search(stripped)
                # Only op *results* count (lines defining a value); operand
                # mentions repeat the defining op's shape anyway.
                if m and "=" in stripped:
                    self._fail(
                        "forbid_shapes",
                        f"shape {tuple(dims)} materialized by: "
                        f"{stripped[:160]}",
                    )
        return analysis

    # --------------------------------------------------------------- stats
    def check_stats(self, stats: Mapping[str, int]) -> None:
        """Assert the runtime-counter caps against an engine/serving
        ``stats`` dict (only the declared caps are checked)."""
        for key, cap in (
            ("dispatches", self.max_dispatches),
            ("compilations", self.max_compilations),
            ("host_syncs", self.max_host_syncs),
        ):
            if cap is None:
                continue
            if key not in stats:
                self._fail(key, f"stats dict has no {key!r} counter: {dict(stats)}")
            if stats[key] > cap:
                self._fail(key, f"{stats[key]} > declared max {cap} ({dict(stats)})")

    # ----------------------------------------------------------- telemetry
    def check_telemetry(self, telemetry, iters: int = 2000) -> None:
        """Assert the telemetry clauses against a ``repro.obs.Telemetry``
        handle: time ``iters`` empty ``span()`` entries/exits (amortized
        per-span cost vs ``max_noop_span_us``), then cap the handle's
        recorded event count at ``max_events``. An *enabled* handle run
        against the no-op contract fails the event clause — that is the
        point: the inert default must record nothing."""
        import time

        if self.max_noop_span_us is not None:
            t0 = time.perf_counter()
            for _ in range(iters):
                with telemetry.span("contract.noop_probe"):
                    pass
            per_span_us = (time.perf_counter() - t0) * 1e6 / iters
            if per_span_us > self.max_noop_span_us:
                self._fail(
                    "max_noop_span_us",
                    f"{per_span_us:.2f}us per span() > declared "
                    f"{self.max_noop_span_us}us",
                )
        if self.max_events is not None:
            n = telemetry.event_count()
            if n > self.max_events:
                self._fail(
                    "max_events",
                    f"handle recorded {n} events > declared {self.max_events}"
                    f" (enabled={telemetry.enabled})",
                )

    # --------------------------------------------------------------- guard
    def guard(self):
        """Context manager enforcing ``no_host_transfers`` (no-op when the
        contract doesn't declare it)."""
        if not self.no_host_transfers:
            return contextlib.nullcontext()
        import jax

        return jax.transfer_guard_device_to_host("disallow")


# ---------------------------------------------------------------------------
# Declared-contract verification (tier 2 of `make analyze`)
# ---------------------------------------------------------------------------


def verify_declared(verbose: bool = True) -> int:
    """Build and check every layer-declared contract at probe scale.

    Requires >= 8 devices for the collective-round contracts —
    ``tools/repro_contracts.py`` sets ``XLA_FLAGS`` fake-device count
    before jax initializes. Returns a process exit code.
    """
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P

    from ..compat import shard_map_compat
    from ..core import engine, frank_wolfe, power_method, tasks
    from ..serve import ServeConfig, ServingEngine
    from ..core import low_rank

    failures = 0

    def report(contract: Contract, err: Optional[Exception], note: str):
        nonlocal failures
        if err is None:
            if verbose:
                print(f"contract {contract.name}: OK ({note})")
        else:
            failures += 1
            print(f"contract {contract.name}: FAIL\n  {err}")

    # 1. Power method: an epoch's K iterations cost exactly 2K collective
    # rounds (the carried-sigma invariant), on an 8-way data mesh.
    K, n, m = 3, 512, 48
    c = power_method.collective_rounds_contract(K)
    try:
        mesh = jax.make_mesh((8,), ("data",))

        def run(a, v0):
            return power_method.power_iterations(
                lambda v: a @ v, lambda u: a.T @ u, v0, K, axis_name="data"
            )

        wrapped = shard_map_compat(
            run,
            mesh,
            in_specs=(P("data"), P()),
            out_specs=power_method.PowerResult(u=P(), v=P(), sigma=P()),
        )
        a = jax.ShapeDtypeStruct((n, m), jnp.float32)
        v0 = jax.ShapeDtypeStruct((m,), jnp.float32)
        c.check_hlo(wrapped, a, v0)
        report(c, None, f"8-way, K={K}: all-reduce == {2 * K}")
    except Exception as e:  # noqa: BLE001 — every failure must be reported
        report(c, e, "")

    # 1b. Block power method: K block iterations still cost exactly 2K
    # all-reduce rounds — the (k,k) Gram orthogonalization runs on the
    # replicated reduced block, adding zero rounds at any block width.
    Kb, kb = 3, 4
    c = power_method.block_collective_rounds_contract(Kb, kb)
    try:
        mesh = jax.make_mesh((8,), ("data",))

        def run_block(a, v0):
            return power_method.block_power_iterations(
                lambda v: a @ v, lambda u: a.T @ u, v0, Kb, axis_name="data"
            )

        bspec = power_method.BlockPowerResult(
            u=P(), v=P(), sigma=P(), probe=P(), iters=P()
        )
        wrapped = shard_map_compat(
            run_block,
            mesh,
            in_specs=(P("data"), P()),
            out_specs=(bspec, ()),
        )
        a = jax.ShapeDtypeStruct((n, m), jnp.float32)
        v0 = jax.ShapeDtypeStruct((m, kb), jnp.float32)
        c.check_hlo(wrapped, a, v0)
        report(c, None, f"8-way, K={Kb}, k={kb}: all-reduce == {2 * Kb}")
    except Exception as e:  # noqa: BLE001
        report(c, e, "")

    # 2. Engine: a const:K run is one scan dispatch (+ final loss eval),
    # device-resident under the transfer guard.
    c = engine.dispatch_contract()
    try:
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        w = jax.random.normal(kw, (24, 18))
        x = jax.random.normal(kx, (400, 24))
        task = tasks.MultiTaskLeastSquares(d=24, m=18)
        state = task.init_state(x, x @ w)
        with c.guard():
            res = frank_wolfe.fit(
                task, state, mu=1.0, num_epochs=30, key=jax.random.PRNGKey(1),
                step_size="linesearch",
            )
        c.check_stats(res.stats)
        report(c, None, f"30-epoch const:2 stats {res.stats}")
    except Exception as e:  # noqa: BLE001
        report(c, e, "")

    # 2b. Engine dispatch pins hold with the block solver enabled: same
    # segment plan, same dispatch/sync/transfer budget — the block tier
    # changes the per-epoch math, never the execution discipline.
    c = engine.dispatch_contract(name="engine.dispatch[solver=block:4:adapt]")
    try:
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        w = jax.random.normal(kw, (24, 18))
        x = jax.random.normal(kx, (400, 24))
        task = tasks.MultiTaskLeastSquares(d=24, m=18)
        state = task.init_state(x, x @ w)
        with c.guard():
            res = frank_wolfe.fit(
                task, state, mu=1.0, num_epochs=30, key=jax.random.PRNGKey(1),
                step_size="linesearch", solver="block:4:adapt",
            )
        c.check_stats(res.stats)
        report(c, None, f"30-epoch const:2 block:4:adapt stats {res.stats}")
    except Exception as e:  # noqa: BLE001
        report(c, e, "")

    # 3. Serving: no compiled scoring executable materializes the d x m
    # (or m x d) matrix, and dispatch+swap run transfer-guarded.
    d_s, m_s = 48, 36
    eng = ServingEngine(
        d_s, m_s, ServeConfig(max_batch=8, rank_block=8, verify_kernels=False)
    )
    c = eng.contract(max_compilations=1)
    try:
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        it = low_rank.FactoredIterate(
            u=jax.random.normal(ks[0], (5, d_s)),
            s=jax.random.normal(ks[1], (5,)),
            v=jax.random.normal(ks[2], (5, m_s)),
            alpha=jnp.asarray(0.9, jnp.float32),
            count=jnp.asarray(5, jnp.int32),
        )
        with c.guard():
            eng.load(low_rank.pack_live(it))
            pending = eng.score_async(jnp.ones((3, d_s)))
        pending.block()
        eng.check_contract(c)
        report(c, None, f"rank-5 load + dispatch, stats {eng.stats}")
    except Exception as e:  # noqa: BLE001
        report(c, e, "")

    # 4. Observability: the no-op Telemetry default is free (sub-contract
    # per-span overhead) and silent (zero recorded events) — the guarantee
    # that lets every layer accept a handle unconditionally.
    from ..obs import Telemetry, noop_contract

    c = noop_contract()
    try:
        c.check_telemetry(Telemetry.noop())
        report(c, None, "no-op handle: spans free, event stream empty")
    except Exception as e:  # noqa: BLE001
        report(c, e, "")

    if failures:
        print(f"{failures} contract(s) FAILED")
    elif verbose:
        print("all declared contracts OK")
    return 1 if failures else 0
