"""Correctness tooling: repo-specific static lint + declarative contracts.

Two tiers, both wired into ``make analyze`` and CI:

- ``analysis.lint`` — AST rules (``REP001``–``REP007``) encoding the repo's
  structural invariants: collectives only through ``repro.comm``, no
  implicit host syncs in hot paths, kernel packages ship the
  kernel/ops/ref trio, jit boundaries don't recompile per call. CLI:
  ``tools/repro_lint.py`` (baseline-gated — existing debt is frozen in
  ``tools/repro_lint_baseline.json``, new violations fail).
- ``analysis.contracts`` — declarative HLO/dispatch ``Contract``s that the
  engine, power-method, and serving layers declare for themselves and the
  test suites + ``tools/repro_contracts.py`` verify against compiled HLO
  and runtime counters.
- ``analysis.hlo`` — the post-SPMD HLO walker both tiers measure with
  (the retired ``launch/hlo_analysis`` shim is gone; ``REP007`` rejects
  imports of the old path).

See ``docs/ANALYSIS.md`` for the rule catalog and how to add a rule or a
contract.
"""
from . import contracts, hlo, lint

__all__ = ["contracts", "hlo", "lint"]
