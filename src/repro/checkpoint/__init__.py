from . import dfw
from .dfw import (
    RunCheckpointer,
    RunSnapshot,
    read_iterate_packed,
    read_run_extra,
    restore_run,
    run_extra,
)
from .store import MANIFEST_FORMAT, CheckpointStore

__all__ = [
    "CheckpointStore",
    "MANIFEST_FORMAT",
    "RunCheckpointer",
    "RunSnapshot",
    "dfw",
    "read_iterate_packed",
    "read_run_extra",
    "restore_run",
    "run_extra",
]
