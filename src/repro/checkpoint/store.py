"""Sharded checkpointing with async write and elastic restore.

Layout (no external deps; orbax-like but self-contained):
    <dir>/step_<N>/
        manifest.json      — step, tree structure, per-leaf dtype/shape/spec
        <leaf_id>.npy      — full logical array (single-host container) or
        <leaf_id>.shard<i>.npy — per-host shards (addressable slice per host)

Design points mirrored from production systems:
  * restore-with-remesh: the manifest stores LOGICAL shapes; restore places
    each array under any new mesh/sharding (elastic scale up/down).
  * async: `save_async` snapshots device arrays to host (blocking only on
    transfer) then writes on a daemon thread; `wait()` joins before the next
    save so at most one write is in flight.
  * integrity: manifest written last, atomically (tmp+rename) — a crash
    mid-write never yields a manifest pointing at partial data.
"""
from __future__ import annotations

import json
import os
import queue
import threading
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_paths(tree: PyTree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


class CheckpointStore:
    def __init__(self, directory: str | Path):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, *, extra: Optional[Dict] = None) -> Path:
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree: PyTree, *, extra: Optional[Dict] = None) -> None:
        """Snapshot to host memory now; write to disk on a background thread."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(x), tree)  # D2H copy (blocking)

        def _run():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:  # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: PyTree, extra: Dict) -> Path:
        out = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            for f in tmp.iterdir():
                f.unlink()
        tmp.mkdir(parents=True, exist_ok=True)

        leaves, treedef = _flatten(host_tree)
        paths = _leaf_paths(host_tree)
        try:  # namedtuple nodes (e.g. optimizer states) can't proto-serialize
            treedef_hex = treedef.serialize_using_proto().hex()
        except ValueError:
            treedef_hex = None
        manifest = {
            "step": step,
            "extra": extra,
            "treedef": treedef_hex,
            "leaves": [],
        }
        for i, (leaf, pth) in enumerate(zip(leaves, paths)):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {"file": fname, "path": pth, "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if out.exists():
            import shutil

            shutil.rmtree(out)
        tmp.rename(out)
        return out

    # --------------------------------------------------------------- restore
    def latest_step(self) -> Optional[int]:
        steps = sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        *,
        like: Optional[PyTree] = None,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[int, PyTree, Dict]:
        """Restore to (step, tree, extra). ``shardings`` (a pytree of
        NamedSharding, e.g. for a DIFFERENT mesh than at save time) performs
        the elastic re-shard: arrays are placed shard-by-shard."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        src = self.dir / f"step_{step:08d}"
        manifest = json.loads((src / "manifest.json").read_text())
        leaves = [np.load(src / rec["file"]) for rec in manifest["leaves"]]

        if like is not None:
            treedef = jax.tree_util.tree_structure(like)
            if treedef.num_leaves != len(leaves):
                raise ValueError(
                    f"restore target has {treedef.num_leaves} leaves, "
                    f"checkpoint has {len(leaves)}"
                )
        elif manifest["treedef"] is not None:
            from jax.tree_util import PyTreeDef

            treedef = PyTreeDef.deserialize_using_proto(
                jax.tree_util.default_registry, bytes.fromhex(manifest["treedef"])
            )
        else:
            raise ValueError(
                "checkpoint contains namedtuple nodes; pass `like=` to restore"
            )
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
        return manifest["step"], tree, manifest.get("extra", {})
