"""Sharded checkpointing with async write and elastic restore.

Layout (no external deps; orbax-like but self-contained):
    <dir>/step_<N>/
        manifest.json      — step, tree structure, per-leaf dtype/shape/spec
        <leaf_id>.npy      — full logical array (single-host container) or
        <leaf_id>.shard<i>.npy — per-host shards (addressable slice per host)

Design points mirrored from production systems:
  * restore-with-remesh: the manifest stores LOGICAL shapes; restore places
    each array under any new mesh/sharding (elastic scale up/down).
  * async: `save_async` snapshots device arrays to host (blocking only on
    transfer) then writes on a daemon thread; `wait()` joins before the next
    save so at most one write is in flight. A failed background write is
    re-raised by the next `wait()` (or save) with the failing step and path.
  * integrity: the step directory is assembled under a `.tmp_` prefix and
    atomically renamed into place — a crash mid-write never yields a
    `step_*` directory with partial data, and `latest_step`/`restore` only
    ever see complete steps.
  * retention: `keep_last=N` prunes older complete steps after each write
    (on the writer thread), bounding disk for long checkpointed runs.

The engine-facing layer — what goes *in* a DFW-Trace run checkpoint and how
a run resumes from one (bit-exact or onto a different mesh) — lives in
``checkpoint/dfw.py``; this module stays payload-agnostic.
"""
from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from ..obs import Telemetry

PyTree = Any

# Manifest schema version. Bump when the manifest layout changes; restore
# rejects manifests newer than it knows how to read (older ones, written
# before the field existed, read as 0 and stay loadable).
MANIFEST_FORMAT = 1


def _flatten(tree: PyTree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def _leaf_paths(tree: PyTree):
    return [
        "/".join(str(getattr(k, "key", getattr(k, "idx", getattr(k, "name", k)))) for k in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]
    ]


class CheckpointStore:
    def __init__(self, directory: str | Path, *, keep_last: Optional[int] = None,
                 telemetry: Optional[Telemetry] = None):
        if keep_last is not None and keep_last < 1:
            raise ValueError(f"keep_last={keep_last}: must be >= 1 (or None)")
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep_last = keep_last
        # Save/restore/prune spans + write-latency histograms. Writes happen
        # on the daemon thread, so the handle's thread-safe event append is
        # load-bearing here, not a nicety.
        self.telemetry = telemetry if telemetry is not None else Telemetry.noop()
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[Tuple[int, Path, BaseException]] = None
        # Recover from a crash inside _write's overwrite window: an
        # ``.old_step_X`` with no ``step_X`` means the durable copy was
        # renamed aside but its replacement never landed — put it back (the
        # aside copy is known-complete; the staged ``.tmp`` may be torn).
        # With ``step_X`` present the aside is just unreclaimed garbage.
        for old in self.dir.glob(".old_step_*"):
            target = self.dir / old.name[len(".old_"):]
            if old.is_dir() and not target.exists():
                old.rename(target)
            else:
                shutil.rmtree(old, ignore_errors=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: PyTree, *, extra: Optional[Dict] = None) -> Path:
        self.wait()
        with self.telemetry.span("checkpoint.snapshot", "checkpoint", step=step):
            host = jax.tree.map(lambda x: np.asarray(x), tree)
        t0 = self.telemetry.now_us()
        out = self._write(step, host, extra or {})
        self._record_write(step, host, t0)
        self._prune(keep=step)
        return out

    def _record_write(self, step: int, host_tree: PyTree, t0_us: float) -> None:
        """Stamp one completed write: a checkpoint.write span (started at
        ``t0_us``, i.e. when ``_write`` began) plus the latency histogram.
        Runs on whichever thread performed the write."""
        tel = self.telemetry
        if not tel.enabled:
            return
        dur = tel.now_us() - t0_us
        nbytes = sum(
            int(x.nbytes) for x in jax.tree_util.tree_leaves(host_tree)
        )
        tel.complete("checkpoint.write", "checkpoint", t0_us, dur,
                     step=step, bytes=nbytes)
        tel.registry.histogram("checkpoint.write_us").observe(dur)
        tel.registry.counter("checkpoint.saves").inc()
        tel.registry.counter("checkpoint.bytes").inc(nbytes)

    def save_async(self, step: int, tree: PyTree, *, extra: Optional[Dict] = None) -> None:
        """Snapshot to host memory now; write to disk on a background thread.

        A write failure is reported by the *next* ``wait()`` (implicit in the
        next save) — callers on the hot path never block on disk, but must
        call ``wait()`` once after the last save or the final step's failure
        would go unobserved.
        """
        self.wait()
        with self.telemetry.span("checkpoint.snapshot", "checkpoint", step=step):
            host = jax.tree.map(lambda x: np.asarray(x), tree)  # D2H copy (blocking)

        def _run():
            try:
                t0 = self.telemetry.now_us()
                self._write(step, host, extra or {})
                self._record_write(step, host, t0)
                self._prune(keep=step)
            except BaseException as e:  # noqa: BLE001
                self._error = (step, self.dir / f"step_{step:08d}", e)

        self._thread = threading.Thread(target=_run, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        """Join the in-flight write; re-raise its failure with context.

        The original exception rides as ``__cause__``, so tracebacks keep the
        real I/O error while the message pins *which* checkpoint was lost.
        """
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            (step, path, err), self._error = self._error, None
            raise RuntimeError(
                f"async checkpoint write for step {step} failed at {path}: "
                f"{type(err).__name__}: {err}"
            ) from err

    def _prune(self, keep: int) -> None:
        """Drop complete steps older than the ``keep_last`` newest (always
        retaining ``keep``, the step just written)."""
        if self.keep_last is None:
            return
        steps = [s for s in self.steps() if s != keep]
        dropped = steps[: max(0, len(steps) + 1 - self.keep_last)]
        for s in dropped:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)
        if dropped:
            self.telemetry.event("checkpoint.prune", "checkpoint",
                                 steps=dropped, keep=keep)

    def _write(self, step: int, host_tree: PyTree, extra: Dict) -> Path:
        out = self.dir / f"step_{step:08d}"
        tmp = self.dir / f".tmp_step_{step:08d}"
        if tmp.exists():
            for f in tmp.iterdir():
                f.unlink()
        tmp.mkdir(parents=True, exist_ok=True)

        leaves, treedef = _flatten(host_tree)
        paths = _leaf_paths(host_tree)
        try:  # namedtuple nodes (e.g. optimizer states) can't proto-serialize
            treedef_hex = treedef.serialize_using_proto().hex()
        except ValueError:
            treedef_hex = None
        manifest = {
            "format": MANIFEST_FORMAT,
            "step": step,
            "extra": extra,
            "treedef": treedef_hex,
            "leaves": [],
        }
        for i, (leaf, pth) in enumerate(zip(leaves, paths)):
            fname = f"leaf_{i:05d}.npy"
            np.save(tmp / fname, leaf)
            manifest["leaves"].append(
                {"file": fname, "path": pth, "shape": list(leaf.shape), "dtype": str(leaf.dtype)}
            )
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if out.exists():
            # Re-saving an existing step id (a resume from an older step
            # overwriting later history). POSIX can't atomically swap two
            # non-empty directories, so rename the durable step aside and
            # the complete replacement in — two renames, during which the
            # step id is briefly unlisted but both complete copies exist on
            # disk (vs. rmtree-then-rename, which would destroy the durable
            # copy before the replacement lands). ``.old_*``/``.tmp_*``
            # never match the ``step_*`` glob, so readers only ever see
            # complete steps.
            old = self.dir / f".old_step_{step:08d}"
            if old.exists():
                shutil.rmtree(old)
            out.rename(old)
            tmp.rename(out)
            shutil.rmtree(old, ignore_errors=True)
        else:
            tmp.rename(out)
        return out

    # --------------------------------------------------------------- restore
    def steps(self) -> List[int]:
        """Sorted complete steps. ``.tmp_step_*`` directories (a write that
        never reached its atomic rename) are invisible here by construction."""
        return sorted(
            int(p.name.split("_")[1]) for p in self.dir.glob("step_*") if p.is_dir()
        )

    def latest_step(self) -> Optional[int]:
        steps = self.steps()
        return steps[-1] if steps else None

    def discard_after(self, step: int) -> None:
        """Remove complete steps newer than ``step``. A run that resumes
        from an interior step and keeps checkpointing into the same
        directory must drop the abandoned timeline's later steps first —
        otherwise a later default (latest-step) restore would silently
        splice the dead run's tail onto the new run's history."""
        self.wait()
        for s in self.steps():
            if s > step:
                shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    def restore(
        self,
        step: Optional[int] = None,
        *,
        like: Optional[PyTree] = None,
        shardings: Optional[PyTree] = None,
    ) -> Tuple[int, PyTree, Dict]:
        """Restore to (step, tree, extra). ``shardings`` (a pytree of
        NamedSharding, e.g. for a DIFFERENT mesh than at save time) performs
        the elastic re-shard: arrays are placed shard-by-shard."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        src = self.dir / f"step_{step:08d}"
        with self.telemetry.span("checkpoint.restore", "checkpoint", step=step):
            return self._restore(step, src, like=like, shardings=shardings)

    def _restore(self, step, src, *, like, shardings) -> Tuple[int, PyTree, Dict]:
        manifest = json.loads((src / "manifest.json").read_text())
        fmt = manifest.get("format", 0)
        if fmt > MANIFEST_FORMAT:
            raise ValueError(
                f"checkpoint {src} has manifest format {fmt}; this build "
                f"reads <= {MANIFEST_FORMAT} — upgrade to restore it"
            )
        leaves = [np.load(src / rec["file"]) for rec in manifest["leaves"]]

        if like is not None:
            treedef = jax.tree_util.tree_structure(like)
            if treedef.num_leaves != len(leaves):
                raise ValueError(
                    f"restore target has {treedef.num_leaves} leaves, "
                    f"checkpoint has {len(leaves)}"
                )
        elif manifest["treedef"] is not None:
            from jax.tree_util import PyTreeDef

            treedef = PyTreeDef.deserialize_using_proto(
                jax.tree_util.default_registry, bytes.fromhex(manifest["treedef"])
            )
        else:
            raise ValueError(
                "checkpoint contains namedtuple nodes; pass `like=` to restore"
            )
        tree = jax.tree_util.tree_unflatten(treedef, leaves)
        if shardings is not None:
            tree = jax.tree.map(lambda x, s: jax.device_put(x, s), tree, shardings)
        return manifest["step"], tree, manifest.get("extra", {})
