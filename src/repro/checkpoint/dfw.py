"""DFW-Trace run checkpointing: what a run checkpoint *is* and how to resume.

``checkpoint/store.py`` is payload-agnostic (any pytree, async sharded
writes, atomic manifests). This module fixes the payload schema for a
DFW-Trace run and implements the two resume contracts the drivers expose:

* **Bit-exact resume** — same mesh, same comm mode: the restored
  ``EpochCarry`` (task sufficient-information state, factored iterate,
  reducer/error-feedback state, epoch counter ``t``, run PRNG key) plus the
  saved straggler-mask schedule reproduce the uninterrupted trajectory
  bit-for-bit. Everything the epoch scan reads is in the payload; nothing is
  re-derived.
* **Elastic resume** — different worker count: the payload stores LOGICAL
  (global) arrays, so the task state re-shards row-wise onto the new mesh,
  per-worker reducer state is re-initialized (residuals are per-worker and
  cannot follow a repartition), and the mask schedule is re-drawn for the
  new worker count. Exactness is not preserved (summation order changes);
  convergence is.

Payload schema (one checkpoint step = one segment boundary, step id = t)::

    {
      "carry":   EpochCarry(state, iterate_packed, comm_state, t, key[, probe]),
      "history": {"loss","gap","sigma","gamma","k"} arrays of length t,
      "masks":   (num_epochs, nw) straggler weights, or (0, 0) when unused,
    }

``iterate_packed`` is the factored iterate trimmed to its live-rank prefix
(``low_rank.pack_live``): a t-epoch checkpoint stores t factors, not the
full ``max_rank`` capacity — restore re-pads to any capacity bit-exactly
(rows past ``count`` are zeros by construction). The manifest ``extra``
records the run configuration (task/d/m/comm/num_workers/schedule/...) so
``restore_run`` can rebuild structure skeletons and drivers can decide
between the bit-exact and elastic paths.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, Optional, Union

import numpy as np

from ..core import low_rank
from ..core.frank_wolfe import EpochCarry, parse_solver
from .store import CheckpointStore

PyTree = Any

# v2 appends the block solver's warm-start probe leaf to the carry (format 1
# carries no probe — EpochCarry.probe defaults to the zero-leaf ``()``, so
# v1 payloads restore leaf-for-leaf into the current treedef with a cold
# probe). v3 records the run's comm ``topology`` in extra and fixes the
# per-node-iterate convention: a gossip run's checkpoint stores the NODE-0
# slice of the worker-stacked factored iterate (the payload shape is
# therefore identical to a flat run's — v1/v2 readers of the iterate keep
# working). A gossip resume re-broadcasts that slice to every node
# (elastic); the optimization dynamics themselves resume bit-exactly, since
# they read only the task state, which is saved in full. Writers stamp
# PAYLOAD_FORMAT; readers accept READABLE_FORMATS.
PAYLOAD_FORMAT = 3
READABLE_FORMATS = (1, 2, 3)
HISTORY_KEYS = ("loss", "gap", "sigma", "gamma", "k")

# Manifest-extra fields restore_run hard-indexes to rebuild structure
# skeletons; a checkpoint written without them could never be restored, so
# RunCheckpointer refuses to be built without them (fail at save setup, not
# days later at restore).
REQUIRED_EXTRA = ("task", "d", "m", "num_workers", "comm")


def _history_arrays(history: Dict[str, list]) -> Dict[str, np.ndarray]:
    out = {}
    for k in HISTORY_KEYS:
        vals = history.get(k, [])
        dtype = np.int32 if k == "k" else np.float64
        out[k] = np.asarray(vals, dtype)  # REP002-ok: history holds host floats
    return out


def _history_lists(arrays: Dict[str, np.ndarray]) -> Dict[str, list]:
    return {
        k: [int(v) for v in arrays[k]] if k == "k" else [float(v) for v in arrays[k]]
        for k in HISTORY_KEYS
    }


class RunCheckpointer:
    """Engine-facing checkpoint policy: *when* to save and *what* payload.

    The engine calls ``want(boundary_index, last)`` at every segment
    boundary and, when it answers True, hands over the host-fetched carry,
    history-so-far, and mask schedule via ``save_segment`` — which packs the
    iterate to its live prefix and issues one ``CheckpointStore.save_async``
    (the write itself never blocks the next segment's dispatch).

    ``extra`` is the run-configuration record stamped into every manifest;
    drivers fill it via ``run_extra``. ``save_every`` saves every Nth
    boundary (the final/early-stop boundary is always saved).

    ``per_node_iterate=True`` (gossip-topology runs) declares that the
    carry's factored-iterate leaves arrive worker-stacked ``(nw, ...)``;
    ``save_segment`` then stores the node-0 slice, keeping the payload
    shape identical to a flat run's (see the format-3 note above).
    """

    def __init__(
        self,
        store: Union[CheckpointStore, str, Path],
        *,
        save_every: int = 1,
        keep_last: Optional[int] = 2,
        extra: Optional[Dict] = None,
        telemetry=None,
        per_node_iterate: bool = False,
    ):
        if save_every < 1:
            raise ValueError(f"save_every={save_every}: must be >= 1")
        if isinstance(store, (str, Path)):
            store = CheckpointStore(store, keep_last=keep_last,
                                    telemetry=telemetry)
        self.store = store
        self.save_every = save_every
        self.per_node_iterate = per_node_iterate
        self.extra = dict(extra or {})
        missing = [k for k in REQUIRED_EXTRA if k not in self.extra]
        if missing:
            raise ValueError(
                f"RunCheckpointer extra is missing {missing}: restore_run "
                "needs these to rebuild the payload skeleton — build extra "
                "with checkpoint.dfw.run_extra(task, ...)"
            )

    def want(self, boundary_index: int, last: bool) -> bool:
        return last or (boundary_index + 1) % self.save_every == 0

    def save_segment(
        self,
        *,
        t: int,
        carry: EpochCarry,
        history: Dict[str, list],
        masks: Optional[np.ndarray],
        done: bool,
    ) -> None:
        it = carry.iterate
        if self.per_node_iterate:
            # Worker-stacked gossip iterate: store node 0's slice (all nodes
            # agree to consensus tolerance; resume re-broadcasts it).
            it = type(it)(*(leaf[0] for leaf in it))
        payload = {
            "carry": carry._replace(iterate=low_rank.pack_live(it)),
            "history": _history_arrays(history),
            "masks": (
                np.zeros((0, 0), np.float32)
                if masks is None
                # REP002-ok: masks is a host-side numpy schedule, never traced
                else np.asarray(masks, np.float32)
            ),
        }
        extra = {
            **self.extra,
            "payload_format": PAYLOAD_FORMAT,
            "t": int(t),
            "done": bool(done),
        }
        self.store.save_async(int(t), payload, extra=extra)

    def wait(self) -> None:
        self.store.wait()


def run_extra(
    task,
    *,
    num_workers: int,
    comm: str,
    num_epochs: int,
    schedule: str,
    mu: float,
    step_size: str,
    **more,
) -> Dict:
    """The run-configuration record stamped into checkpoint manifests —
    what ``restore_run`` needs to rebuild structure skeletons and what the
    drivers validate before choosing the bit-exact vs elastic path."""
    import jax

    return {
        "task": type(task).__name__,
        "d": int(task.d),
        "m": int(task.m),
        "num_workers": int(num_workers),
        "comm": comm,
        "num_epochs": int(num_epochs),
        "schedule": schedule,
        "mu": float(mu),
        "step_size": step_size,
        "jax_version": jax.__version__,
        **more,
    }


@dataclasses.dataclass
class RunSnapshot:
    """A restored run checkpoint, host-side (numpy leaves).

    ``carry.iterate`` is still live-prefix packed; drivers re-pad to their
    capacity with ``unpack_iterate``. ``t`` is the resume epoch (== number
    of epochs executed == length of every ``history`` list)."""

    t: int
    carry: EpochCarry  # iterate packed; see unpack_iterate
    history: Dict[str, list]
    masks: Optional[np.ndarray]  # (num_epochs, nw) or None
    extra: Dict

    @property
    def done(self) -> bool:
        return bool(self.extra.get("done", False))  # REP002-ok: extra is JSON

    def unpack_iterate(self, max_rank: int) -> low_rank.FactoredIterate:
        return low_rank.unpack_live(self.carry.iterate, max_rank)


def _payload_like(
    state_like: PyTree, comm_state_like: PyTree, probe_like: PyTree = ()
) -> Dict:
    """Structure skeleton matching ``RunCheckpointer.save_segment``'s
    payload. Leaf *values* are ignored by restore; only the treedef counts
    (the carry holds namedtuple nodes, which the store cannot re-serialize
    on its own — see ``CheckpointStore.restore``). ``probe_like`` is a
    dummy leaf when the checkpoint carries a block-solver probe (format 2
    block runs), ``()`` otherwise — format-1 payloads have no probe leaf."""
    z = np.zeros((0,), np.float32)
    return {
        "carry": EpochCarry(
            state=state_like,
            iterate=low_rank.packed_like(),
            comm_state=comm_state_like,
            t=z,
            key=z,
            probe=probe_like,
        ),
        "history": {k: z for k in HISTORY_KEYS},
        "masks": z,
    }


def read_run_extra(
    store: Union[CheckpointStore, str, Path], step: Optional[int] = None
) -> tuple:
    """(step, extra) of a checkpoint without loading its arrays — the cheap
    peek drivers use to build restore skeletons (saved comm spec, worker
    count) before committing to a full restore."""
    if isinstance(store, (str, Path)):
        store = CheckpointStore(store)
    if step is None:
        step = store.latest_step()
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {store.dir}")
    import json

    manifest = json.loads(
        (store.dir / f"step_{step:08d}" / "manifest.json").read_text()
    )
    return manifest["step"], manifest.get("extra", {})


def read_iterate_packed(
    store: Union[CheckpointStore, str, Path], step: Optional[int] = None
) -> tuple:
    """(step, packed_iterate, extra): load ONLY the live-rank-packed factored
    iterate out of a run checkpoint — the serving path's restore.

    A scorer needs the model, not the training run: task sufficient
    information is O(n) (the sharded data residuals), while the packed
    iterate is O(t(d+m)). This reads the manifest, selects exactly the
    ``carry/iterate/*`` leaves by their recorded paths, and never touches
    the task-state or history arrays on disk — so a serving process can
    hot-swap models without holding (or even knowing the structure of) the
    training state. The result is ``low_rank.pack_live`` output verbatim;
    re-pad to any capacity with ``low_rank.unpack_live``.
    """
    if isinstance(store, (str, Path)):
        store = CheckpointStore(store)
    step, extra = read_run_extra(store, step)
    fmt = extra.get("payload_format", -1)
    if fmt not in READABLE_FORMATS:
        raise ValueError(
            f"checkpoint step {step} has payload format {fmt}; this build "
            f"reads {READABLE_FORMATS}"
        )
    import json

    src = store.dir / f"step_{step:08d}"
    manifest = json.loads((src / "manifest.json").read_text())
    prefix = "carry/iterate/"
    leaves = {
        rec["path"][len(prefix):]: np.load(src / rec["file"])
        for rec in manifest["leaves"]
        if rec["path"].startswith(prefix)
    }
    missing = [k for k in low_rank.packed_like() if k not in leaves]
    if missing:
        raise ValueError(
            f"checkpoint step {step} at {src} has no packed iterate leaves "
            f"{missing} (paths {sorted(leaves)}); was it written by "
            "RunCheckpointer.save_segment?"
        )
    return step, leaves, extra


def restore_run(
    store: Union[CheckpointStore, str, Path],
    *,
    state_like: PyTree,
    step: Optional[int] = None,
) -> RunSnapshot:
    """Load a run checkpoint into a host-side ``RunSnapshot``.

    ``state_like`` is any pytree with the *structure* of the saved task
    state (e.g. a freshly built state for the same task) — required because
    task states are namedtuples, whose treedefs the store cannot rebuild
    unaided. The reducer-state skeleton is rebuilt from the manifest's saved
    ``comm`` spec, so a warm restart that *changes* the comm mode still
    restores cleanly (the driver then re-initializes fresh reducer state).
    """
    if isinstance(store, (str, Path)):
        store = CheckpointStore(store)
    step, extra = read_run_extra(store, step)
    fmt = extra.get("payload_format", -1)
    if fmt not in READABLE_FORMATS:
        raise ValueError(
            f"checkpoint step {step} has payload format {fmt}; this build "
            f"reads {READABLE_FORMATS}"
        )
    from ..comm import make_reducer

    reducer = make_reducer(
        extra["comm"], num_workers=max(1, int(extra["num_workers"]))
    )
    # The block solver flattens (d,k)/(m,k) payloads through the reducer, so
    # stateful encodings saved their state at the flattened sizes; v1
    # checkpoints predate the solver field and are always rank1 (k=1).
    sspec = parse_solver(extra.get("solver", "rank1"))
    k_blk = sspec.k if sspec.kind == "block" else 1
    comm_like = reducer.state_spec(
        int(extra["d"]) * k_blk, int(extra["m"]) * k_blk
    )
    probe_like = (
        np.zeros((0,), np.float32)
        if fmt >= 2 and sspec.kind == "block"
        else ()
    )
    like = _payload_like(state_like, comm_like, probe_like)
    step, payload, extra = store.restore(step, like=like)

    carry = payload["carry"]
    masks = payload["masks"]
    return RunSnapshot(
        t=int(extra["t"]),
        carry=carry,
        history=_history_lists(payload["history"]),
        masks=None if masks.size == 0 else masks,
        extra=extra,
    )
