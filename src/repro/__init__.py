"""repro — DFW-TRACE distributed Frank-Wolfe framework + LM architecture zoo.

Subpackages:
    core      — the paper's contribution (distributed FW for trace-norm balls)
    comm      — pluggable power-method collectives (dense / int8 / top-k EF)
    kernels   — Pallas TPU kernels (power matvec, quantize, flash attn, ...)
    models    — 10-arch model zoo (dense/MoE/VLM/audio/hybrid/SSM)
    configs   — exact published configs + smoke variants
    launch    — mesh, sharding rules, train/serve/dryrun drivers
    data      — deterministic sharded data pipeline
    optim     — AdamW, schedules, PowerSGD-style gradient compression
    checkpoint— sharded save/restore with elastic re-mesh
    obs       — zero-sync telemetry: metrics registry, span tracing, sinks
"""
__version__ = "1.0.0"
