"""Factor-form low-rank serving engine.

The traffic-facing consumer of a DFW-Trace iterate. Training keeps the model
as the factor triple ``W = alpha * U^T diag(s) V`` with rank <= T (paper
§2.2); this engine scores requests directly against those factors —
``x @ W`` is ``alpha * ((x @ U^T) * s) @ V`` — so the scoring path is
O(batch * rank * (d + m)) FLOPs and O(rank * (d + m)) memory and the dense
d x m matrix is never materialized (`kernels/factor_matvec` is the fused
Pallas hot path; Yun et al.'s streaming completion serving, arXiv:1107.0789,
is the same never-densify discipline at cluster scale).

Three serving-specific contracts, all about *static shapes*:

* **Padded micro-batches.** Every scoring call is padded to the engine's
  ``max_batch`` rows, so ONE ahead-of-time compiled executable serves every
  batch size 1..max_batch — request traffic never triggers a recompile, and
  latency is flat in the batch fill. Padding rows are zeros; callers get
  exactly their rows back.
* **Live-rank bucket packing.** Models load via ``low_rank.pack_live``: a
  t-epoch iterate ships t factors, padded up to the next ``rank_block``
  multiple (zero ``s`` rows — exact no-ops in the kernel). Per-request
  FLOPs therefore track the model's *actual* rank at rank_block
  granularity, not the training run's ``max_rank`` capacity.
* **Hot-swap without recompiles or drops.** ``load`` stages the new model's
  factors onto device, then atomically republishes the engine's model
  reference. Executables are keyed by rank bucket: a swap inside the same
  bucket reuses the compiled scorer (``stats["compilations"]`` is the pin —
  ahead-of-time compilation means a shape change *raises* rather than
  silently recompiling). In-flight batches hold references to the old
  factor arrays — jax arrays are immutable, so they complete against
  exactly the model they were dispatched with; nothing blocks, nothing is
  dropped.

Scoring never pulls device->host implicitly: ``score_async`` returns a
``PendingScores`` handle whose ``block()`` performs the one explicit
``device_get`` (the same transfer-guard discipline as ``core/engine``,
pinned in ``tests/test_serve.py``).
"""
from __future__ import annotations

import dataclasses
from pathlib import Path
from typing import Any, Dict, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import dfw as ckpt
from ..checkpoint.store import CheckpointStore
from ..core import low_rank
from ..kernels.factor_matvec import ops as fm_ops
from ..obs import MetricsRegistry, Telemetry

ModelSource = Union[
    low_rank.FactoredIterate, Dict[str, Any], CheckpointStore, str, Path
]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Knobs of one serving engine.

    ``max_batch`` is the padded static batch capacity — the one executable
    per rank bucket scores exactly this many rows per dispatch.
    ``rank_block`` is the live-rank bucket granularity: models whose live
    ranks land in the same bucket share an executable, so routine
    checkpoint-to-checkpoint hot-swaps (rank grows by one per epoch) only
    compile when the rank crosses a bucket boundary. ``transpose=False``
    scores ``x @ W`` (requests are d-vectors of features, scores are
    m-vectors over tasks/classes — the ``dfw_head``/MTLS convention);
    ``transpose=True`` scores ``x @ W^T`` (m -> d, the paper's
    ``U (s ⊙ V^T x)`` direction). ``use_pallas``/``interpret`` route the
    fused kernel exactly like ``launch/dfw.DFWConfig``.

    ``telemetry`` (a ``repro.obs.Telemetry``; None = inert no-op) backs the
    engine's ``stats`` counters with the handle's registry and records
    per-dispatch latency histograms plus load/hot-swap/compile events — the
    no-op default's overhead is contract-pinned (<2% p50, measured by
    ``benchmarks/serving_latency.py``).
    """

    max_batch: int = 64
    rank_block: int = 32
    transpose: bool = False
    use_pallas: Optional[bool] = None
    interpret: bool = False
    verify_kernels: bool = True
    block_o: int = 256
    telemetry: Optional[Telemetry] = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch={self.max_batch}: must be >= 1")
        if self.rank_block < 1:
            raise ValueError(f"rank_block={self.rank_block}: must be >= 1")


class Model:
    """One loaded model version: capacity-padded device factors + metadata.

    Immutable by convention (and jax arrays by construction): a swap builds
    a new ``Model``; anything already scoring against this one is safe.
    """

    __slots__ = ("u", "s", "v", "alpha", "live_rank", "capacity", "version", "step")

    def __init__(self, *, u, s, v, alpha, live_rank, capacity, version, step):
        self.u = u  # (capacity, d) device
        self.s = s  # (capacity,) device; rows >= live_rank are 0
        self.v = v  # (capacity, m) device
        self.alpha = alpha  # () device
        self.live_rank = int(live_rank)
        self.capacity = int(capacity)
        self.version = int(version)
        self.step = step  # checkpoint step or None


class PendingScores:
    """A dispatched scoring batch: device-resident until ``block()``.

    ``raw`` is the full (max_batch, n_out) device array; ``block()`` does
    the single explicit device->host transfer and returns the caller's
    ``n`` rows (cached — blocking twice transfers once). ``version``/
    ``step`` stamp which model scored the batch, so hot-swap tests can
    prove in-flight batches completed against the model they were
    dispatched with.
    """

    __slots__ = ("raw", "n", "version", "step", "_host", "_tel", "_t0", "_hist")

    def __init__(self, raw: jax.Array, n: int, version: int, step,
                 telemetry: Optional[Telemetry] = None, t0_us: float = 0.0,
                 latency_hist=None):
        self.raw = raw
        self.n = n
        self.version = version
        self.step = step
        self._host: Optional[np.ndarray] = None
        self._tel = telemetry
        self._t0 = t0_us
        # Pre-bound by the engine: a registry lookup per fetch costs real
        # microseconds on this path (cold caches after an XLA dispatch).
        self._hist = latency_hist

    def block(self) -> np.ndarray:
        if self._host is None:
            self._host = np.asarray(jax.device_get(self.raw))[: self.n]
            # Dispatch->host latency, stamped exactly once per batch on the
            # transfer the caller already pays for (zero added syncs).
            tel = self._tel
            if tel is not None and tel.enabled:
                dur = tel.now_us() - self._t0
                tel.complete("serve.dispatch", "serve", self._t0, dur,
                             n=self.n, version=self.version)
                self._hist.observe(dur)
        return self._host


def rank_bucket(live_rank: int, rank_block: int) -> int:
    """Smallest ``rank_block`` multiple >= max(live_rank, 1): the executable
    capacity serving this live rank. Rank 0 (an untrained iterate) shares
    the first bucket — its ``s`` rows are all zero, so it scores exactly 0
    through the same executable rather than needing a degenerate one."""
    return rank_block * max(1, -(-live_rank // rank_block))


def _as_packed(source: ModelSource, step: Optional[int]):
    """Normalize a model source to (packed_dict, step, extra)."""
    if isinstance(source, low_rank.FactoredIterate):
        return low_rank.pack_live(source), None, {}
    if isinstance(source, dict):
        missing = [k for k in low_rank.packed_like() if k not in source]
        if missing:
            raise ValueError(f"packed iterate dict is missing {missing}")
        return source, None, {}
    if isinstance(source, (CheckpointStore, str, Path)):
        step, packed, extra = ckpt.read_iterate_packed(source, step)
        return packed, step, extra
    raise TypeError(
        f"cannot load a model from {type(source).__name__}; pass a "
        "FactoredIterate, a pack_live dict, or a checkpoint store/directory"
    )


class ServingEngine:
    """Score request batches against a hot-swappable factored model.

    Built for a fixed problem shape ``(d, m)``; every loaded model must
    match it. ``load`` is both first load and hot-swap. ``score`` /
    ``score_async`` accept 1..max_batch requests of dimension ``n_in``
    (= d, or m when ``transpose``) and return ``n_out`` scores per request.

    ``stats`` counters mirror ``core/engine``'s pins: ``compilations``
    (ahead-of-time executable builds — the hot-swap regression pin),
    ``dispatches`` (scoring calls), ``loads`` (models published),
    ``requests`` (caller rows scored, excluding padding). They are backed
    by ``repro.obs`` registry counters (``serve.*``) — on the telemetry
    handle's registry when one is configured, else a private registry —
    and ``stats`` is a read-only snapshot; ``check_contract()``'s pins are
    unchanged by the migration.
    """

    def __init__(self, d: int, m: int, cfg: ServeConfig = ServeConfig()):
        self.d, self.m = int(d), int(m)
        self.cfg = cfg
        self.n_in = self.m if cfg.transpose else self.d
        self.n_out = self.d if cfg.transpose else self.m
        self._model: Optional[Model] = None
        self._compiled: Dict[int, Any] = {}  # rank capacity -> executable
        self._verified = not cfg.verify_kernels
        self.telemetry = (
            cfg.telemetry if cfg.telemetry is not None else Telemetry.noop()
        )
        # A disabled handle's registry is the shared no-op singleton's —
        # counting there would alias every un-instrumented engine in the
        # process onto one set of counters, so each gets its own registry.
        reg = (
            self.telemetry.registry if self.telemetry.enabled
            else MetricsRegistry()
        )
        self._counters = {
            k: reg.counter(f"serve.{k}")
            for k in ("compilations", "dispatches", "loads", "requests")
        }
        self._latency_hist = reg.histogram("serve.latency_us")

    @property
    def stats(self) -> Dict[str, int]:
        """Registry-backed counter snapshot (same keys as before the obs
        migration; see ``check_contract``)."""
        return {k: int(c.value) for k, c in self._counters.items()}

    # ------------------------------------------------------------ compile
    def _scorer(self):
        cfg = self.cfg
        kw = dict(
            use_pallas=cfg.use_pallas, interpret=cfg.interpret,
            block_b=min(128, _ceil_to(cfg.max_batch, 8)), block_o=cfg.block_o,
        )

        def score(u, s, v, alpha, x):
            if cfg.transpose:
                return fm_ops.factor_matvec(x, v, s, u, alpha=alpha, **kw)
            return fm_ops.factor_matvec(x, u, s, v, alpha=alpha, **kw)

        return score

    def _executable(self, capacity: int):
        """The ahead-of-time compiled scorer for one rank bucket. AOT (not
        plain jit) is the no-recompile guarantee: the executable admits
        exactly the (capacity, max_batch) shapes it was built for, and any
        drift raises instead of silently compiling on the request path."""
        if capacity not in self._compiled:
            f32 = jnp.float32
            sd = jax.ShapeDtypeStruct
            args = (
                sd((capacity, self.d), f32),
                sd((capacity,), f32),
                sd((capacity, self.m), f32),
                sd((), f32),
                sd((self.cfg.max_batch, self.n_in), f32),
            )
            t0 = self.telemetry.now_us()
            exe = jax.jit(self._scorer()).lower(*args).compile()
            self._compiled[capacity] = exe
            self._counters["compilations"].inc()
            self.telemetry.complete(
                "serve.compile", "serve", t0, self.telemetry.now_us() - t0,
                capacity=capacity, max_batch=self.cfg.max_batch,
            )
            if self.telemetry.wants_hlo:
                # One HLO walk per executable, mirroring the engine's
                # compile-time comm accounting (never on the request path).
                try:
                    from ..analysis import hlo as hlo_lib

                    info = hlo_lib.analyze(exe.as_text())
                    self.telemetry.event(
                        "serve.executable", "serve", capacity=capacity,
                        hlo_flops=info["flops"],
                        hlo_dot_bytes=info["dot_bytes"],
                    )
                except Exception:  # pragma: no cover - HLO formats drift
                    pass
        return self._compiled[capacity]

    # --------------------------------------------------------------- load
    def load(self, source: ModelSource, *, step: Optional[int] = None) -> Model:
        """Publish a model (first load or hot-swap) from an in-memory
        iterate, a ``pack_live`` dict, or a run-checkpoint directory/store
        (``step=None`` means its latest step).

        The new model's factors are staged to device and its rank bucket's
        executable ensured *before* the engine reference flips, so there is
        no window where scoring sees a half-loaded model; batches already
        dispatched keep their (immutable) old factor arrays.
        """
        t0 = self.telemetry.now_us()
        packed, ck_step, extra = _as_packed(source, step)
        if extra:
            got = (int(extra.get("d", -1)), int(extra.get("m", -1)))
            if got != (self.d, self.m):
                raise ValueError(
                    f"checkpoint model is {got[0]}x{got[1]} but this engine "
                    f"serves {self.d}x{self.m}"
                )
        # `packed` leaves may still live on device (a dict handed over from
        # a training process): one explicit batched device_get is the load
        # path's only transfer — int()/np.asarray on the leaves would each
        # block on an implicit pull (lint rule REP002).
        packed = jax.device_get(packed)
        live = int(packed["count"])
        capacity = rank_bucket(live, self.cfg.rank_block)
        padded = low_rank.unpack_live(packed, capacity)
        if padded.u.shape[1] != self.d or padded.v.shape[1] != self.m:
            raise ValueError(
                f"model factors are {padded.u.shape[1]}x{padded.v.shape[1]} "
                f"but this engine serves {self.d}x{self.m}"
            )
        model = Model(
            u=jnp.asarray(padded.u, jnp.float32),
            s=jnp.asarray(padded.s, jnp.float32),
            v=jnp.asarray(padded.v, jnp.float32),
            alpha=jnp.asarray(packed["alpha"], jnp.float32),
            live_rank=live,
            capacity=capacity,
            version=(self._model.version + 1) if self._model else 0,
            step=ck_step,
        )
        self._verify_once(model)
        self._executable(capacity)  # compile (or reuse) before publishing
        self._model = model
        self._counters["loads"].inc()
        self.telemetry.complete(
            "serve.load", "serve", t0, self.telemetry.now_us() - t0,
            version=model.version, step=model.step, live_rank=live,
            capacity=capacity,
        )
        if model.version > 0:
            self.telemetry.event("serve.hot_swap", "serve",
                                 version=model.version, step=model.step,
                                 live_rank=live, capacity=capacity)
        return model

    @classmethod
    def from_checkpoint(
        cls,
        store: Union[CheckpointStore, str, Path],
        cfg: ServeConfig = ServeConfig(),
        *,
        step: Optional[int] = None,
    ) -> "ServingEngine":
        """Build an engine sized from a run checkpoint's manifest and load
        that checkpoint — the one-call serving bootstrap."""
        _, extra = ckpt.read_run_extra(store, step)
        eng = cls(int(extra["d"]), int(extra["m"]), cfg)
        eng.load(store, step=step)
        return eng

    # -------------------------------------------------------------- score
    @property
    def model(self) -> Model:
        if self._model is None:
            raise RuntimeError("no model loaded; call load() first")
        return self._model

    def score_async(self, x) -> PendingScores:
        """Dispatch one padded scoring batch; returns without blocking.

        ``x`` is (b, n_in) with 1 <= b <= max_batch (or a single (n_in,)
        request). The result handle is pinned to the model version at
        dispatch time — a concurrent ``load`` cannot retarget it.
        """
        model = self.model
        xh = np.asarray(x, np.float32)  # REP002-ok: host request ingress
        if xh.ndim == 1:
            xh = xh[None, :]
        b, n_in = xh.shape
        if n_in != self.n_in:
            raise ValueError(
                f"requests have dim {n_in}; this engine scores "
                f"{'m' if self.cfg.transpose else 'd'}={self.n_in}-vectors"
            )
        if not 1 <= b <= self.cfg.max_batch:
            raise ValueError(
                f"batch of {b} exceeds max_batch={self.cfg.max_batch}; "
                "split it (serve.MicroBatcher does this)"
            )
        pad = np.zeros((self.cfg.max_batch, self.n_in), np.float32)
        pad[:b] = xh
        exe = self._executable(model.capacity)
        t0 = self.telemetry.now_us()
        raw = exe(model.u, model.s, model.v, model.alpha, jnp.asarray(pad))
        self._counters["dispatches"].inc()
        self._counters["requests"].inc(b)
        return PendingScores(raw, b, model.version, model.step,
                             telemetry=self.telemetry, t0_us=t0,
                             latency_hist=self._latency_hist)

    def score(self, x) -> np.ndarray:
        """Blocking convenience: ``score_async(x).block()``."""
        return self.score_async(x).block()

    # ----------------------------------------------------------- contract
    def contract(self, *, max_compilations: Optional[int] = None):
        """The serving layer's declarative invariant (see
        ``repro.analysis.contracts``): no compiled scorer may materialize a
        d x m (or m x d) intermediate — scoring is strictly factored,
        O(t(d+m)) per request — and the request path performs no implicit
        device->host transfer. ``max_compilations`` optionally pins the AOT
        no-recompile guarantee on top."""
        from ..analysis.contracts import Contract

        return Contract(
            name=f"serve.never_materialize[{self.d}x{self.m}]",
            forbid_shapes=((self.d, self.m), (self.m, self.d)),
            max_compilations=max_compilations,
            no_host_transfers=True,
        )

    def check_contract(self, contract=None) -> "Contract":
        """Assert ``contract`` (default: ``self.contract()``) against every
        compiled executable's HLO and the engine's runtime counters. Raises
        ``ContractViolation`` with the offending HLO line on failure."""
        c = contract if contract is not None else self.contract()
        for exe in self._compiled.values():
            c.check_hlo(exe)
        c.check_stats(self.stats)
        return c

    # ------------------------------------------------------------- verify
    def _verify_once(self, model: Model) -> None:
        """First-load startup check (same role as ``launch/dfw.
        verify_kernelized``): the configured kernel path must agree with
        the dense materialized oracle before any traffic is scored."""
        if self._verified:
            return
        verify_factor_kernels(
            jax.random.PRNGKey(0x5E12),
            d=self.d,
            m=self.m,
            use_pallas=self.cfg.use_pallas,
            interpret=self.cfg.interpret,
        )
        self._verified = True


def _ceil_to(n: int, mult: int) -> int:
    return mult * (-(-n // mult))


def verify_factor_kernels(
    key: jax.Array,
    *,
    d: int,
    m: int,
    rank: int = 6,
    batch: int = 4,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    tol: float = 1e-4,
) -> float:
    """Assert the fused factor-matvec path matches the dense materialized
    product on a random triple, in both scoring directions. Returns the max
    relative error observed; raises AssertionError past ``tol``."""
    from ..kernels.factor_matvec import ref as fm_ref

    ks = jax.random.split(key, 5)
    dd, mm = min(d, 96), min(m, 96)  # probe scale: the check is structural
    a = jax.random.normal(ks[0], (rank, dd))
    s = jax.random.normal(ks[1], (rank,))
    b = jax.random.normal(ks[2], (rank, mm))
    kw = dict(use_pallas=use_pallas, interpret=interpret)

    def rel_err(got, want):
        got, want = jnp.asarray(got), jnp.asarray(want)
        return float(jax.device_get(
            jnp.max(jnp.abs(got - want)) / (jnp.max(jnp.abs(want)) + 1e-30)
        ))

    x_d = jax.random.normal(ks[3], (batch, dd))
    x_m = jax.random.normal(ks[4], (batch, mm))
    err = max(
        rel_err(fm_ops.factor_matvec(x_d, a, s, b, **kw),
                fm_ref.dense_matvec(x_d, a, s, b)),
        rel_err(fm_ops.factor_matvec(x_m, b, s, a, **kw),
                fm_ref.dense_matvec(x_m, b, s, a)),
    )
    if err > tol:
        raise AssertionError(
            f"factor_matvec kernels diverge from the dense oracle: rel err "
            f"{err:.3e} > tol {tol:.1e}"
        )
    return err
