"""Request micro-batching for the factor-form serving engine.

Single requests are the worst case for an accelerator scorer — one row of a
padded batch does the same device work as a full one. The ``MicroBatcher``
accumulates individual requests into the engine's padded static batch and
dispatches them as ONE ``score_async`` call, so per-request cost amortizes
toward ``1/max_batch`` of a dispatch while every caller still gets an
individual, independently blockable ``Ticket``.

Dispatch policy is deliberately explicit rather than timer-driven: a batch
flushes when it reaches ``flush_at`` rows (auto) or when the caller says so
(``flush()``, typically at an event-loop tick or queue-empty edge). Tickets
are model-version-stamped at *dispatch* time, which is what makes hot-swap
semantics testable: requests flushed before a swap score against the old
model, requests flushed after score against the new one, and a ticket can
never observe a half-swapped state.
"""
from __future__ import annotations

from typing import List, Optional

import numpy as np

from .engine import PendingScores, ServingEngine


class Ticket:
    """One submitted request's future score row.

    ``result()`` blocks (flushing the owning batcher first if this request
    is still queued — a lone ticket never deadlocks waiting for neighbors
    that may never arrive). ``version``/``step`` identify the model that
    scored it, available once dispatched.
    """

    __slots__ = ("_batcher", "_pending", "_row")

    def __init__(self, batcher: "MicroBatcher"):
        self._batcher = batcher
        self._pending: Optional[PendingScores] = None
        self._row = -1

    def _attach(self, pending: PendingScores, row: int) -> None:
        self._pending = pending
        self._row = row

    @property
    def dispatched(self) -> bool:
        return self._pending is not None

    @property
    def version(self) -> int:
        if self._pending is None:
            raise RuntimeError("ticket not dispatched yet; flush() first")
        return self._pending.version

    @property
    def step(self):
        if self._pending is None:
            raise RuntimeError("ticket not dispatched yet; flush() first")
        return self._pending.step

    def result(self) -> np.ndarray:
        if self._pending is None:
            self._batcher.flush()
        assert self._pending is not None  # flush attaches every queued ticket
        return self._pending.block()[self._row]


class MicroBatcher:
    """Accumulate single requests into padded engine dispatches.

    ``flush_at`` defaults to the engine's ``max_batch`` (maximum
    amortization); set it lower to trade fill for latency. One batcher
    fronts one engine; submissions after a hot-swap simply land in the next
    dispatch against the new model.
    """

    def __init__(self, engine: ServingEngine, *, flush_at: Optional[int] = None):
        self.engine = engine
        self.flush_at = engine.cfg.max_batch if flush_at is None else int(flush_at)
        if not 1 <= self.flush_at <= engine.cfg.max_batch:
            raise ValueError(
                f"flush_at={self.flush_at}: must be in [1, max_batch="
                f"{engine.cfg.max_batch}]"
            )
        self._rows: List[np.ndarray] = []
        self._tickets: List[Ticket] = []

    @property
    def pending_count(self) -> int:
        return len(self._rows)

    def submit(self, x) -> Ticket:
        """Queue one (n_in,) request; auto-flushes at ``flush_at`` rows."""
        row = np.asarray(x, np.float32)  # REP002-ok: host request ingress
        if row.ndim != 1 or row.shape[0] != self.engine.n_in:
            raise ValueError(
                f"submit takes one ({self.engine.n_in},) request; got shape "
                f"{row.shape} (use engine.score for whole batches)"
            )
        ticket = Ticket(self)
        self._rows.append(row)
        self._tickets.append(ticket)
        if len(self._rows) >= self.flush_at:
            self.flush()
        return ticket

    def flush(self) -> Optional[PendingScores]:
        """Dispatch everything queued as one padded batch (no-op if empty)."""
        if not self._rows:
            return None
        pending = self.engine.score_async(np.stack(self._rows))
        for row, ticket in enumerate(self._tickets):
            ticket._attach(pending, row)
        self._rows, self._tickets = [], []
        return pending
