"""Factor-form serving: score requests straight from the factored iterate.

``engine.ServingEngine`` — padded static batches, rank-bucketed AOT
executables, checkpoint hot-swap without recompiles or dropped batches.
``batcher.MicroBatcher`` — accumulate single requests into engine dispatches.
"""
from . import batcher, engine
from .batcher import MicroBatcher, Ticket
from .engine import (
    Model,
    PendingScores,
    ServeConfig,
    ServingEngine,
    rank_bucket,
    verify_factor_kernels,
)

__all__ = [
    "MicroBatcher",
    "Model",
    "PendingScores",
    "ServeConfig",
    "ServingEngine",
    "Ticket",
    "batcher",
    "engine",
    "rank_bucket",
    "verify_factor_kernels",
]
