"""Compat re-export: the HLO walker moved to ``repro.analysis.hlo``.

The walker started life here as a launch-layer tool (dry-run rooflines),
but it is really the *measurement* half of the repo's correctness tooling —
``repro.analysis.contracts`` builds the declarative HLO/dispatch contract
checker on top of it. Import from ``repro.analysis.hlo`` going forward.
"""
from ..analysis.hlo import (  # noqa: F401  (re-export shim)
    COLLECTIVES,
    Costs,
    HloModule,
    analyze,
)

__all__ = ["COLLECTIVES", "Costs", "HloModule", "analyze"]
