"""Training driver: data -> jitted train_step -> async checkpoints -> restart.

Structured for the 1000+-node regime:
  * restart-safe: the data stream is a pure function of the step counter, the
    checkpoint manifest carries step + RNG, so kill -9 at any point resumes
    bit-identically.
  * elastic: `--mesh` may differ between runs; restore re-shards (ZeRO-style
    resharding handled by CheckpointStore.restore(shardings=...)).
  * async checkpointing: the train loop never blocks on disk.

On this CPU container it drives smoke-scale configs end-to-end (see
examples/train_e2e.py for the ~100M-param run).
"""
from __future__ import annotations

import argparse
import time
from typing import Optional, Tuple

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import CheckpointStore
from repro.configs import get_config
from repro.data import SyntheticLMStream
from repro.models import lm
from repro.models.config import ModelConfig, ShapeSpec
from repro.optim import adamw

from .mesh import make_mesh
from .params import param_pspecs
from .sharding import use_mesh
from .steps import batch_pspecs, make_train_step


def build(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh=None,
    *,
    peak_lr: float = 3e-4,
    seed: int = 0,
):
    """Returns (init_fn, step_fn, shardings) under the (optional) mesh."""
    with use_mesh(mesh):
        step = make_train_step(cfg, peak_lr=peak_lr)
        if mesh is None:
            return (
                lambda: (lm.init_params(cfg, jax.random.PRNGKey(seed)),),
                jax.jit(step),
                None,
            )
        aparams = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
        pspecs = param_pspecs(aparams)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        bsh = {k: NamedSharding(mesh, s) for k, s in batch_pspecs(cfg, shape).items()}
        init = jax.jit(
            lambda k: lm.init_params(cfg, k), out_shardings=psh
        )
        jstep = jax.jit(step, donate_argnums=(0, 1))
        return (lambda: (init(jax.random.PRNGKey(seed)),), jstep, {"params": psh, "batch": bsh})


def train(
    *,
    arch: str,
    steps: int,
    smoke: bool = True,
    seq_len: int = 128,
    global_batch: int = 8,
    mesh_shape: Optional[Tuple[int, int]] = None,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 50,
    peak_lr: float = 3e-4,
    log_every: int = 10,
    resume: bool = True,
):
    cfg = get_config(arch, smoke=smoke)
    shape = ShapeSpec("train_custom", "train", seq_len, global_batch)
    mesh = make_mesh(mesh_shape, ("data", "model")) if mesh_shape else None
    init_fn, step_fn, shardings = build(cfg, shape, mesh, peak_lr=peak_lr)
    stream = SyntheticLMStream(cfg, shape)
    store = CheckpointStore(ckpt_dir) if ckpt_dir else None

    start = 0
    with use_mesh(mesh):
        if store is not None and resume and store.latest_step() is not None:
            aparams = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
            like = {"params": aparams, "opt": jax.eval_shape(adamw.init, aparams)}
            sh = None
            if shardings is not None:
                sh = {"params": shardings["params"],
                      "opt": adamw.AdamWState(
                          step=NamedSharding(mesh, P()),
                          m=shardings["params"], v=shardings["params"])}
            start, state, extra = store.restore(like=like, shardings=sh)
            params, opt = state["params"], state["opt"]
            print(f"[train] resumed from step {start}")
        else:
            (params,) = init_fn()
            opt = adamw.init(params)

        history = []
        t0 = time.time()
        for step in range(start, steps):
            batch = stream.batch_for_step(step)
            if shardings is not None:
                batch = {k: jax.device_put(v, shardings["batch"][k]) for k, v in batch.items()}
            params, opt, metrics = step_fn(params, opt, batch)
            if (step + 1) % log_every == 0 or step == start:
                loss = float(metrics["loss"])
                print(f"[train] step={step+1:5d} loss={loss:.4f} "
                      f"({(time.time()-t0)/max(step-start+1,1)*1e3:.0f} ms/step)")
                history.append((step + 1, loss))
            if store is not None and (step + 1) % ckpt_every == 0:
                store.save_async(step + 1, {"params": params, "opt": opt})
        if store is not None:
            store.save(steps, {"params": params, "opt": opt})
    return params, opt, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--full", action="store_true", help="full (non-smoke) config")
    ap.add_argument("--mesh", default=None, help="e.g. 2x4 (data x model)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()
    mesh_shape = tuple(int(x) for x in args.mesh.split("x")) if args.mesh else None
    train(
        arch=args.arch, steps=args.steps, smoke=not args.full,
        seq_len=args.seq_len, global_batch=args.global_batch,
        mesh_shape=mesh_shape, ckpt_dir=args.ckpt_dir, peak_lr=args.lr,
    )


if __name__ == "__main__":
    main()
