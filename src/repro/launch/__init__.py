"""Execution layer: meshes, sharding rules, and the distributed DFW driver.

``dfw`` (the distributed DFW-Trace driver) is imported lazily via
``__getattr__`` so that ``import repro.launch`` stays light for users who
only need the sharding rules.
"""
from . import sharding

__all__ = ["dfw", "mesh", "sharding"]


def __getattr__(name):
    if name in ("dfw", "mesh"):
        import importlib

        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
