"""Distributed DFW-Trace execution layer (paper Algorithm 2, end to end).

``core/frank_wolfe.py`` builds the *math* of one FW epoch and
``core/engine.py`` the device-resident execution engine (scan-compiled K(t)
segments, unified ``EpochCarry``, gap-based early stop); this module builds
the *machine* around them:

- a 1-D data mesh over the available devices (``launch/mesh.py``),
- row-wise sharding of the task state across workers (each worker owns a
  contiguous shard of the sample axis, exactly the paper's data partition),
- the BSP master realized as ``psum`` inside ``shard_map`` — per epoch only
  the O(d+m) power-iteration vectors cross the network, never a d x m
  gradient (paper Table 1),
- the paper's straggler/sampled-worker mode: a per-epoch Bernoulli schedule
  over workers precomputed as a (num_epochs, nw) weight array, indexed
  inside the engine's scan,
- kernelized matvecs: the power-iteration hot path is routed through the
  ``kernels/power_matvec`` Pallas ops (dense-state tasks) or
  ``kernels/mc_matvec`` (observed-entry completion gradient) — one HBM pass
  per call on TPU, jnp reference fallback elsewhere — with an up-front
  correctness check against the task's pure-jnp operator chain,
- matrix-completion data layout: ``shard_observations`` partitions the
  observed entries into row-block worker shards padded to equal sizes with
  zero-weight no-op entries (static shapes under shard_map).

The serial driver (``frank_wolfe.fit``) and this sharded driver execute the
same engine; they differ only in the ``segment_wrapper`` layer (shard_map
over the data mesh), so their loss/gap trajectories agree to
float-summation-order tolerance. A ``const:K`` run is a single jit dispatch
with O(1) device->host transfers; ``gap_tol`` stops runs on the duality-gap
certificate at segment granularity.

Typical use (8 simulated hosts; see ``examples/distributed_dfw.py``)::

    from repro.launch import dfw
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=20, schedule="log",
                        step_size="linesearch", sample_prob=0.8,
                        gap_tol=1e-3)
    res = dfw.fit(task, x, y, cfg=cfg, key=key, num_workers=8)
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .. import comm as comm_lib, specs
from ..checkpoint import dfw as ckpt
from ..compat import shard_map_compat
from ..core import engine, frank_wolfe, low_rank, tasks
from ..core.frank_wolfe import EpochAux
from ..obs import Telemetry
from ..core.power_method import sphere_vector
from ..kernels.mc_matvec import ops as mc_ops
from ..kernels.power_matvec import ops as pm_ops
from . import mesh as mesh_lib

PyTree = Any


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DFWConfig:
    """Knobs of one distributed DFW-Trace run.

    ``sample_prob`` < 1 enables the paper's sampled-worker/straggler mode:
    each epoch, every worker participates independently with this probability
    (at least one worker is always kept). ``reweight`` scales the survivors
    by num_workers/num_alive so psum'd aggregates (loss, gap, line-search
    terms) remain estimates of the full-data quantities.

    ``solver`` selects the LMO tier (``frank_wolfe.parse_solver`` grammar):
    ``"rank1"`` is the paper's single-atom power method;
    ``"block:K[:adapt][:cold]"`` the linear-convergence BlockFW tier — a
    rank-K block power iteration appending K atoms per epoch, warm-started
    from the previous epoch's converged right block (``:cold`` disables the
    warm start for ablations, ``:adapt`` stops power iterations early once
    they no longer move the gap certificate). ``max_rank`` then defaults to
    ``num_epochs * K``.

    ``comm`` selects the collective encoding for the power method's vector
    exchanges (``repro.comm``): "dense" (exact f32 psum), "int8"
    (stochastic-rounding s8 psum, ~4x fewer wire bytes), or "topk:r" (top-r
    sparsification with per-worker error feedback). Scalar aggregates stay
    exact under every setting. Applies to all three tasks — the reducer
    wraps the psum, not the task.

    ``topology`` selects the *graph* those exchanges flow over
    (``repro.comm.make_topology`` grammar): "flat" (one global all-reduce
    domain — bit-exact legacy behavior), "ring"/"gossip:k" (master-less
    neighbor averaging; every worker evolves its own iterate and the
    recorded gap is the pmax over the per-node certificates, so early stop
    fires only when all nodes are within ``gap_tol``; requires
    ``comm="dense"`` and ``solver="rank1"``), or "hier:g" (two-level
    reduce: exact psum inside each of g groups, ``comm``-encoded exchange
    across groups — bit-exact vs flat under "dense", and the composition
    point for int8/topk at scale). ``gossip_rounds`` overrides the number
    of mixing rounds per exchange (default: auto-sized from the gossip
    matrix's spectral gap to hit ~1% consensus error). The two axes are
    orthogonal; ``repro.specs.validate`` rejects the meaningless corners.

    ``gap_tol`` stops the run once the psum'd duality-gap certificate
    satisfies ``gap <= gap_tol`` (checked on device every epoch, acted on at
    segment granularity — see ``core/engine.py``); the result records
    ``epochs_run`` and truncates histories to it. Under a compressed
    ``comm`` the certificate inherits the sigma estimate's noise, so treat
    the stop as approximate there. ``block_epochs`` caps the scan segment
    length, bounding both the early-stop overshoot and the staleness of a
    progress ``callback``. ``engine`` selects the execution mode: "scan"
    (production: one dispatch per K(t) segment) or "legacy" (per-epoch
    dispatch + blocking scalar pulls; the overhead baseline).

    **Fault tolerance.** ``checkpoint_dir`` makes the run durable: at every
    ``checkpoint_every``-th segment boundary the full run carry (task
    state, factored iterate, per-worker comm state, epoch counter, PRNG
    key) plus history and the straggler-mask schedule are written
    asynchronously (``repro.checkpoint``), keeping the newest
    ``checkpoint_keep`` steps. The run *owns* the directory's timeline: a
    fresh run clears any previous run's steps, and a resume drops steps
    past its resume point, so the latest step is always this run's.
    ``resume_from`` (a checkpoint directory; ``resume_step`` picks an
    exact step, default latest) restarts a run from its last durable
    boundary:

    - **bit-exact** when the worker count and ``comm`` mode are unchanged —
      the resumed trajectory equals the uninterrupted one bit for bit;
    - **elastic** when the worker count differs — the row-blocked task
      state is re-sharded onto the new mesh, per-worker comm state is
      re-initialized, the mask schedule is re-drawn, and the run converges
      to the same solution (within float-summation-order noise);
    - **warm restart**: ``gap_tol``, ``schedule``, ``num_epochs``, and
      ``comm`` may all differ from the checkpointed run's — the new values
      apply from the resume point (a changed ``comm`` re-initializes
      reducer state, costing exactness but not correctness).

    Note ``block_epochs`` bounds the work a crash can lose: an unbroken
    ``const:K`` run is a single segment and only checkpoints at its end.

    **Telemetry.** ``telemetry`` (a ``repro.obs.Telemetry``; None = inert
    no-op) turns on the zero-sync observability spine: engine segment/
    dispatch spans, per-epoch loss/gap/sigma/gamma samples riding the
    existing boundary fetches, analytic + HLO comm byte accounting,
    checkpoint save/prune spans, and — when the handle's ``profiler_dir``
    is set — a ``jax.profiler`` XLA capture bracketing the epoch loop.
    Export with ``telemetry.write_jsonl(...)`` /
    ``telemetry.write_chrome_trace(...)`` after the run
    (docs/OBSERVABILITY.md).
    """

    mu: float
    num_epochs: int
    schedule: str = "const:2"  # K(t); see frank_wolfe.k_schedule
    step_size: str = "default"  # "default" (2/(t+2)) or "linesearch"
    solver: str = "rank1"  # LMO tier; see frank_wolfe.parse_solver
    comm: str = "dense"  # power-method collective encoding; see repro.comm
    topology: str = "flat"  # exchange graph; see repro.comm.make_topology
    gossip_rounds: Optional[int] = None  # mixing rounds/exchange (None = auto)
    data_axis: str = "data"
    sample_prob: float = 1.0
    reweight: bool = True
    kernelize: bool = True  # route matvecs through kernels/power_matvec
    use_pallas: Optional[bool] = None  # None = auto (Pallas on TPU, jnp ref else)
    interpret: bool = False  # Pallas interpreter mode (debugging)
    verify_kernels: bool = True  # up-front kernel-vs-jnp agreement check
    max_rank: Optional[int] = None  # factored-iterate capacity (default epochs)
    gap_tol: Optional[float] = None  # duality-gap early-stop threshold
    block_epochs: Optional[int] = None  # max epochs per scan segment
    engine: str = "scan"  # "scan" (device-resident) or "legacy" (per-epoch)
    checkpoint_dir: Optional[str] = None  # enable segment-boundary checkpoints
    checkpoint_every: int = 1  # save every Nth segment boundary
    checkpoint_keep: Optional[int] = 2  # retained steps (None = all)
    resume_from: Optional[str] = None  # checkpoint dir to restore from
    resume_step: Optional[int] = None  # exact step (default: latest)
    telemetry: Optional[Any] = None  # repro.obs.Telemetry (None = no-op)


@dataclasses.dataclass
class DFWFitResult:
    iterate: low_rank.FactoredIterate
    state: PyTree
    history: Dict[str, list]  # loss/gap/sigma/gamma/k per epoch (pre-update)
    masks: Optional[jax.Array]  # (epochs_run, num_workers) worker weights
    final_loss: float = float("nan")  # F at the returned iterate (full data)
    epochs_run: int = 0  # < num_epochs when gap_tol stopped the run
    stats: Dict[str, int] = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Mesh + row-wise state sharding
# ---------------------------------------------------------------------------


def data_mesh(num_workers: int, axis: str = "data") -> Mesh:
    """1-D mesh over the first ``num_workers`` devices (the paper's workers)."""
    if num_workers > len(jax.devices()):
        raise ValueError(
            f"num_workers={num_workers} > visible devices={len(jax.devices())}; "
            "on CPU set XLA_FLAGS=--xla_force_host_platform_device_count=N "
            "before the first jax import"
        )
    return mesh_lib.make_mesh((num_workers,), (axis,))


def row_specs(tree: PyTree, axis: str) -> PyTree:
    """PartitionSpec pytree sharding every leaf's leading (sample) dim."""
    return jax.tree.map(lambda _: P(axis), tree)


def replicated_specs(tree: PyTree) -> PyTree:
    return jax.tree.map(lambda _: P(), tree)


def shard_rowwise(mesh: Mesh, tree: PyTree, axis: str = "data") -> PyTree:
    """Place every leaf row-sharded over ``axis``; leading dims must divide."""
    nw = mesh.shape[axis]

    def place(x):
        x = jnp.asarray(x)
        if x.shape[0] % nw:
            raise ValueError(
                f"leading dim {x.shape[0]} not divisible by {nw} workers; "
                "pad or trim the sample axis before sharding"
            )
        return jax.device_put(x, NamedSharding(mesh, P(axis)))

    return jax.tree.map(place, tree)


def shard_observations(
    rows,
    cols,
    vals,
    num_workers: int,
    d: int,
    *,
    m: Optional[int] = None,
    weight=None,
):
    """Partition matrix-completion observations into row-block worker shards.

    Worker j owns the contiguous row block ``[j*ceil(d/nw), (j+1)*ceil(d/nw))``
    (the paper's data partition along the sample axis); each observed entry is
    routed to its row's owner. Shard sizes differ, and shard_map needs static
    equal shapes, so every shard is padded to the largest one with
    **zero-weight** entries at coordinate (0, 0) — exact no-ops in every
    reduction (``tasks.MCState`` pre-masks the residual).

    Returns ``(idx, yw)`` as produced by ``tasks.pack_observations``, laid out
    so ``shard_rowwise``'s contiguous split hands worker j exactly its block.
    Pass ``m`` to also range-check the column indices (recommended — the
    downstream gather/segment chains clip silently). Runs on host (numpy):
    this is one-time data layout, not epoch work.
    """
    import numpy as np

    # Explicit boundary (no-op on numpy inputs): a caller handing device
    # arrays gets one batched fetch, not four implicit pulls (REP002).
    rows, cols, vals, weight = jax.device_get((rows, cols, vals, weight))
    rows_np = np.asarray(rows, np.int64)
    cols_np = np.asarray(cols, np.int64)
    vals_np = np.asarray(vals, np.float32)
    w_np = (
        np.ones_like(vals_np)
        if weight is None
        else np.asarray(weight, np.float32)
    )
    if not (rows_np.shape == cols_np.shape == vals_np.shape == w_np.shape):
        raise ValueError("rows/cols/vals/weight must have identical shapes")
    if rows_np.size and (rows_np.min() < 0 or rows_np.max() >= d):
        raise ValueError(f"row indices must lie in [0, {d})")
    # Out-of-range columns would be silently clipped/dropped by the gather/
    # segment chains downstream — reject them here while shapes are concrete.
    if cols_np.size and cols_np.min() < 0:
        raise ValueError("column indices must be nonnegative")
    if m is not None and cols_np.size and cols_np.max() >= m:
        raise ValueError(f"column indices must lie in [0, {m})")

    block = -(-d // num_workers)  # ceil: worker j owns rows [j*block, (j+1)*block)
    owner = np.minimum(rows_np // block, num_workers - 1)
    sizes = np.bincount(owner, minlength=num_workers)
    p_max = max(int(sizes.max(initial=0)), 1)

    order = np.argsort(owner, kind="stable")
    owner_sorted = owner[order]
    starts = np.concatenate([[0], np.cumsum(sizes)[:-1]])
    slot = owner_sorted * p_max + (np.arange(order.size) - starts[owner_sorted])

    idx = np.zeros((num_workers * p_max, 2), np.int32)
    yw = np.zeros((num_workers * p_max, 2), np.float32)  # weight-0 padding
    idx[slot, 0] = rows_np[order]
    idx[slot, 1] = cols_np[order]
    yw[slot, 0] = vals_np[order]
    yw[slot, 1] = w_np[order]
    return jnp.asarray(idx), jnp.asarray(yw)


# ---------------------------------------------------------------------------
# Kernelized tasks — power_matvec Pallas ops on the power-iteration hot path
# ---------------------------------------------------------------------------


class KernelizedTask:
    """Delegating task wrapper that routes the streaming matvecs of the
    power iteration through the Pallas kernels (paper Alg. 2 lines 5-10, the
    per-epoch hot path): ``kernels/power_matvec`` for the dense-state tasks,
    ``kernels/mc_matvec`` for the observed-entry (COO) completion gradient.

    On TPU each call is a single-HBM-pass blocked Pallas kernel; elsewhere the
    ops dispatch to the pure-jnp reference (``power_matvec/ref.py``), so the
    wrapper is a no-op semantically everywhere. Everything except
    matvec/rmatvec is delegated to the base task untouched.
    """

    def __init__(
        self,
        base,
        *,
        use_pallas: Optional[bool] = None,
        interpret: bool = False,
    ):
        self._base = base
        self._kw = dict(use_pallas=use_pallas, interpret=interpret)

    def __getattr__(self, name):
        return getattr(self._base, name)

    # -- implicit-gradient operator, kernel-routed per state type ----------
    # (MTLSDenseState is not handled: the dense task lacks the epoch
    # interface — local_loss/inner_w_grad — so the drivers here can't run it.)
    def matvec(self, s, v: jax.Array) -> jax.Array:
        if isinstance(s, tasks.MTLSState):  # A = X^T R
            return pm_ops.rmatvec(s.x, pm_ops.matvec(s.r, v, **self._kw), **self._kw)
        if isinstance(s, tasks.LogisticState):  # A = X^T (P - H)
            pv = self._base._probs(s) @ v - v[s.y]
            return pm_ops.rmatvec(s.x, pv, **self._kw)
        if isinstance(s, tasks.MCState):  # A = P_Omega(W - M), COO values resid
            return mc_ops.matvec(s.rows, s.cols, s.resid, v, self._base.d, **self._kw)
        return self._base.matvec(s, v)

    def rmatvec(self, s, u: jax.Array) -> jax.Array:
        if isinstance(s, tasks.MTLSState):
            return pm_ops.rmatvec(s.r, pm_ops.matvec(s.x, u, **self._kw), **self._kw)
        if isinstance(s, tasks.LogisticState):
            t = pm_ops.matvec(s.x, u, **self._kw)
            p = self._base._probs(s)
            return p.T @ t - jnp.zeros((self._base.m,), t.dtype).at[s.y].add(t)
        if isinstance(s, tasks.MCState):
            return mc_ops.rmatvec(s.rows, s.cols, s.resid, u, self._base.m, **self._kw)
        return self._base.rmatvec(s, u)


def kernelize(task, *, use_pallas: Optional[bool] = None, interpret: bool = False):
    """Wrap ``task`` so its power-iteration matvecs run through the Pallas ops."""
    if isinstance(task, KernelizedTask):
        return task
    return KernelizedTask(task, use_pallas=use_pallas, interpret=interpret)


def verify_kernelized(
    task,
    ktask: KernelizedTask,
    state: PyTree,
    key: jax.Array,
    *,
    tol: float = 1e-4,
) -> float:
    """Assert kernel-routed matvec/rmatvec match the base task's pure-jnp
    operator chain (the same oracle ``kernels/power_matvec/ref.py`` encodes)
    on random unit probes. Returns the max relative error observed."""
    kv, ku = jax.random.split(key)
    v = sphere_vector(kv, task.m)
    u = sphere_vector(ku, task.d)

    def rel_err(a, b):
        a, b = jnp.asarray(a), jnp.asarray(b)
        # Explicit device_get: this runs inside drivers whose callers may
        # guard against implicit device->host transfers.
        return float(jax.device_get(
            jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-30)
        ))

    err = max(
        rel_err(ktask.matvec(state, v), task.matvec(state, v)),
        rel_err(ktask.rmatvec(state, u), task.rmatvec(state, u)),
    )
    if err > tol:
        raise AssertionError(
            f"kernelized matvec diverges from jnp reference: rel err {err:.3e} "
            f"> tol {tol:.1e} (task={type(task).__name__})"
        )
    return err


# ---------------------------------------------------------------------------
# Sampled-worker (straggler) schedule
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("num_epochs", "num_workers", "reweight"))
def worker_schedule(
    key: jax.Array,
    num_epochs: int,
    num_workers: int,
    sample_prob: float,
    *,
    reweight: bool = True,
) -> jax.Array:
    """(num_epochs, num_workers) per-epoch worker weights.

    Each worker participates independently with ``sample_prob`` (the paper's
    sampled-worker/straggler-timeout model); if a draw kills every worker one
    uniformly-chosen survivor is forced so the LMO stays well-defined. With
    ``reweight`` the survivors are scaled by num_workers/num_alive, making
    the psum'd loss/gap/line-search aggregates unbiased estimates of their
    full-data values under equal shard sizes.
    """

    def one_epoch(k):
        alive = jax.random.bernoulli(k, sample_prob, (num_workers,))
        force = jax.random.randint(jax.random.fold_in(k, 1), (), 0, num_workers)
        alive = jnp.where(jnp.any(alive), alive, alive.at[force].set(True))
        w = alive.astype(jnp.float32)
        if reweight:
            w = w * (num_workers / jnp.sum(w))
        return w

    keys = jax.vmap(jax.random.fold_in, in_axes=(None, 0))(
        key, jnp.arange(num_epochs)
    )
    return jax.vmap(one_epoch)(keys)


# ---------------------------------------------------------------------------
# Sharded epoch construction (single-epoch unit; the engine wraps segments)
# ---------------------------------------------------------------------------


def make_sharded_epoch(
    task,
    cfg: DFWConfig,
    mesh: Mesh,
    num_power_iters: int,
    state_example: PyTree,
    reducer: Optional[comm_lib.Reducer] = None,
) -> Callable:
    """shard_map-wrapped single epoch: ``(carry, mask) -> (carry, aux)``.

    The unified-carry analogue of one engine scan step, exposed for tests
    and the HLO-analysis benchmarks that need exactly one epoch's compiled
    collectives. ``carry.state`` is row-sharded over ``cfg.data_axis``;
    iterate, scalars and the PRNG key are replicated; ``mask`` is the
    (num_workers,) worker-weight vector of which each worker consumes its
    own entry; every ``carry.comm_state`` leaf carries a leading worker axis
    sharded over ``cfg.data_axis`` (leaf (nw, d) outside, (1, d) per worker
    inside — the error-feedback residuals live with the worker that owns
    them, exactly like the task state rows; ``()`` for dense).

    ``cfg.topology`` other than "flat" routes the exchanges through a
    ``comm.Topology`` built for this mesh (a passed ``reducer`` is then
    ignored — the topology builds its own inner reducer at the right
    width); gossip topologies additionally give the factored iterate the
    leading worker axis (see ``engine.sharded_carry_spec``).
    """
    axis = cfg.data_axis
    tspec = specs.parse_topology(cfg.topology)
    if tspec.kind == "flat":
        if reducer is None:
            reducer = comm_lib.DenseReducer()
        comm_obj = reducer
    else:
        comm_obj = comm_lib.make_topology(
            cfg.topology, num_workers=mesh.shape[axis], comm=cfg.comm,
            rounds=cfg.gossip_rounds,
            use_pallas=cfg.use_pallas, interpret=cfg.interpret,
        )
        reducer = comm_obj.reducer
    per_node = bool(getattr(comm_obj, "per_node", False))  # REP002-ok: host attribute
    sspec = frank_wolfe.parse_solver(cfg.solver)
    k_block = sspec.k if sspec.kind == "block" else 1
    ep = frank_wolfe.make_epoch_step(
        task, cfg.mu, num_power_iters, step_size=cfg.step_size, axis_name=axis,
        reducer=comm_obj, solver=sspec,
    )

    carry_spec = engine.sharded_carry_spec(
        axis,
        row_specs(state_example, axis),
        reducer.init_state(task.d * k_block, task.m * k_block),
        frank_wolfe.init_probe(sspec, task.m),
        per_node_iterate=per_node,
    )
    aux_spec = EpochAux(P(), P(), P(), P(), P())

    def step(carry, mask):
        carry, aux = ep(
            engine.strip_worker_axis(carry, per_node_iterate=per_node),
            worker_weight=mask[0],
        )
        return (
            engine.restore_worker_axis(carry, per_node_iterate=per_node),
            aux,
        )

    return shard_map_compat(
        step,
        mesh,
        in_specs=(carry_spec, P(axis)),
        out_specs=(carry_spec, aux_spec),
    )


# ---------------------------------------------------------------------------
# Checkpoint / resume plumbing shared by the drivers
# ---------------------------------------------------------------------------


def _check_snapshot(snap: ckpt.RunSnapshot, task, cfg: DFWConfig) -> None:
    """A checkpoint is only resumable onto the problem it was saved from:
    same task type and dimensions (worker count / comm / schedule MAY
    change — that's elastic / warm restart). Mismatches here mean the
    caller pointed resume_from at the wrong run."""
    ext = snap.extra
    want = (type(task).__name__, int(task.d), int(task.m))
    got = (ext.get("task"), int(ext.get("d", -1)), int(ext.get("m", -1)))
    if want != got:
        raise ValueError(
            f"checkpoint was saved by task {got} but resume targets {want}; "
            "resume_from must point at a checkpoint of the same problem"
        )
    if snap.t > cfg.num_epochs:
        raise ValueError(
            f"checkpoint is at epoch {snap.t} but num_epochs={cfg.num_epochs}; "
            "extend num_epochs to resume past it"
        )


def _resume_complete(snap: ckpt.RunSnapshot, cfg: DFWConfig) -> bool:
    """Does the checkpoint already satisfy the *current* config? True when
    the epoch budget is spent, or when the saved early stop still stands
    under cfg's gap_tol. A warm restart that extends num_epochs or loosens/
    removes gap_tol re-enters the engine instead of returning the stopped
    run verbatim — the saved ``done`` flag records the OLD certificate, not
    this one."""
    if snap.t >= cfg.num_epochs:
        return True
    if not snap.done:
        return False
    gaps = snap.history.get("gap", [])
    return bool(gaps) and cfg.gap_tol is not None and gaps[-1] <= cfg.gap_tol


def _make_checkpointer(
    task, cfg: DFWConfig, nw: int, comm_spec: str, telemetry=None,
    *, per_node_iterate: bool = False,
) -> Optional[ckpt.RunCheckpointer]:
    if cfg.checkpoint_dir is None:
        return None
    return ckpt.RunCheckpointer(
        cfg.checkpoint_dir,
        save_every=cfg.checkpoint_every,
        keep_last=cfg.checkpoint_keep,
        telemetry=telemetry,
        per_node_iterate=per_node_iterate,
        extra=ckpt.run_extra(
            task,
            num_workers=nw,
            comm=comm_spec,
            num_epochs=cfg.num_epochs,
            schedule=cfg.schedule,
            mu=cfg.mu,
            step_size=cfg.step_size,
            sample_prob=cfg.sample_prob,
            reweight=cfg.reweight,
            solver=cfg.solver,
            topology=cfg.topology,
        ),
    )


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


def fit(
    task,
    x: jax.Array,
    y: jax.Array,
    *,
    cfg: DFWConfig,
    key: jax.Array,
    mesh: Optional[Mesh] = None,
    num_workers: Optional[int] = None,
    callback: Optional[Callable[[int, EpochAux], None]] = None,
) -> DFWFitResult:
    """Run distributed DFW-Trace on data ``(x, y)`` sharded over workers.

    Provide either a prebuilt 1-D ``mesh`` (axis ``cfg.data_axis``) or a
    ``num_workers`` count (a mesh over the first N devices is built). The
    sample axis of ``x``/``y`` must divide the worker count. The returned
    history matches ``frank_wolfe.fit``'s, plus the per-epoch worker masks.

    Execution goes through ``core/engine.run_epochs``: maximal constant-K(t)
    segments each compiled as one ``lax.scan`` inside ``shard_map``, epochs
    advancing entirely on device. ``callback(start_t, aux_block)`` fires per
    segment (see ``frank_wolfe.fit``), not per epoch.
    """
    if mesh is None:
        if num_workers is None:
            raise ValueError("pass mesh= or num_workers=")
        mesh = data_mesh(num_workers, cfg.data_axis)
    elif num_workers is not None and mesh.shape[cfg.data_axis] != num_workers:
        raise ValueError(
            f"mesh has {mesh.shape[cfg.data_axis]} workers on "
            f"{cfg.data_axis!r} but num_workers={num_workers}; pass one or "
            "make them agree"
        )
    nw = mesh.shape[cfg.data_axis]
    sspec, _, tspec = specs.validate(
        solver=cfg.solver, comm=cfg.comm, topology=cfg.topology
    )
    k_block = sspec.k if sspec.kind == "block" else 1
    max_rank = engine.resolve_max_rank(cfg.max_rank, cfg.num_epochs, k_block)
    tel = cfg.telemetry if cfg.telemetry is not None else Telemetry.noop()
    tel.event("run.start", "run", driver="launch.dfw.fit",
              task=type(task).__name__, d=int(task.d), m=int(task.m),
              num_workers=nw, comm=cfg.comm, topology=cfg.topology,
              schedule=cfg.schedule,
              num_epochs=cfg.num_epochs, solver=cfg.solver)

    # The comm stack: a Topology (exchange graph) wrapping a Reducer (wire
    # encoding). "flat" hands the bare reducer to the engine — the exact
    # legacy psum path, bit for bit — while ring/gossip/hier pass the
    # topology itself (it quacks like a Reducer: same ``exchange``
    # signature, so nothing downstream changes shape).
    topo = comm_lib.make_topology(
        cfg.topology, num_workers=nw, comm=cfg.comm,
        rounds=cfg.gossip_rounds,
        use_pallas=cfg.use_pallas, interpret=cfg.interpret,
    )
    reducer = topo.reducer
    comm_obj = reducer if tspec.kind == "flat" else topo
    per_node = bool(getattr(comm_obj, "per_node", False))

    ktask = (
        kernelize(task, use_pallas=cfg.use_pallas, interpret=cfg.interpret)
        if cfg.kernelize
        else task
    )
    if cfg.kernelize and cfg.verify_kernels:
        # Probe on a small host-local slice before committing to the run.
        probe_rows = min(x.shape[0], max(nw, 64))
        probe = task.init_state(x[:probe_rows], y[:probe_rows])
        verify_kernelized(task, ktask, probe, jax.random.fold_in(key, 0x5EED))
    if isinstance(reducer, comm_lib.Int8Reducer) and cfg.verify_kernels:
        comm_lib.verify_quantize_kernels(
            jax.random.fold_in(key, 0x17F8),
            num_workers=nw, use_pallas=cfg.use_pallas, interpret=cfg.interpret,
        )

    xs, ys = shard_rowwise(mesh, (x, y), cfg.data_axis)
    state = ktask.init_state(xs, ys)
    it = low_rank.init(max_rank, task.d, task.m)

    # Per-worker reducer state: every worker starts from the reducer's own
    # init_state values (not zeros — the contract allows nonzero
    # initialization), stacked along a leading worker axis sharded like the
    # data rows. Dense's () has no leaves, so this is a no-op there. The
    # block solver flattens (d,k)/(m,k) blocks through the reducer, so
    # stateful encodings are sized for the flattened payload.
    comm_example = reducer.init_state(task.d * k_block, task.m * k_block)
    comm_state = jax.tree.map(
        lambda leaf: jax.device_put(
            jnp.broadcast_to(leaf, (nw,) + leaf.shape),
            NamedSharding(mesh, P(cfg.data_axis)),
        ),
        comm_example,
    )

    # Block-solver warm-start probe: replicated (m, k) block, cold-started
    # deterministically (() for rank1 — zero extra carry leaves).
    probe_blk = frank_wolfe.init_probe(sspec, task.m)
    if sspec.kind == "block":
        probe_blk = jax.device_put(probe_blk, NamedSharding(mesh, P()))

    sampling = cfg.sample_prob < 1.0
    if sampling:
        masks = worker_schedule(
            jax.random.fold_in(key, 0x1A5C),
            cfg.num_epochs,
            nw,
            cfg.sample_prob,
            reweight=cfg.reweight,
        )
    else:
        masks = jnp.ones((cfg.num_epochs, nw), jnp.float32)

    start_t, initial_history = 0, None
    if cfg.resume_from is not None:
        # `state` (freshly built above) supplies the treedef skeleton; its
        # values are then replaced wholesale by the checkpointed ones.
        snap = ckpt.restore_run(
            cfg.resume_from, state_like=state, step=cfg.resume_step
        )
        _check_snapshot(snap, task, cfg)
        state = shard_rowwise(mesh, snap.carry.state, cfg.data_axis)
        it = snap.unpack_iterate(max_rank)
        key = jnp.asarray(snap.carry.key)
        start_t, initial_history = snap.t, snap.history
        snap_probe = getattr(snap.carry, "probe", ())
        if (
            sspec.kind == "block"
            and hasattr(snap_probe, "shape")
            and tuple(snap_probe.shape) == (task.m, sspec.k)
        ):
            # v2 checkpoint with a matching block width: resume the warm
            # start bit-exactly. v1 payloads (or a changed k) keep the cold
            # probe initialized above — convergence is preserved, warmth
            # is not.
            probe_blk = jax.device_put(
                jnp.asarray(snap_probe), NamedSharding(mesh, P())
            )
        same_mesh = int(snap.extra.get("num_workers", -1)) == nw
        same_topo = snap.extra.get("topology", "flat") == cfg.topology
        if same_mesh and same_topo and snap.extra.get("comm") == reducer.spec:
            # Bit-exact path: per-worker reducer state (e.g. top-k
            # error-feedback residuals) resumes exactly where it stopped.
            comm_state = jax.tree.map(
                lambda leaf: jax.device_put(
                    jnp.asarray(leaf), NamedSharding(mesh, P(cfg.data_axis))
                ),
                snap.carry.comm_state,
            )
        # else: keep the freshly initialized comm_state — an elastic remesh
        # (or a warm comm-mode change) re-derives per-worker state.
        same_sampling = (
            float(snap.extra.get("sample_prob", -1.0)) == cfg.sample_prob
            and bool(snap.extra.get("reweight", not cfg.reweight))
            == cfg.reweight
        )
        if (
            same_mesh
            and same_sampling
            and snap.masks is not None
            and snap.masks.shape == (cfg.num_epochs, nw)
        ):
            masks = jnp.asarray(snap.masks)
        # else: the regenerated schedule above stands — a new worker count
        # or extended num_epochs re-draws it, and a warm restart that
        # changes sample_prob/reweight must get the schedule it asked for,
        # not the checkpointed run's.
        if _resume_complete(snap, cfg):
            # Nothing left to run: the checkpoint already holds the final
            # carry (epoch budget spent, or its gap certificate still
            # stands under this config's gap_tol).
            final_loss = float(jax.device_get(jax.jit(ktask.local_loss)(state)))
            return DFWFitResult(
                iterate=it, state=state, history=snap.history,
                masks=masks[: snap.t] if sampling else None,
                final_loss=final_loss, epochs_run=snap.t,
                stats={"segments_planned": 0, "segments_run": 0,
                       "dispatches": 1, "compilations": 1, "host_syncs": 1},
            )

    checkpointer = _make_checkpointer(
        task, cfg, nw, reducer.spec, tel, per_node_iterate=per_node
    )
    if checkpointer is not None:
        # checkpoint_dir belongs to THIS run's timeline from here on: a
        # fresh run clears any previous run's steps, a resume keeps its
        # prefix and drops the abandoned tail. Either way, steps past
        # start_t would shadow this run's history on the next default
        # (latest-step) resume.
        checkpointer.store.discard_after(start_t)

    if per_node:
        # Gossip: every worker evolves its own inexact-consensus iterate, so
        # the (possibly resumed node-0) iterate is stacked along a leading
        # worker axis sharded like the data rows — the exact treatment the
        # per-worker reducer state already gets. A gossip resume is elastic
        # here by construction: checkpoints store the node-0 slice and this
        # broadcast re-seeds every node with it (the optimization dynamics
        # themselves resume bit-exactly — they read only the task state).
        it = jax.tree.map(
            lambda leaf: jax.device_put(
                jnp.broadcast_to(leaf, (nw,) + leaf.shape),
                NamedSharding(mesh, P(cfg.data_axis)),
            ),
            it,
        )

    wrapper = engine.shard_map_segment_wrapper(
        mesh,
        cfg.data_axis,
        row_specs(state, cfg.data_axis),
        comm_state_example=comm_example,
        probe_example=probe_blk,
        has_masks=True,
        per_node_iterate=per_node,
    )
    with tel.profiler():
        eres = engine.run_epochs(
            ktask,
            state,
            mu=cfg.mu,
            num_epochs=cfg.num_epochs,
            key=key,
            schedule=cfg.schedule,
            step_size=cfg.step_size,
            axis_name=cfg.data_axis,
            reducer=comm_obj,
            comm_state=comm_state,
            iterate=it,
            masks=masks,
            gap_tol=cfg.gap_tol,
            block_epochs=cfg.block_epochs,
            segment_wrapper=wrapper,
            callback=callback,
            mode=cfg.engine,
            start_t=start_t,
            initial_history=initial_history,
            checkpointer=checkpointer,
            telemetry=tel,
            num_workers=nw,
            solver=sspec,
            probe=probe_blk if sspec.kind == "block" else None,
        )
    if checkpointer is not None:
        # Surface the last in-flight write's failure here, not silently at
        # interpreter exit — the run result should not claim durability the
        # store never achieved.
        with tel.span("checkpoint.join", "checkpoint"):
            checkpointer.wait()
    # Loss at the returned iterate (history is pre-update; see frank_wolfe.fit).
    # The plain sum over the row-sharded state is already the global loss, and
    # straggler weights never apply here: this is the true full-data F.
    with tel.span("engine.final_loss", "engine"):
        final_loss = float(
            jax.device_get(jax.jit(ktask.local_loss)(eres.carry.state))
        )
    eres.stats["dispatches"] += 1
    eres.stats["host_syncs"] += 1
    eres.stats["compilations"] += 1
    it_out = eres.carry.iterate
    if per_node:
        # Report node 0's iterate — the same convention gossip checkpoints
        # use. All nodes agree to consensus tolerance; the caller's
        # final_loss above is the exact full-data F of the *states*.
        it_out = jax.tree.map(lambda a: a[0], it_out)
    return DFWFitResult(
        iterate=it_out,
        state=eres.carry.state,
        history=eres.history,
        masks=masks[: eres.epochs_run] if sampling else None,
        final_loss=final_loss,
        epochs_run=eres.epochs_run,
        stats=eres.stats,
    )


def fit_serial(
    task,
    x: jax.Array,
    y: jax.Array,
    *,
    cfg: DFWConfig,
    key: jax.Array,
    callback: Optional[Callable[[int, EpochAux], None]] = None,
) -> DFWFitResult:
    """Single-device reference run with the *same* config (and the same
    kernelized matvec path) as ``fit`` — the baseline every sharded run is
    compared against in tests and benchmarks.

    ``cfg.comm`` is honored with a one-worker reducer: the serial run
    *simulates* the compressed encoding (int8 at full 127-level budget,
    top-k with one worker's error feedback), which is what the
    convergence-vs-bits sweeps compare against. ``cfg.topology`` is honored
    the same way: a one-worker gossip exchange is the identity (a node
    averaging with itself) and a one-worker ``hier:g`` applies the reducer
    encoding at group width g — the serial baselines the topology tests and
    sweeps compare their sharded runs against.

    ``cfg.sample_prob`` < 1 is rejected: the straggler model samples
    *workers*, and a serial run has exactly one — silently ignoring the
    setting (the old behavior) made a "straggler" benchmark measure nothing.
    """
    if cfg.sample_prob < 1.0:
        raise ValueError(
            f"sample_prob={cfg.sample_prob} needs multiple workers to sample "
            "from; fit_serial runs exactly one. Use fit(..., num_workers=N) "
            "for the straggler mode, or set sample_prob=1.0"
        )
    ktask = (
        kernelize(task, use_pallas=cfg.use_pallas, interpret=cfg.interpret)
        if cfg.kernelize
        else task
    )
    sspec, _, tspec = specs.validate(
        solver=cfg.solver, comm=cfg.comm, topology=cfg.topology
    )
    topo = comm_lib.make_topology(
        cfg.topology, num_workers=1, comm=cfg.comm,
        rounds=cfg.gossip_rounds,
        use_pallas=cfg.use_pallas, interpret=cfg.interpret,
    )
    reducer = topo.reducer
    comm_obj = reducer if tspec.kind == "flat" else topo
    k_block = sspec.k if sspec.kind == "block" else 1
    state = ktask.init_state(jnp.asarray(x), jnp.asarray(y))
    iterate, comm_state, start_t, initial_history = None, None, 0, None
    probe = None
    if cfg.resume_from is not None:
        snap = ckpt.restore_run(
            cfg.resume_from, state_like=state, step=cfg.resume_step
        )
        _check_snapshot(snap, task, cfg)
        state = jax.tree.map(jnp.asarray, snap.carry.state)
        iterate = snap.unpack_iterate(
            engine.resolve_max_rank(cfg.max_rank, cfg.num_epochs, k_block)
        )
        key = jnp.asarray(snap.carry.key)
        start_t, initial_history = snap.t, snap.history
        snap_probe = getattr(snap.carry, "probe", ())
        if (
            sspec.kind == "block"
            and hasattr(snap_probe, "shape")
            and tuple(snap_probe.shape) == (task.m, sspec.k)
        ):
            # v2 payload with matching block width resumes the warm start;
            # v1 (or a changed k) cold-starts via the engine default.
            probe = jnp.asarray(snap_probe)
        if (
            int(snap.extra.get("num_workers", -1)) == 1
            and snap.extra.get("comm") == reducer.spec
        ):
            comm_state = jax.tree.map(jnp.asarray, snap.carry.comm_state)
        # else: default (fresh) reducer state — a sharded checkpoint's
        # per-worker residuals don't transfer to the one-worker run, and a
        # warm comm change starts its new encoding from scratch.
        if _resume_complete(snap, cfg):
            final_loss = float(jax.device_get(jax.jit(ktask.local_loss)(state)))
            return DFWFitResult(
                iterate=iterate, state=state, history=snap.history,
                masks=None, final_loss=final_loss, epochs_run=snap.t,
                stats={"segments_planned": 0, "segments_run": 0,
                       "dispatches": 1, "compilations": 1, "host_syncs": 1},
            )
    checkpointer = _make_checkpointer(task, cfg, 1, reducer.spec, cfg.telemetry)
    if checkpointer is not None:
        # As in `fit`: the dir is this run's timeline — drop steps past
        # start_t (all of them, for a fresh run).
        checkpointer.store.discard_after(start_t)
    res = frank_wolfe.fit(
        ktask,
        state,
        mu=cfg.mu,
        num_epochs=cfg.num_epochs,
        key=key,
        schedule=cfg.schedule,
        step_size=cfg.step_size,
        callback=callback,
        reducer=comm_obj,
        max_rank=cfg.max_rank,
        gap_tol=cfg.gap_tol,
        block_epochs=cfg.block_epochs,
        mode=cfg.engine,
        iterate=iterate,
        comm_state=comm_state,
        start_t=start_t,
        initial_history=initial_history,
        checkpointer=checkpointer,
        telemetry=cfg.telemetry,
        solver=sspec,
        probe=probe,
    )
    return DFWFitResult(
        iterate=res.iterate, state=res.state, history=res.history, masks=None,
        final_loss=res.final_loss, epochs_run=res.epochs_run, stats=res.stats,
    )
