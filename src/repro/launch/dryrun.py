import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
# cell against 512 placeholder CPU devices, then extract the roofline terms
# from the compiled artifact. The two lines above MUST run before any other
# import (jax locks the device count at first init).

import argparse  # noqa: E402
import dataclasses  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from pathlib import Path  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCH_IDS, get_config  # noqa: E402
from repro.analysis import hlo as hlo_analysis  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.launch.params import param_pspecs  # noqa: E402
from repro.launch.sharding import pspec, rules_for, use_mesh  # noqa: E402
from repro.launch.steps import (  # noqa: E402
    batch_pspecs,
    cache_pspecs,
    logits_pspec,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models import applicable_shapes, input_specs, lm  # noqa: E402
from repro.models.config import LM_SHAPES  # noqa: E402
from repro.optim import adamw  # noqa: E402


def count_params(aparams) -> dict:
    """Total and MoE-active parameter counts from the abstract tree."""
    total = 0
    moe_total = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(aparams)[0]:
        names = [getattr(k, "key", None) for k in path]
        total += leaf.size
        if "moe" in names and names[-1] != "router":
            moe_total += leaf.size
    return {"total": int(total), "moe": int(moe_total)}


def model_flops(cfg, params_count: dict, shape) -> float:
    """Standard 6*N*D (train) / 2*N*D (inference) with MoE active params and
    the embedding table excluded, attention excluded (the convention)."""
    n_embed = cfg.vocab_size * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    dense_n = params_count["total"] - params_count["moe"] - n_embed
    if cfg.num_experts:
        active = params_count["moe"] * cfg.experts_per_token / cfg.num_experts
    else:
        active = 0
    n = dense_n + active
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train" else 1)
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
    factor = 6.0 if shape.kind == "train" else 2.0
    return factor * n * tokens


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool, mesh=None,
               profile: str = "tp", seq_chunk: int = 0):
    """Build and lower one cell. Returns (lowered, meta)."""
    cfg = get_config(arch)
    if seq_chunk:
        cfg = dataclasses.replace(cfg, seq_chunk=seq_chunk)
    shapes = applicable_shapes(cfg)
    if shape_name not in shapes:
        raise KeyError(f"{arch} skips {shape_name} (see DESIGN.md §Arch-applicability)")
    shape = shapes[shape_name]
    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)

    with use_mesh(mesh, rules_for(profile)):
        aparams = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
        pspecs = param_pspecs(aparams)
        psh = jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                           is_leaf=lambda x: isinstance(x, P))
        bspecs = batch_pspecs(cfg, shape)
        bsh = {k: NamedSharding(mesh, s) for k, s in bspecs.items()}
        abatch = input_specs(cfg, shape)

        if shape.kind == "train":
            aopt = jax.eval_shape(adamw.init, aparams)
            osh = adamw.AdamWState(
                step=NamedSharding(mesh, P()),
                m=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P)),
                v=jax.tree.map(lambda s: NamedSharding(mesh, s), pspecs,
                               is_leaf=lambda x: isinstance(x, P)),
            )
            step = make_train_step(cfg)
            msh = {k: NamedSharding(mesh, P()) for k in ("ce", "aux", "loss", "lr")}
            fn = jax.jit(
                step,
                in_shardings=(psh, osh, bsh),
                out_shardings=(psh, osh, msh),
                donate_argnums=(0, 1),
            )
            lowered = fn.lower(aparams, aopt, abatch)

        elif shape.kind == "prefill":
            step = make_prefill_step(cfg)
            if cfg.encoder_only:
                out_sh = (NamedSharding(mesh, logits_pspec(cfg, shape, full_seq=True)), None)
            else:
                csh = {
                    k: NamedSharding(mesh, s)
                    for k, s in cache_pspecs(cfg, shape).items()
                }
                out_sh = (NamedSharding(mesh, logits_pspec(cfg, shape)), csh)
            fn = jax.jit(step, in_shardings=(psh, bsh), out_shardings=out_sh)
            lowered = fn.lower(aparams, abatch)

        else:  # decode
            acache = lm.cache_specs(cfg, shape.global_batch, shape.seq_len)
            cspecs = cache_pspecs(cfg, shape)
            csh = {k: NamedSharding(mesh, s) for k, s in cspecs.items()}
            step = make_serve_step(cfg)
            out_sh = (NamedSharding(mesh, logits_pspec(cfg, shape, full_seq=True)), csh)
            fn = jax.jit(
                step, in_shardings=(psh, csh, bsh), out_shardings=out_sh,
                donate_argnums=(1,),
            )
            lowered = fn.lower(aparams, acache, abatch)

    meta = {
        "arch": arch,
        "shape": shape_name,
        "kind": shape.kind,
        "profile": profile,
        "mesh": "pod2x16x16" if multi_pod else "pod16x16",
        "n_devices": 512 if multi_pod else 256,
        "params": count_params(aparams),
        "model_flops": model_flops(cfg, count_params(aparams), shape),
        "global_batch": shape.global_batch,
        "seq_len": shape.seq_len,
    }
    return lowered, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, out_dir: Path,
             mesh=None, tag: str = "", profile: str = "tp",
             seq_chunk: int = 0) -> dict:
    t0 = time.time()
    lowered, meta = lower_cell(
        arch, shape_name, multi_pod=multi_pod, mesh=mesh, profile=profile,
        seq_chunk=seq_chunk,
    )
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    walk = hlo_analysis.analyze(compiled.as_text())

    result = dict(meta)
    result.update(
        {
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "alias_bytes": mem.alias_size_in_bytes,
            },
            "cost_analysis": {
                "flops_body_once": cost.get("flops", 0.0),
                "bytes_accessed_body_once": cost.get("bytes accessed", 0.0),
            },
            "hlo": walk,
        }
    )
    out_dir.mkdir(parents=True, exist_ok=True)
    name = f"{arch}__{shape_name}__{result['mesh']}{tag}.json"
    (out_dir / name).write_text(json.dumps(result, indent=1))
    return result


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="shape name or 'all'")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--profile", default="tp", choices=["tp", "sp", "msp"])
    ap.add_argument("--tag", default="")
    ap.add_argument("--seq-chunk", type=int, default=0)
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    out_dir = Path(args.out)
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            cfg = get_config(arch)
            shapes = applicable_shapes(cfg)
            names = list(shapes) if args.shape == "all" else [args.shape]
            for shape_name in names:
                if shape_name not in shapes:
                    print(f"SKIP {arch} {shape_name} (inapplicable)")
                    continue
                try:
                    res = run_cell(
                        arch, shape_name, multi_pod=multi_pod, out_dir=out_dir,
                        mesh=mesh, profile=args.profile, tag=args.tag,
                        seq_chunk=args.seq_chunk,
                    )
                    print(
                        f"OK   {arch:24s} {shape_name:12s} {res['mesh']:10s} "
                        f"compile={res['compile_s']:7.1f}s "
                        f"flops/dev={res['hlo']['flops']:.3e} "
                        f"coll={res['hlo']['collective_bytes_total']:.3e}B "
                        f"temp={res['memory']['temp_bytes']/2**30:.2f}GiB"
                    )
                except Exception as e:  # noqa: BLE001
                    failures.append((arch, shape_name, multi_pod, repr(e)))
                    print(f"FAIL {arch} {shape_name} multi_pod={multi_pod}: {e}")
                    traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} dry-run cells failed: {failures}")


if __name__ == "__main__":
    main()
