"""Serving driver: batched prefill + decode with KV/SSM caches.

Smoke-scale on CPU; the same serve_step is what the dry-run lowers at
(16,16)/(2,16,16) for the decode_32k / long_500k cells.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm

from .steps import make_serve_step


def generate(
    *,
    arch: str,
    batch: int = 4,
    prompt_len: int = 16,
    max_new_tokens: int = 32,
    smoke: bool = True,
    temperature: float = 0.0,
    seed: int = 0,
):
    """Greedy/temperature sampling over the synthetic-token distribution."""
    cfg = get_config(arch, smoke=smoke)
    if cfg.encoder_only:
        raise ValueError(f"{arch} is encoder-only; no decode path")
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(cfg, key)
    max_len = prompt_len + max_new_tokens
    cache = lm.init_cache(cfg, batch, max_len)
    step = jax.jit(make_serve_step(cfg))

    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    toks = [prompt[:, i : i + 1] for i in range(prompt_len)]
    out_tokens = []
    logits = None
    t0 = time.time()
    for t in range(max_len - 1):
        cur = toks[t] if t < prompt_len else out_tokens[-1]
        b = {"tokens": cur, "cache_pos": jnp.int32(t)}
        if cfg.family == "vlm":
            b["positions"] = jnp.full((batch, 3, 1), t, jnp.int32)
        logits, cache = step(params, cache, b)
        if t >= prompt_len - 1:
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, 0, :] / temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, 0, :], axis=-1)[:, None]
            out_tokens.append(nxt)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] {arch}: generated {gen.shape} in {dt:.2f}s "
          f"({dt / max(len(out_tokens),1) * 1e3:.1f} ms/token at batch {batch})")
    return np.asarray(gen)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()
    generate(
        arch=args.arch, batch=args.batch, prompt_len=args.prompt_len,
        max_new_tokens=args.max_new_tokens, temperature=args.temperature,
    )


if __name__ == "__main__":
    main()
