"""Serving drivers.

Two traffic shapes live here:

* **Factor-form scoring** (``serve_factored``, the primary driver): score
  request vectors against a DFW-Trace checkpoint through
  ``repro.serve.ServingEngine`` — fused factor matvec, padded static
  batches, live-rank bucket packing, and hot-swap that follows the
  checkpoint directory as training writes new steps. This is the paper's
  deployment story: the model never exists as a dense d x m matrix, in
  training *or* in serving.
* **LM decode** (``generate``, legacy): batched incremental decoding over
  the model zoo with KV/SSM caches. Smoke-scale on CPU; the same
  serve_step is what the dry-run lowers at (16,16)/(2,16,16) for the
  decode_32k / long_500k cells.

CLI: ``python -m repro.launch.serve factor --checkpoint DIR ...`` or
``python -m repro.launch.serve lm --arch NAME ...``.
"""
from __future__ import annotations

import argparse
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import lm
from repro.serve import ServeConfig, ServingEngine

from .steps import make_serve_step


# ---------------------------------------------------------------------------
# Factor-form serving (primary)
# ---------------------------------------------------------------------------


def serve_factored(
    *,
    checkpoint: str,
    max_batch: int = 64,
    rank_block: int = 32,
    transpose: bool = False,
    batches: int = 8,
    follow: int = 0,
    poll_s: float = 0.2,
    seed: int = 0,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
):
    """Serve random request traffic from a run-checkpoint directory.

    Loads the latest step, scores ``batches`` full padded batches, and — in
    ``follow`` mode — polls the directory up to ``follow`` more rounds,
    hot-swapping whenever training has written a newer step (the live
    train-and-serve topology: one process fits, this one scores). Returns a
    summary dict; prints one line per swap and a final stats line.
    """
    cfg = ServeConfig(
        max_batch=max_batch, rank_block=rank_block, transpose=transpose,
        use_pallas=use_pallas, interpret=interpret,
    )
    eng = ServingEngine.from_checkpoint(checkpoint, cfg)
    print(
        f"[serve] {eng.d}x{eng.m} model, step {eng.model.step}, "
        f"live rank {eng.model.live_rank} (bucket {eng.model.capacity}), "
        f"max_batch {max_batch}"
    )
    rng = np.random.default_rng(seed)

    def pump(n_batches: int) -> float:
        xs = rng.standard_normal((n_batches, max_batch, eng.n_in), np.float32)
        t0 = time.perf_counter()
        handles = [eng.score_async(xs[i]) for i in range(n_batches)]
        rows = sum(h.block().shape[0] for h in handles)
        dt = time.perf_counter() - t0
        print(
            f"[serve] scored {rows} requests in {dt * 1e3:.1f} ms "
            f"({rows / max(dt, 1e-9):.0f} req/s, model v{eng.model.version})"
        )
        return dt

    pump(batches)
    for _ in range(follow):
        time.sleep(poll_s)
        from repro.checkpoint import CheckpointStore

        latest = CheckpointStore(checkpoint).latest_step()
        if latest is not None and latest != eng.model.step:
            before = eng.stats["compilations"]
            model = eng.load(checkpoint, step=latest)
            print(
                f"[serve] hot-swap -> step {model.step}, live rank "
                f"{model.live_rank}, +{eng.stats['compilations'] - before} "
                "compiles"
            )
        pump(batches)
    print(f"[serve] stats: {eng.stats}")
    return {"stats": dict(eng.stats), "step": eng.model.step,
            "live_rank": eng.model.live_rank, "version": eng.model.version}


# ---------------------------------------------------------------------------
# LM decode (legacy)
# ---------------------------------------------------------------------------


def generate(
    *,
    arch: str,
    batch: int = 4,
    prompt_len: int = 16,
    max_new_tokens: int = 32,
    smoke: bool = True,
    temperature: float = 0.0,
    seed: int = 0,
):
    """Greedy/temperature sampling over the synthetic-token distribution."""
    cfg = get_config(arch, smoke=smoke)
    if cfg.encoder_only:
        raise ValueError(f"{arch} is encoder-only; no decode path")
    key = jax.random.PRNGKey(seed)
    params = lm.init_params(cfg, key)
    max_len = prompt_len + max_new_tokens
    cache = lm.init_cache(cfg, batch, max_len)
    step = jax.jit(make_serve_step(cfg))

    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab_size)
    toks = [prompt[:, i : i + 1] for i in range(prompt_len)]
    out_tokens = []
    logits = None
    t0 = time.time()
    for t in range(max_len - 1):
        cur = toks[t] if t < prompt_len else out_tokens[-1]
        b = {"tokens": cur, "cache_pos": jnp.int32(t)}
        if cfg.family == "vlm":
            b["positions"] = jnp.full((batch, 3, 1), t, jnp.int32)
        logits, cache = step(params, cache, b)
        if t >= prompt_len - 1:
            if temperature > 0:
                key, sub = jax.random.split(key)
                nxt = jax.random.categorical(sub, logits[:, 0, :] / temperature)[:, None]
            else:
                nxt = jnp.argmax(logits[:, 0, :], axis=-1)[:, None]
            out_tokens.append(nxt)
    dt = time.time() - t0
    gen = jnp.concatenate(out_tokens, axis=1)
    print(f"[serve] {arch}: generated {gen.shape} in {dt:.2f}s "
          f"({dt / max(len(out_tokens),1) * 1e3:.1f} ms/token at batch {batch})")
    return np.asarray(gen)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    sub = ap.add_subparsers(dest="mode", required=True)

    fp = sub.add_parser("factor", help="score requests from a DFW checkpoint")
    fp.add_argument("--checkpoint", required=True)
    fp.add_argument("--max-batch", type=int, default=64)
    fp.add_argument("--rank-block", type=int, default=32)
    fp.add_argument("--transpose", action="store_true",
                    help="score x @ W^T (m -> d) instead of x @ W")
    fp.add_argument("--batches", type=int, default=8)
    fp.add_argument("--follow", type=int, default=0,
                    help="poll the checkpoint dir N more rounds, hot-swapping "
                         "onto any new step")
    fp.add_argument("--poll-s", type=float, default=0.2)
    fp.add_argument("--seed", type=int, default=0)
    fp.add_argument("--interpret", action="store_true")

    lp = sub.add_parser("lm", help="legacy LM decode driver")
    lp.add_argument("--arch", required=True)
    lp.add_argument("--batch", type=int, default=4)
    lp.add_argument("--prompt-len", type=int, default=16)
    lp.add_argument("--max-new-tokens", type=int, default=32)
    lp.add_argument("--temperature", type=float, default=0.0)

    args = ap.parse_args(argv)
    if args.mode == "factor":
        serve_factored(
            checkpoint=args.checkpoint, max_batch=args.max_batch,
            rank_block=args.rank_block, transpose=args.transpose,
            batches=args.batches, follow=args.follow, poll_s=args.poll_s,
            seed=args.seed, interpret=args.interpret,
        )
    else:
        generate(
            arch=args.arch, batch=args.batch, prompt_len=args.prompt_len,
            max_new_tokens=args.max_new_tokens, temperature=args.temperature,
        )


if __name__ == "__main__":
    main()
