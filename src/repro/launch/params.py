"""Parameter PartitionSpec assignment by pytree path.

Logical layout (mapped to mesh axes by launch.sharding rules):
  in-projections  (.., D_in, D_out_tp)  -> (..., fsdp, model)   Megatron col
  out-projections (.., D_in_tp, D_out)  -> (..., model, fsdp)   Megatron row
  embedding       (V, D)                -> (vocab, fsdp)
  unembedding     (D, V)                -> (fsdp, vocab)
  MoE experts     (E, D, F)/(E, F, D)   -> (expert, fsdp, None)  EP x ZeRO-3
  biases          (D_out_tp,)           -> (model,)
  norms / scalars / small tables        -> replicated
Stacked-layer leading dims get None prepended automatically.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .sharding import pspec

# leaf-name -> (trailing logical dims)
_IN_PROJ = ("fsdp", "heads")  # heads/mlp/vocab all map to "model" by default
_RULES: Dict[str, Tuple] = {
    # attention / generic in-projections (col-parallel)
    "wq": ("fsdp", "heads"),
    "wk": ("fsdp", "kv_heads"),
    "wv": ("fsdp", "kv_heads"),
    "wg": ("fsdp", "mlp"),
    "wu": ("fsdp", "mlp"),
    "w1": ("fsdp", "mlp"),
    "wr": ("fsdp", "mlp"),
    "ck": ("fsdp", "mlp"),
    "cr": ("fsdp", "mlp"),
    "w_in": ("fsdp", "mlp"),
    "w_lora_a": ("fsdp", None),
    # out-projections (row-parallel)
    "wo": ("heads", "fsdp"),
    "wd": ("mlp", "fsdp"),
    "w2": ("mlp", "fsdp"),
    "cv": ("mlp", "fsdp"),
    "w_out": ("mlp", "fsdp"),
    "w_lora_b": (None, "fsdp"),
    # embeddings
    "embed": ("vocab", "fsdp_embed"),
    "unembed": ("fsdp_embed", "vocab"),
    "frame_proj": (None, "fsdp_embed"),
    # biases
    "bq": ("heads",),
    "bk": ("kv_heads",),
    "bv": ("kv_heads",),
    # mamba conv (channel dim model-sharded)
    "conv_w": (None, "mlp"),
    "conv_b": ("mlp",),
}

# MoE expert tensors: (E, D, F) or (E, F, D); dim1 is the dim gathered
# (ZeRO-3) inside the shard_map MoE, dim0 is expert-parallel.
_MOE_RULES: Dict[str, Tuple] = {
    "wg": ("expert", "fsdp", None),
    "wu": ("expert", "fsdp", None),
    "wd": ("expert", "fsdp", None),
    "router": (None, None),
}


def _leaf_spec(path, leaf) -> P:
    from .sharding import axes_size

    names = [getattr(k, "key", getattr(k, "name", None)) for k in path]
    leaf_name = names[-1]
    in_moe = "moe" in names[:-1]
    rules = _MOE_RULES if in_moe and leaf_name in _MOE_RULES else _RULES
    trailing = rules.get(leaf_name)
    if trailing is None:
        return P()  # replicated (norms, gates, scalars, decay tables)
    pad = leaf.ndim - len(trailing)
    if pad < 0:
        return P()
    logical = [None] * pad + list(trailing)
    # pjit in_shardings must divide exactly (e.g. hubert's 504-way vocab on a
    # 16-way axis): drop the annotation for non-dividing dims.
    for i, name in enumerate(logical):
        if name is not None and leaf.shape[i] % max(axes_size(name), 1) != 0:
            logical[i] = None
    return pspec(*logical)


def param_pspecs(abstract_params: Any) -> Any:
    """PartitionSpec pytree for a (possibly abstract) param pytree, resolved
    under the ACTIVE mesh/rules (call inside use_mesh)."""
    return jax.tree_util.tree_map_with_path(_leaf_spec, abstract_params)


def param_shardings(mesh: Mesh, abstract_params: Any) -> Any:
    return jax.tree.map(
        lambda spec: NamedSharding(mesh, spec),
        param_pspecs(abstract_params),
        is_leaf=lambda x: isinstance(x, P),
    )
