"""Production mesh construction.

Single pod : (16, 16)   ("data", "model")  = 256 chips (TPU v5e pod)
Multi-pod  : (2, 16, 16)("pod", "data", "model") = 512 chips, "pod" over DCN.

Functions (not module constants) so importing never touches device state.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = math.prod(shape)
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices, found {len(devices)} — dryrun.py must set "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import"
        )
    dev = np.asarray(devices[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> jax.sharding.Mesh:
    """Arbitrary mesh over the first prod(shape) devices (tests, elasticity)."""
    n = math.prod(shape)
    dev = np.asarray(jax.devices()[:n]).reshape(shape)
    return jax.sharding.Mesh(dev, axes)


# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
DCN_BW = 25e9  # B/s per host, assumed for the "pod" axis
