"""jit-able train / prefill / serve steps + their input/output shardings.

These are the functions the dry-run lowers for every (arch x shape x mesh)
cell and the drivers (train.py / serve.py) execute for real.
"""
from __future__ import annotations

from typing import Dict

import jax
from jax.sharding import PartitionSpec as P

from repro.models import lm
from repro.models.config import ModelConfig, ShapeSpec, input_specs
from repro.optim import adamw, schedule

from .params import param_pspecs
from .sharding import pspec


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, *, peak_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000):
    def train_step(params, opt_state: adamw.AdamWState, batch):
        (loss, metrics), grads = jax.value_and_grad(lm.loss_fn, has_aux=True)(
            params, batch, cfg
        )
        lr = schedule.cosine_with_warmup(
            opt_state.step, peak_lr=peak_lr, warmup=warmup, total=total_steps
        )
        params, opt_state = adamw.update(grads, opt_state, params, lr=lr)
        metrics = dict(metrics, loss=loss, lr=lr)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    if cfg.encoder_only:  # no KV cache; "prefill" = full encoder forward
        def encode_step(params, batch):
            out = lm.forward(params, batch, cfg, mode="train")
            return out["logits"], None

        return encode_step

    def prefill_step(params, batch):
        out = lm.forward(params, batch, cfg, mode="prefill")
        last = out["logits"][:, -1, :]
        return last, out.get("cache")

    return prefill_step


def make_serve_step(cfg: ModelConfig):
    def serve_step(params, cache, batch):
        return lm.decode_step(params, cache, batch, cfg)

    return serve_step


# ---------------------------------------------------------------------------
# Shardings for the non-param inputs
# ---------------------------------------------------------------------------


def batch_pspecs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, P]:
    """PartitionSpecs matching models.config.input_specs (call inside use_mesh).

    If the global batch does not divide the batch mesh axes (bs=1 long-context
    decode), the batch dim is replicated — sequence/context parallelism takes
    over via cache_pspecs."""
    from .sharding import active_mesh, data_axes

    mesh = active_mesh()
    n_batch_shards = 1
    for a in data_axes():
        n_batch_shards *= mesh.shape[a] if mesh else 1
    b_axis = "batch" if shape.global_batch % max(n_batch_shards, 1) == 0 else None

    specs = {}
    for name in input_specs(cfg, shape):
        if name in ("tokens", "labels"):
            specs[name] = pspec(b_axis, None)
        elif name == "frames":
            specs[name] = pspec(b_axis, None, None)
        elif name == "vision_embeds":
            specs[name] = pspec(b_axis, None, "embed")
        elif name == "positions":
            specs[name] = pspec(b_axis, None, None)
        elif name == "cache_pos":
            specs[name] = pspec()
        else:
            raise KeyError(name)
    return specs


def logits_pspec(cfg: ModelConfig, shape: ShapeSpec, *, full_seq: bool = False) -> P:
    """Output-logits sharding, batch/vocab-divisibility aware."""
    from .sharding import active_mesh, axes_size, data_axes

    mesh = active_mesh()
    n = 1
    for a in data_axes():
        n *= mesh.shape[a] if mesh else 1
    b_axis = "batch" if shape.global_batch % max(n, 1) == 0 else None
    v_axis = "vocab" if cfg.vocab_size % max(axes_size("vocab"), 1) == 0 else None
    if full_seq:
        return pspec(b_axis, None, v_axis)
    return pspec(b_axis, v_axis)


def cache_pspecs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, P]:
    """Decode-cache shardings. bs==1 (long-context): shard the SEQUENCE dim of
    the KV cache over the data axes (flash-decode combine handles softmax);
    otherwise shard the batch dim."""
    from .sharding import axes_size

    seq_sharded = shape.global_batch == 1
    b = None if seq_sharded else "batch"
    # pjit in/out shardings must divide exactly: kv-head dim only when it
    # divides the model axis, else shard the cache's seq dim over the model
    # axis instead ("seq_tp") so the cache still spreads across all chips.
    kv_div = cfg.num_kv_heads % max(axes_size("kv_heads"), 1) == 0
    kv_h = "kv_heads" if kv_div else None
    if seq_sharded:
        kv_s = "seq"
    else:
        kv_s = None if kv_div else "seq_tp"
    table = {
        # (L, B, Hkv, S, Dh)
        "k": pspec(None, b, kv_h, kv_s, None),
        "v": pspec(None, b, kv_h, kv_s, None),
        # (L, B, nh, hd, N) SSM state: heads over TP
        "mamba_h": pspec(None, b, "heads", None, None),
        # (L, B, K-1, conv_dim): conv channels over TP
        "mamba_conv": pspec(None, b, None, "mlp"),
        # (L, B, H, dk, dv) wkv state: heads over TP
        "s": pspec(None, b, "heads", None, None),
        # (L, B, D) token-shift carries
        "x_tm": pspec(None, b, None),
        "x_cm": pspec(None, b, None),
    }
    return {name: table[name] for name in lm.cache_specs(cfg, 1, 8)}


def train_state_specs(cfg: ModelConfig):
    """(abstract_params, abstract_opt, param_specs, opt_specs) under the
    active mesh."""
    aparams = jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0))
    pspecs = param_pspecs(aparams)
    aopt = jax.eval_shape(adamw.init, aparams)
    ospecs = adamw.AdamWState(step=P(), m=pspecs, v=pspecs)
    return aparams, aopt, pspecs, ospecs
