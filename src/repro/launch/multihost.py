"""Multi-host bring-up glue for real TPU pods (launch scripts, deliverable e).

On a v5e pod each host owns 4-8 chips; this module is the thin layer between
the cluster scheduler (GKE/QR/Ray) and the SPMD program:

    # per host, under your scheduler:
    python -m repro.launch.multihost --coordinator $COORD:8476 \
        --num-hosts 64 --host-id $RANK -- \
        train --arch qwen2.5-14b --steps 10000 --ckpt-dir gs://...

Responsibilities:
  1. jax.distributed.initialize (device mesh spans all hosts),
  2. per-host data sharding (SyntheticLMStream(host_id, num_hosts) — swap in
     your tokenized-shard reader with the same interface),
  3. the ELASTIC loop: on a host failure the scheduler restarts survivors
     with a smaller --num-hosts; restore re-shards the last checkpoint onto
     the new mesh (CheckpointStore.restore(shardings=...)),
  4. straggler policy: BSP with per-step timeout; persistent stragglers are
     reported to the scheduler for replacement (the DFW-TRACE power method
     additionally tolerates in-step dropout via worker_weight masks).

On this CPU container the module is import-safe and the single-host path is
exercised by the test-suite; the distributed init is only taken when
--coordinator is given.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

import jax


def initialize(coordinator: Optional[str], num_hosts: int, host_id: int) -> None:
    """Bring up the jax distributed runtime (no-op single-host)."""
    if coordinator is None or num_hosts <= 1:
        return
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_hosts,
        process_id=host_id,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default=None, help="host:port of process 0")
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("command", choices=["train", "serve", "dryrun"])
    ap.add_argument("rest", nargs=argparse.REMAINDER)
    args = ap.parse_args()

    initialize(args.coordinator, args.num_hosts, args.host_id)
    if jax.process_index() == 0:
        print(f"[multihost] {jax.process_count()} hosts, "
              f"{len(jax.devices())} global devices")

    sys.argv = [args.command] + [a for a in args.rest if a != "--"]
    if args.command == "train":
        from . import train as mod
    elif args.command == "serve":
        from . import serve as mod
    else:
        from . import dryrun as mod
    mod.main()


if __name__ == "__main__":
    main()
