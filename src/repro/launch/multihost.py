"""Multi-host bring-up glue for real TPU pods (launch scripts, deliverable e).

On a v5e pod each host owns 4-8 chips; this module is the thin layer between
the cluster scheduler (GKE/QR/Ray) and the SPMD program:

    # per host, under your scheduler:
    python -m repro.launch.multihost --coordinator $COORD:8476 \
        --num-hosts 64 --host-id $RANK -- \
        train --arch qwen2.5-14b --steps 10000 --ckpt-dir gs://...

Responsibilities:
  1. jax.distributed.initialize (device mesh spans all hosts),
  2. per-host data sharding (SyntheticLMStream(host_id, num_hosts) — swap in
     your tokenized-shard reader with the same interface),
  3. the ELASTIC loop: on a host failure the scheduler restarts survivors
     with a smaller --num-hosts; restore re-shards the last checkpoint onto
     the new mesh (CheckpointStore.restore(shardings=...)),
  4. straggler policy: BSP with per-step timeout; persistent stragglers are
     reported to the scheduler for replacement (the DFW-TRACE power method
     additionally tolerates in-step dropout via worker_weight masks),
  5. comm-topology selection: ``host_topology()`` maps the process layout
     onto the ``repro.comm`` exchange graph — ``hier:<num_hosts>`` on a pod
     (exact psum stays on intra-host ICI, only the comm-encoded inter-group
     hop crosses DCN), ``flat`` single-host. The ``dfw`` subcommand runs a
     distributed DFW-Trace fit with it end to end.

On this CPU container the module is import-safe and the single-host path is
exercised by the test-suite; the distributed init is only taken when
--coordinator is given.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional

import jax


def initialize(coordinator: Optional[str], num_hosts: int, host_id: int) -> None:
    """Bring up the jax distributed runtime (no-op single-host)."""
    if coordinator is None or num_hosts <= 1:
        return
    jax.distributed.initialize(
        coordinator_address=coordinator,
        num_processes=num_hosts,
        process_id=host_id,
    )


def host_topology(num_hosts: Optional[int] = None) -> str:
    """The comm topology matching the process layout (``DFWConfig.topology``
    grammar): ``"hier:<num_hosts>"`` groups the mesh by host so the
    intra-group exact psum rides the fast intra-host interconnect and only
    the (compressible) inter-group hop crosses the host network;
    single-host is just ``"flat"``. ``num_hosts=None`` reads
    ``jax.process_count()`` — call after :func:`initialize`."""
    nh = jax.process_count() if num_hosts is None else int(num_hosts)
    return "flat" if nh <= 1 else f"hier:{nh}"


def _dfw_main() -> None:
    """Distributed DFW-Trace entry point: the topology API's pod consumer.

    Runs ``launch.dfw.fit`` over all visible devices with the topology
    derived from the host layout (override with --topology). The synthetic
    low-rank MTLS problem is a bring-up probe — swap in a real data loader
    for production runs; everything else (mesh, topology, comm encoding,
    checkpointing) is the production path.
    """
    import jax.numpy as jnp

    from ..core import tasks
    from . import dfw

    ap = argparse.ArgumentParser(prog="dfw")
    ap.add_argument("--epochs", type=int, default=20)
    ap.add_argument("--mu", type=float, default=1.0)
    ap.add_argument("--comm", default="dense", help="dense | int8 | topk:r")
    ap.add_argument("--topology", default="auto",
                    help="flat | ring | gossip:k | hier:g | auto (host layout)")
    ap.add_argument("--workers", type=int, default=None,
                    help="default: all visible devices")
    ap.add_argument("--samples", type=int, default=4096)
    ap.add_argument("--dim", type=int, default=64)
    ap.add_argument("--tasks", dest="m", type=int, default=48)
    ap.add_argument("--gap-tol", type=float, default=None)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    nw = args.workers if args.workers is not None else len(jax.devices())
    topology = host_topology() if args.topology == "auto" else args.topology
    key = jax.random.PRNGKey(7)
    kw, kx = jax.random.split(key)
    w_star = jax.random.normal(kw, (args.dim, args.m))
    w_star = w_star / jnp.linalg.norm(w_star, ord="nuc")
    n = (args.samples // nw) * nw
    x = jax.random.normal(kx, (n, args.dim))
    y = x @ w_star
    task = tasks.MultiTaskLeastSquares(d=args.dim, m=args.m)
    cfg = dfw.DFWConfig(
        mu=args.mu, num_epochs=args.epochs, step_size="linesearch",
        comm=args.comm, topology=topology, gap_tol=args.gap_tol,
        checkpoint_dir=args.ckpt_dir,
    )
    res = dfw.fit(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1),
                  num_workers=nw)
    if jax.process_index() == 0:
        print(  # REP006-ok: CLI subcommand summary — the terminal is the interface
            f"[multihost.dfw] workers={nw} topology={topology} "
            f"comm={args.comm} epochs_run={res.epochs_run} "
            f"final_loss={res.final_loss:.6f} "
            f"gap={res.history['gap'][-1]:.4f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--coordinator", default=None, help="host:port of process 0")
    ap.add_argument("--num-hosts", type=int, default=1)
    ap.add_argument("--host-id", type=int, default=0)
    ap.add_argument("command", choices=["train", "serve", "dryrun", "dfw"])
    ap.add_argument("rest", nargs=argparse.REMAINDER)
    args = ap.parse_args()

    initialize(args.coordinator, args.num_hosts, args.host_id)
    if jax.process_index() == 0:
        print(f"[multihost] {jax.process_count()} hosts, "
              f"{len(jax.devices())} global devices "
              f"(host_topology={host_topology()})")

    sys.argv = [args.command] + [a for a in args.rest if a != "--"]
    if args.command == "train":
        from . import train as mod
    elif args.command == "serve":
        from . import serve as mod
    elif args.command == "dfw":
        _dfw_main()
        return
    else:
        from . import dryrun as mod
    mod.main()


if __name__ == "__main__":
    main()
