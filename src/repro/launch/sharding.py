"""Logical-axis sharding rules and the mesh context used by the model zoo.

Models annotate tensors with *logical* dim names; the active rule set maps
them to mesh axes. Outside a mesh context every annotation is a no-op, so the
exact same model code runs single-device smoke tests and 512-chip dry-runs.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]

# Logical dim -> mesh axes. "fsdp" axes also carry the batch (ZeRO-3 style).
DEFAULT_RULES: Dict[str, Axis] = {
    "batch": ("pod", "data"),
    "batch_tp": ("pod", "data", "model"),  # batch over ALL axes (attention
    # fallback when head counts don't divide the model axis)
    "fsdp": ("pod", "data"),  # weight dim sharded over the DP axes
    "fsdp_embed": ("pod", "data"),  # embed/unembed weight dim (never "model",
    # which already carries their vocab dim)
    "seq": "data",  # context/sequence parallelism (long-context decode)
    "seq_tp": "model",  # KV-cache seq dim when kv-heads don't divide TP
    "seq_act": None,  # activation seq dim between blocks (SP profile: model)
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    "embed": None,  # d_model dim of activations: replicated
    "state": None,
}

# Sharding profiles. "tp" = Megatron tensor parallelism on the model axis
# (default). "sp" = sequence parallelism: activations are sharded on the
# SEQUENCE dim over the model axis, heads/mlp run locally, and parameters are
# ZeRO-3 sharded over every axis — eliminates the per-layer activation
# all-reduces entirely (the dominant baseline cost; see EXPERIMENTS.md §Perf).
PROFILES: Dict[str, Dict[str, Axis]] = {
    "tp": {},
    "sp": {
        "heads": None,
        "kv_heads": None,
        "mlp": None,
        "seq_act": "model",
        "fsdp": ("pod", "data", "model"),
    },
    # Megatron-style SP: TP inside blocks, sequence-sharded residual stream
    # between blocks (AG/RS pairs replace the activation all-reduces).
    "msp": {"seq_act": "model"},
}


def rules_for(profile: str) -> Dict[str, Axis]:
    rules = dict(DEFAULT_RULES)
    rules.update(PROFILES[profile])
    return rules


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Dict[str, Axis] = dict(DEFAULT_RULES)


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[Dict[str, Axis]] = None):
    """Activate a mesh + rule set for model tracing (and jax's mesh context)."""
    prev_mesh, prev_rules = _CTX.mesh, _CTX.rules
    _CTX.mesh = mesh
    merged = dict(DEFAULT_RULES)
    if rules:
        merged.update(rules)
    _CTX.rules = merged
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev_mesh, prev_rules


def active_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _resolve(logical: Sequence[Optional[str]]) -> P:
    axes_in_mesh = set(_CTX.mesh.axis_names) if _CTX.mesh is not None else set()
    out = []
    used: set = set()  # a mesh axis may appear at most once per spec; under
    # mixed profiles (e.g. msp: heads AND seq_act -> model) the EARLIER
    # logical dim wins and later mentions resolve to None.
    for name in logical:
        ax = _CTX.rules.get(name) if name else None
        if ax is None:
            out.append(None)
            continue
        if isinstance(ax, str):
            ax = (ax,)
        ax = tuple(a for a in ax if a in axes_in_mesh and a not in used)
        used.update(ax)
        out.append(ax if len(ax) > 1 else (ax[0] if ax else None))
    return P(*out)


def pspec(*logical: Optional[str]) -> P:
    """PartitionSpec for the given logical dims under the active rules."""
    return _resolve(logical)


def shard(x: jax.Array, *logical: Optional[str]) -> jax.Array:
    """with_sharding_constraint under the active mesh; identity without one."""
    if _CTX.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, _resolve(logical))
    )


def named_sharding(*logical: Optional[str]) -> Optional[NamedSharding]:
    if _CTX.mesh is None:
        return None
    return NamedSharding(_CTX.mesh, _resolve(logical))


def data_axes() -> Tuple[str, ...]:
    """Mesh axes carrying the batch (for psums in manual-collective regions)."""
    ax = _CTX.rules.get("batch")
    if ax is None or _CTX.mesh is None:
        return ()
    if isinstance(ax, str):
        ax = (ax,)
    return tuple(a for a in ax if a in _CTX.mesh.axis_names)


def axes_size(logical: str) -> int:
    """Product of mesh-axis sizes a logical dim maps to (1 without a mesh)."""
    if _CTX.mesh is None:
        return 1
    ax = _CTX.rules.get(logical)
    if ax is None:
        return 1
    if isinstance(ax, str):
        ax = (ax,)
    n = 1
    for a in ax:
        if a in _CTX.mesh.axis_names:
            n *= _CTX.mesh.shape[a]
    return n


def seq_axes() -> Tuple[str, ...]:
    """Mesh axes carrying the sequence dim (context parallelism)."""
    ax = _CTX.rules.get("seq")
    if ax is None or _CTX.mesh is None:
        return ()
    if isinstance(ax, str):
        ax = (ax,)
    return tuple(a for a in ax if a in _CTX.mesh.axis_names)


def model_axes() -> Tuple[str, ...]:
    ax = _CTX.rules.get("expert")
    if ax is None or _CTX.mesh is None:
        return ()
    if isinstance(ax, str):
        ax = (ax,)
    return tuple(a for a in ax if a in _CTX.mesh.axis_names)
