"""Exchange graphs for the distributed power method — the *where* of comm.

A ``Reducer`` (``comm/base.py``) decides how one collective's bytes are
encoded; a ``Topology`` decides what graph those bytes flow over. The two
axes compose: every topology routes its expensive hop through a reducer, so
``topology="hier:2", comm="int8"`` means "exact f32 psum inside each group,
quantized exchange across groups".

Three graphs (spec grammar in ``repro.specs.parse_topology``):

``flat``
    One global all-reduce domain — byte-for-byte the paper's BSP master.
    ``FlatTopology(reducer).all_reduce`` *is* ``reducer.exchange``, so
    installing the default ``flat``/``dense`` pair leaves the legacy HLO
    untouched.

``ring`` / ``gossip:k``
    Master-less neighbor averaging (Bellet et al., arXiv:1404.2644): no
    global collective at all. Each mixing round every worker replaces its
    value with the uniform average of itself and its k ring neighbors
    (offsets ±1..±k/2) moved via ``ppermute``; after R rounds each node
    holds ``(W^R x)_i`` for the doubly-stochastic circulant W, and
    ``N * (W^R x)_i`` is its *local estimate* of the global sum. Estimates
    differ per node by O(λ₂^R) where λ₂ is W's second eigenvalue — the
    default R is auto-sized from λ₂ so the consensus error lands at
    ``CONSENSUS_TARGET``. Downstream quantities (singular vectors, duality
    gaps) become per-node; the driver keeps per-node iterates and certifies
    convergence with the *worst* per-node gap (a valid global certificate
    at consensus, pinned by ``tests/test_topology.py``).

``hier:<g>``
    Two-level reduce for multi-host meshes (``launch/multihost.py``): an
    exact dense psum inside each of the g groups (the cheap intra-host hop)
    followed by the installed reducer exchanged across groups only (XLA
    ``axis_index_groups``), so compression spends its noise budget where
    the bytes are expensive. With the dense reducer the result equals the
    flat psum up to f32 re-association (bit-exact when every partial sum is
    representable, e.g. integer-valued inputs — pinned in tests).

``Topology.exchange`` has the exact ``Reducer.exchange`` signature, so the
power method treats a topology as "the comm object" without branching; the
extra surface is ``rounds_per_exchange``, per-hop byte accounting
(``hop_wire_bytes``), and an HLO-checkable ``collective_contract``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp

from ..specs import SpecError, TopologySpec, parse_topology
from . import base
from .base import AxisName, PyTree, Reducer

#: Target per-node consensus error (relative to the true mean) that the
#: auto-sized gossip round count R aims for: R = ceil(log target / log λ₂).
#: 1e-2 keeps the LMO direction error inside the multiplicative-error regime
#: of the paper's Theorem 2 while staying ~20 rounds on an 8-ring.
CONSENSUS_TARGET = 1e-2


def _merge_counts(*dicts: Dict[str, float]) -> Dict[str, float]:
    out: Dict[str, float] = {}
    for d in dicts:
        for k, v in d.items():
            out[k] = out.get(k, 0.0) + float(v)
    return out


def _reducer_collective_counts(reducer: Reducer) -> Dict[str, float]:
    """HLO collective ops emitted by ONE ``reducer.exchange`` (the vocabulary
    of ``analysis.hlo.COLLECTIVES``)."""
    spec = reducer.spec
    if spec == "dense":
        return {"all-reduce": 1.0}
    if spec == "int8":
        return {"all-reduce": 2.0}  # f32 scale pmax + s8 psum
    if spec.startswith("topk:"):
        return {"all-gather": 2.0}  # int32 indices + f32 values
    raise ValueError(f"no collective profile for reducer spec {spec!r}")


def _single_axis(axis_name: AxisName) -> str:
    """Gossip/hier address workers by index along ONE mesh axis."""
    if isinstance(axis_name, str):
        return axis_name
    names = tuple(axis_name)
    if len(names) != 1:
        raise ValueError(
            f"topology collectives need a single mesh axis, got {names!r}"
        )
    return names[0]


class Topology:
    """Interface of an exchange graph (see module docstring).

    ``spec`` is the parseable name (``make_topology(t.spec, ...)``
    round-trips); ``reducer`` is the encoding installed on the expensive
    hop. ``exchange`` aliases ``all_reduce`` with the full
    ``Reducer.exchange`` signature so a ``Topology`` can stand wherever a
    reducer is accepted (the power method's ``reducer=`` slot).
    """

    spec: str = "base"
    reducer: Reducer
    num_workers: int = 1

    #: True when ``all_reduce`` returns *per-node estimates* (gossip) rather
    #: than one replicated value — the driver must then carry per-node
    #: iterates and aggregate gap certificates with a worst-case pmax.
    per_node: bool = False

    @property
    def rounds_per_exchange(self) -> int:
        """Sequential collective rounds issued by one ``all_reduce``."""
        raise NotImplementedError

    def init_state(self, d: int, m: int) -> PyTree:
        return self.reducer.init_state(d, m)

    def state_spec(self, d: int, m: int) -> PyTree:
        return self.reducer.state_spec(d, m)

    def all_reduce(
        self,
        x: jax.Array,
        state: PyTree,
        *,
        slot: str,
        key: jax.Array,
        axis_name: AxisName = None,
        weight=None,
    ) -> tuple:
        """Estimate the global sum of ``x`` over ``axis_name`` through this
        graph. Same contract as ``Reducer.exchange`` (slot/key/weight
        semantics, ``(estimate, new_state)`` return); for a per-node
        topology the estimate differs across workers."""
        raise NotImplementedError

    def exchange(self, x, state, *, slot, key, axis_name=None, weight=None,
                 groups=None):
        if groups is not None:
            raise ValueError(
                "Topology.exchange does not accept groups= — the graph IS "
                "the grouping"
            )
        return self.all_reduce(
            x, state, slot=slot, key=key, axis_name=axis_name, weight=weight
        )

    def collective_counts(self, num_exchanges: int = 1) -> Dict[str, float]:
        """Executed HLO collective counts for ``num_exchanges`` calls."""
        raise NotImplementedError

    def hop_wire_bytes(self, dim: int) -> Dict[str, int]:
        """Analytic wire bytes of one exchange of a (dim,) f32 vector,
        broken down by hop (``global`` / ``neighbor`` / ``intra`` +
        ``inter``) — feeds the engine's per-hop comm counters."""
        raise NotImplementedError

    def wire_bytes(self, dim: int, num_workers: int) -> int:
        # Reducer-compatible total so existing accounting keeps working.
        return sum(self.hop_wire_bytes(dim).values())

    def collective_contract(
        self, num_exchanges: int = 1, *, name: Optional[str] = None
    ):
        """An ``analysis.contracts.Contract`` pinning exactly the collectives
        this graph is allowed to emit over ``num_exchanges`` exchanges."""
        from ..analysis import contracts  # local: analysis is a heavier layer

        counts = {
            k: v * num_exchanges
            for k, v in self.collective_counts(1).items()
        }
        return contracts.Contract(
            name=name or f"comm.topology[{self.spec}]",
            collective_counts=counts,
        )


@dataclasses.dataclass(frozen=True)
class FlatTopology(Topology):
    """One global all-reduce domain — pure delegation to the reducer, so the
    default ``flat`` routing is bit-exact legacy behavior."""

    reducer: Reducer = dataclasses.field(default_factory=base.DenseReducer)
    num_workers: int = 1
    spec: str = "flat"
    per_node = False

    @property
    def rounds_per_exchange(self) -> int:
        return 1

    def all_reduce(self, x, state, *, slot, key, axis_name=None, weight=None):
        return self.reducer.exchange(
            x, state, slot=slot, key=key, axis_name=axis_name, weight=weight
        )

    def collective_counts(self, num_exchanges: int = 1) -> Dict[str, float]:
        return {
            k: v * num_exchanges
            for k, v in _reducer_collective_counts(self.reducer).items()
        }

    def hop_wire_bytes(self, dim: int) -> Dict[str, int]:
        return {"global": self.reducer.wire_bytes(dim, self.num_workers)}


def gossip_lambda2(num_workers: int, degree: int) -> float:
    """Second-largest |eigenvalue| of the uniform gossip mixing matrix
    ``W = (I + Σ_o S_o) / (degree+1)`` over ring offsets ±1..±degree/2
    (circulant, so the spectrum is closed-form). Governs the per-round
    consensus contraction: error ∝ λ₂^rounds."""
    half = degree // 2
    lam2 = 0.0
    for j in range(1, num_workers):
        lam = (
            1.0
            + sum(
                2.0 * math.cos(2.0 * math.pi * o * j / num_workers)
                for o in range(1, half + 1)
            )
        ) / (degree + 1)
        lam2 = max(lam2, abs(lam))
    return lam2


def default_gossip_rounds(num_workers: int, degree: int) -> int:
    """Rounds R with λ₂^R <= CONSENSUS_TARGET (min 1; 1 when the graph is
    complete and one round already averages everything)."""
    if num_workers <= 1:
        return 1
    lam2 = gossip_lambda2(num_workers, degree)
    if lam2 <= 0.0:
        return 1
    return max(1, math.ceil(math.log(CONSENSUS_TARGET) / math.log(lam2)))


@dataclasses.dataclass(frozen=True)
class GossipTopology(Topology):
    """Master-less k-regular gossip over ``ppermute`` neighbor exchange.

    ``all_reduce`` returns each node's own estimate ``N * (W^R x)_node`` of
    the global sum (unbiased across nodes; per-node deviation O(λ₂^R)).
    Serial (``axis_name=None``) it is the identity — one node is its own
    consensus — so serial trajectories match ``flat``/``dense`` exactly.
    """

    num_workers: int = 1
    degree: int = 2
    rounds: int = 1
    reducer: Reducer = dataclasses.field(default_factory=base.DenseReducer)
    spec: str = "ring"
    per_node = True

    @property
    def rounds_per_exchange(self) -> int:
        return self.rounds

    def _offsets(self) -> List[int]:
        half = self.degree // 2
        return [o for i in range(1, half + 1) for o in (i, -i)]

    def all_reduce(self, x, state, *, slot, key, axis_name=None, weight=None):
        # weight is ignored beyond the caller's pre-scaling of x: mixing is
        # linear, so the estimate stays an unbiased image of the masked sum.
        if axis_name is None:
            return x, state
        name = _single_axis(axis_name)
        nw = self.num_workers
        offsets = self._offsets()
        inv = jnp.float32(1.0 / (len(offsets) + 1))
        for _ in range(self.rounds):
            acc = x
            for o in offsets:
                perm = [(i, (i + o) % nw) for i in range(nw)]
                acc = acc + jax.lax.ppermute(x, name, perm)
            x = acc * inv
        return jnp.float32(nw) * x, state

    def collective_counts(self, num_exchanges: int = 1) -> Dict[str, float]:
        return {
            "collective-permute": float(  # REP002-ok: host ints, analytic count
                num_exchanges * self.rounds * self.degree
            )
        }

    def hop_wire_bytes(self, dim: int) -> Dict[str, int]:
        # Each ppermute moves the full f32 vector once (1x wire factor).
        return {"neighbor": self.rounds * self.degree * 4 * dim}


@dataclasses.dataclass(frozen=True)
class HierTopology(Topology):
    """Two-level reduce: exact psum inside each of ``groups`` contiguous
    groups, then the installed reducer exchanged across groups only.

    The inner reducer is built for a world of ``groups`` participants (one
    delegate per group — e.g. int8's overflow budget is 127 // g, not
    127 // N), and receives ``groups=`` = the cross-group partition, so its
    collectives never leave the cheap intra hop unencoded bytes to carry.
    """

    num_workers: int = 2
    groups: int = 2
    reducer: Reducer = dataclasses.field(default_factory=base.DenseReducer)
    spec: str = "hier:2"
    per_node = False

    @property
    def group_size(self) -> int:
        return self.num_workers // self.groups

    def _intra_groups(self) -> List[List[int]]:
        s = self.group_size
        return [[g * s + j for j in range(s)] for g in range(self.groups)]

    def _cross_groups(self) -> List[List[int]]:
        s = self.group_size
        return [[j + g * s for g in range(self.groups)] for j in range(s)]

    @property
    def rounds_per_exchange(self) -> int:
        return 2 if self.group_size > 1 else 1

    def all_reduce(self, x, state, *, slot, key, axis_name=None, weight=None):
        if axis_name is None:
            # Serial simulation: the intra sum over one worker is identity;
            # the inter hop still applies the reducer's encoding noise.
            return self.reducer.exchange(
                x, state, slot=slot, key=key, axis_name=None, weight=weight
            )
        if self.group_size > 1:
            x = base.psum(x, axis_name, self._intra_groups())
        # Every worker holds its group's partial sum (replicated within the
        # group), so all group_size cross-exchanges compute the same global
        # sum — the result lands replicated without a broadcast hop.
        return self.reducer.exchange(
            x, state, slot=slot, key=key, axis_name=axis_name, weight=weight,
            groups=self._cross_groups(),
        )

    def collective_counts(self, num_exchanges: int = 1) -> Dict[str, float]:
        per = _reducer_collective_counts(self.reducer)
        if self.group_size > 1:
            per = _merge_counts(per, {"all-reduce": 1.0})
        return {k: v * num_exchanges for k, v in per.items()}

    def hop_wire_bytes(self, dim: int) -> Dict[str, int]:
        hops = {"inter": self.reducer.wire_bytes(dim, self.groups)}
        if self.group_size > 1:
            hops["intra"] = 2 * 4 * dim  # ring all-reduce inside the group
        return hops


def make_topology(
    spec,
    *,
    num_workers: int = 1,
    comm: str = "dense",
    rounds: Optional[int] = None,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
) -> Topology:
    """Parse a ``topology=`` spec and build the graph for ``num_workers``.

    ``comm`` is the encoding spec for the expensive hop (the global
    collective for ``flat``, the inter-group exchange for ``hier`` — where
    the reducer is sized to the *group count*, not the world). ``rounds``
    overrides the auto-sized gossip mixing-round count (default: enough for
    λ₂^R <= CONSENSUS_TARGET). Worker-count constraints (degree < N, N
    divisible by g) are validated here; the string grammar itself lives in
    ``repro.specs.parse_topology``.
    """
    t: TopologySpec = parse_topology(spec)
    if t.kind == "flat":
        reducer = base.make_reducer(
            comm, num_workers=num_workers,
            use_pallas=use_pallas, interpret=interpret,
        )
        return FlatTopology(
            reducer=reducer, num_workers=num_workers, spec=t.spec
        )
    if t.kind == "gossip":
        if num_workers > 1 and t.degree >= num_workers:
            raise SpecError(
                f"topology {t.spec!r}: gossip degree {t.degree} needs more "
                f"than {t.degree} workers, got num_workers={num_workers}"
            )
        if base.parse_comm(comm).kind != "dense":
            raise SpecError(
                f"topology {t.spec!r} requires comm 'dense' (gossip "
                f"exchanges are neighbor averages, not compressible "
                f"collectives), got comm {comm!r}"
            )
        r = rounds if rounds is not None else default_gossip_rounds(
            num_workers, t.degree
        )
        if r < 1:
            raise SpecError(
                f"topology {t.spec!r}: rounds must be >= 1, got {r}"
            )
        return GossipTopology(
            num_workers=num_workers, degree=t.degree, rounds=r, spec=t.spec
        )
    # hier (num_workers == 1 is the serial simulation: no intra hop, the
    # reducer still encodes at group width so serial mirrors the wire noise)
    if num_workers > 1 and num_workers % t.groups != 0:
        raise SpecError(
            f"topology {t.spec!r}: num_workers={num_workers} is not "
            f"divisible into {t.groups} equal groups"
        )
    reducer = base.make_reducer(
        comm, num_workers=t.groups,
        use_pallas=use_pallas, interpret=interpret,
    )
    return HierTopology(
        num_workers=num_workers, groups=t.groups, reducer=reducer, spec=t.spec
    )
