"""int8 stochastic-rounding reducer: quantize -> s8 psum -> dequantize.

Per ``exchange`` of a (dim,) f32 vector:

1. every worker computes its local absmax and a scalar f32 ``pmax`` makes it
   the *shared* per-vector scale s (the "scale exchange" — 8 wire bytes),
2. the local contribution is stochastically rounded onto the integer grid
   ``[-b, b]`` with ``b = 127 // N`` via the fused ``kernels/quantize``
   Pallas kernel (jnp ref off-TPU),
3. one s8 all-reduce sums the integers — ``2 * dim`` wire bytes instead of
   the dense ``8 * dim`` (4x lighter; the scale pmax is amortized),
4. the sum is mapped back to f32 by ``dequantize`` (* s / b).

Unbiasedness: stochastic rounding gives ``E[q_j] = x_j * b / s`` exactly
(noise uniform in [0, 1)), so ``E[dequant(sum_j q_j)] = sum_j x_j`` — the
LMO direction estimate is noisier but not biased, which is the regime the
paper's Theorem 2 (multiplicative LMO error) already covers.

Overflow safety: ``|x_j| <= s`` by construction of the shared scale, so every
worker's integers lie in [-b, b] and any partial sum of the ring all-reduce
is bounded by ``N * b <= 127`` — the s8 wire dtype cannot wrap.

The sacrifice is log2(N) bits of per-worker resolution (b = 15 at N = 8).
The power method tolerates it: each iteration renormalizes, and FW corrects
residual direction error over epochs.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from ..kernels.quantize import ops as qops
from . import base


@dataclasses.dataclass(frozen=True)
class Int8Reducer(base.Reducer):
    """Stateless (no error feedback): quantization noise is zero-mean, so
    there is no systematic residual to feed back. Its ``state_spec`` is
    therefore ``()`` — checkpoints save nothing for it, and a bit-exact
    resume needs only the carried PRNG key (the stochastic-rounding noise
    is keyed off the epoch counter folded into the run key)."""

    num_workers: int = 1
    use_pallas: Optional[bool] = None
    interpret: bool = False

    def __post_init__(self):
        if not 1 <= self.num_workers <= 127:
            raise ValueError(
                f"int8 reducer supports 1..127 workers (got {self.num_workers}): "
                "the per-worker budget 127 // N must stay >= 1"
            )

    @property
    def spec(self) -> str:  # type: ignore[override]
        return "int8"

    @property
    def budget(self) -> int:
        return max(1, 127 // self.num_workers)

    def exchange(self, x, state, *, slot, key, axis_name=None, weight=None,
                 groups=None):
        # weight is ignored: x of a masked worker is exactly zero, which
        # quantizes to zero — no stale state to guard (stateless).
        # groups restricts both the scale pmax and the s8 psum to each
        # worker's own axis_index_group (the hier inter-group hop); the
        # shared-scale overflow argument holds per group since the budget is
        # sized to the group width.
        x = x.astype(jnp.float32)
        scale = base.pmax(jnp.max(jnp.abs(x)), axis_name, groups)
        noise = jax.random.uniform(
            base.fold_axis_index(key, axis_name), x.shape, jnp.float32
        )
        kw = dict(
            budget=self.budget, use_pallas=self.use_pallas, interpret=self.interpret
        )
        q = qops.quantize(x, noise, scale, **kw)
        total = base.psum(q, axis_name, groups)  # s8 on the wire
        return qops.dequantize(total, scale, **kw), state

    def wire_bytes(self, dim: int, num_workers: int) -> int:
        # s8 ring all-reduce (2x) + the f32 scalar scale pmax (2x * 4B)
        return 2 * 1 * dim + 2 * 4


def verify_quantize_kernels(
    key: jax.Array,
    *,
    num_workers: int = 8,
    dim: int = 384,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
    tol: float = 1e-6,
) -> float:
    """Startup check (same role as ``launch/dfw.verify_kernelized``): the
    dispatched quantize/dequantize pair must match the jnp reference on a
    random probe — both paths consume the same explicit noise, so agreement
    is exact up to f32 rounding. Returns the max abs error observed."""
    from ..kernels.quantize import ref as qref

    b = max(1, 127 // num_workers)
    kx, kn = jax.random.split(key)
    x = jax.random.normal(kx, (dim,), jnp.float32)
    noise = jax.random.uniform(kn, (dim,), jnp.float32)
    scale = jnp.max(jnp.abs(x))
    q_got = qops.quantize(
        x, noise, scale, budget=b, use_pallas=use_pallas, interpret=interpret
    )
    q_want = qref.quantize(x, noise, scale, b)
    d_got = qops.dequantize(
        q_got, scale, budget=b, use_pallas=use_pallas, interpret=interpret
    )
    # One explicit batched pull for both error scalars (REP002): float() on
    # each jnp reduction would block on two implicit device->host syncs.
    err_q, err_d = jax.device_get((
        jnp.max(jnp.abs(q_got.astype(jnp.int32) - q_want.astype(jnp.int32))),
        jnp.max(jnp.abs(d_got - qref.dequantize(q_want, scale, b))),
    ))
    err = max(float(err_q), float(err_d))
    if err > tol:
        raise AssertionError(
            f"quantize kernel diverges from jnp reference: max abs err {err:.3e} "
            f"> tol {tol:.1e} (budget={b})"
        )
    return err
