"""Top-k sparsifying reducer with per-worker error feedback.

Each worker sends only the k largest-magnitude components of its corrected
contribution ``c = x + e`` (e = residual of everything it never sent); the
master sum is reassembled from an index+value all-gather and the unsent mass
``c - topk(c)`` becomes the next residual. Error feedback is what makes
aggressive sparsification safe: the compression error is *fed back*, not
dropped, so the cumulative transmitted signal tracks the cumulative true
signal (classic EF-SGD argument — for a constant input the deviation of the
running mean from the truth decays as O(1/T); ``tests/test_comm.py`` pins
both properties).

Wire cost per exchange: two all-gathers of (N, k) — int32 indices + f32
values,
``8 * N * k`` bytes versus the dense ``8 * dim``. Compression wins while
``N * k < dim``: right for the big (d,) u-vectors, marginal for small m.

The residuals are genuinely per-worker state: under shard_map every worker
carries its own {"u": (d,), "v": (m,)} buffers, threaded through the epoch as
part of the sharded state pytree (``launch/dfw`` shards the leading worker
axis) and across epochs by the driver loop.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

import jax
import jax.numpy as jnp

from . import base


@dataclasses.dataclass(frozen=True)
class TopKReducer(base.Reducer):
    k: int = 32

    @property
    def spec(self) -> str:  # type: ignore[override]
        return f"topk:{self.k}"

    def init_state(self, d: int, m: int) -> Dict[str, jax.Array]:
        return {
            "u": jnp.zeros((d,), jnp.float32),
            "v": jnp.zeros((m,), jnp.float32),
        }

    def state_spec(self, d: int, m: int) -> Dict[str, jax.ShapeDtypeStruct]:
        # One worker's error-feedback residuals. Checkpoints carry these per
        # worker (leading worker axis); a remesh re-initializes them — the
        # unsent mass they hold belongs to a data shard that no longer
        # exists, and EF re-accumulates it within a few rounds.
        return {
            "u": jax.ShapeDtypeStruct((d,), jnp.float32),
            "v": jax.ShapeDtypeStruct((m,), jnp.float32),
        }

    def exchange(self, x, state, *, slot, key, axis_name=None, weight=None,
                 groups=None):
        e = state[slot]
        c = x.astype(jnp.float32) + e
        k = min(self.k, c.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(c), k)
        vals = jnp.take(c, idx)  # signed top-k components
        if weight is not None:
            # Straggler mask: a sampled-out worker (weight 0) must send
            # nothing — its x is zero but its residual e is not, and leaking
            # top-k(e) into the aggregate would bias the reweighted sum. It
            # also keeps e frozen: it didn't transmit anything this round.
            alive = jnp.asarray(weight, jnp.float32) > 0.0
            vals = jnp.where(alive, vals, 0.0)
        sparse_local = jnp.zeros_like(c).at[idx].set(vals)
        new_state = dict(state)
        new_e = c - sparse_local  # unsent mass -> next round
        if weight is not None:
            new_e = jnp.where(alive, new_e, e)
        new_state[slot] = new_e
        if axis_name is None:
            return sparse_local, new_state
        # index+value all-gather, then every worker reassembles the sum;
        # duplicate indices across workers accumulate via scatter-add.
        # groups narrows the gather to this worker's axis_index_group (the
        # hier inter-group hop): N becomes the group width.
        gi = jax.lax.all_gather(idx, axis_name, axis_index_groups=groups)
        gv = jax.lax.all_gather(vals, axis_name, axis_index_groups=groups)
        total = jnp.zeros_like(c).at[gi.reshape(-1)].add(gv.reshape(-1))
        return total, new_state

    def wire_bytes(self, dim: int, num_workers: int) -> int:
        k = min(self.k, dim)
        return num_workers * k * (4 + 4)  # gathered int32 idx + f32 vals
