"""Pluggable communication subsystem for the distributed power method.

Two orthogonal axes: the ``Reducer`` (``base.py``) encodes one collective's
bytes (``int8.py``/``topk.py`` are the compressed implementations); the
``Topology`` (``topology.py``) decides what graph those bytes flow over
(flat psum master, master-less gossip, hierarchical two-level reduce). See
``docs/ALGORITHMS.md`` ("Communication layer" and "Communication
topologies") for the extended Table-1 and when compression is safe.
"""
from . import base, int8, topk, topology
from .base import DenseReducer, Reducer, make_reducer
from .int8 import Int8Reducer, verify_quantize_kernels
from .topk import TopKReducer
from .topology import (
    FlatTopology,
    GossipTopology,
    HierTopology,
    Topology,
    make_topology,
)

__all__ = [
    "base",
    "int8",
    "topk",
    "topology",
    "Reducer",
    "DenseReducer",
    "Int8Reducer",
    "TopKReducer",
    "Topology",
    "FlatTopology",
    "GossipTopology",
    "HierTopology",
    "make_reducer",
    "make_topology",
    "verify_quantize_kernels",
]
