"""Pluggable communication subsystem for the distributed power method.

See ``base.py`` for the reducer contract, ``int8.py``/``topk.py`` for the
compressed implementations, and ``docs/ALGORITHMS.md`` ("Communication
layer") for the extended Table-1 and when compression is safe.
"""
from . import base, int8, topk
from .base import DenseReducer, Reducer, make_reducer
from .int8 import Int8Reducer, verify_quantize_kernels
from .topk import TopKReducer

__all__ = [
    "base",
    "int8",
    "topk",
    "Reducer",
    "DenseReducer",
    "Int8Reducer",
    "TopKReducer",
    "make_reducer",
    "verify_quantize_kernels",
]
