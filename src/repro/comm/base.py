"""Pluggable collectives for the distributed power method.

The paper's whole efficiency argument (Table 1) is that only O(d+m)
power-iteration vectors cross the wire per round. A ``Reducer`` makes the
*encoding* of those vectors a tunable axis: the power method asks it to sum
the workers' local contributions (``A_j v`` / ``A_j^T u``) over the data
mesh, and the reducer decides what actually hits the network —

    ``dense``    exact f32 psum (today's behavior, the paper's master),
    ``int8``     stochastic-rounding quantize -> s8 psum -> dequantize,
                 one f32 scale pmax per vector (``comm/int8.py``),
    ``topk:r``   magnitude sparsification with per-worker error-feedback
                 residuals, index+value all-gather (``comm/topk.py``).

Only the power-iteration *vector* psums are rerouted; the epoch's scalar
psums (loss, <W, grad>, line-search terms) stay exact — compressing a
handful of f32 scalars saves nothing and silently corrupts step sizes and
the duality-gap certificate.

State contract: ``init_state(d, m)`` returns a per-worker pytree (empty for
stateless reducers) that the caller threads through every ``exchange`` call —
through the epoch's ``fori_loop`` and across epochs as part of the sharded
state (each worker keeps its own residuals). ``exchange`` is pure and works
serially (``axis_name=None``: the "sum" over one worker, with compression
noise still applied — the serial run simulates the distributed encoding) and
inside shard_map.

The reducer answers *how bytes are encoded*; *what graph they flow over* is
the ``Topology`` axis (``comm/topology.py``), whose ``all_reduce`` mirrors
``exchange`` — a ``hier:<g>`` topology runs a reducer on its inter-group hop
only, by passing ``groups=`` (XLA ``axis_index_groups``) through the helpers
below.
"""
from __future__ import annotations

import warnings
from typing import Any, List, Optional, Sequence, Union

import jax

from ..specs import CommSpec, parse_comm  # noqa: F401

AxisName = Optional[Union[str, Sequence[str]]]
Groups = Optional[List[List[int]]]
PyTree = Any


class Reducer:
    """Interface of a compressed collective (see module docstring).

    ``spec`` is the parseable name (``make_reducer(r.spec)`` round-trips).
    """

    spec: str = "base"

    def init_state(self, d: int, m: int) -> PyTree:
        """Per-worker reducer state for (d,)-slot "u" and (m,)-slot "v"."""
        return ()

    def state_spec(self, d: int, m: int) -> PyTree:
        """Structure/shape/dtype of ONE worker's state, as a pytree of
        ``jax.ShapeDtypeStruct`` — no allocation. This is the reducer's
        save/restore contract: checkpoints store the state with a leading
        worker axis prepended to every leaf, restore skeletons are built
        from this spec, and an elastic remesh (worker count change)
        re-*initializes* via ``init_state`` rather than re-sharding —
        residuals are per-worker quantities that cannot follow a data
        repartition. The default derives the spec from ``init_state``;
        stateful reducers should override it to avoid the allocation."""
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
            self.init_state(d, m),
        )

    def exchange(
        self,
        x: jax.Array,
        state: PyTree,
        *,
        slot: str,
        key: jax.Array,
        axis_name: AxisName = None,
        weight=None,
        groups: Groups = None,
    ) -> tuple:
        """Sum local contributions ``x`` over ``axis_name``.

        ``slot`` ("u" | "v") names which per-shape buffer of ``state``
        belongs to this call; ``key`` feeds stochastic encodings and must
        differ per call (the caller folds the iteration index in). Returns
        ``(global_sum_estimate, new_state)``.

        ``weight`` is the caller's straggler mask for this worker (``x`` is
        already scaled by it; ``None`` means full participation). Stateless
        reducers can ignore it — a masked worker's ``x`` is exactly zero —
        but *stateful* ones must: a sampled-out worker has to contribute
        nothing this round (not its stale residual) and leave its state
        untouched, or the driver's unbiased-reweighting argument breaks.

        ``groups`` (XLA ``axis_index_groups``: a partition of the axis
        indices) restricts the sum to each worker's own group — how a
        ``hier`` topology runs the encoded exchange on the inter-group hop
        only. ``None`` sums over the whole axis.
        """
        raise NotImplementedError

    def reduce(self, x, state, *, slot, key, axis_name=None, weight=None,
               groups=None):
        """Deprecated pre-topology name for :meth:`exchange` (warns once)."""
        _warn_reduce_deprecated()
        return self.exchange(
            x, state, slot=slot, key=key, axis_name=axis_name, weight=weight,
            groups=groups,
        )

    def wire_bytes(self, dim: int, num_workers: int) -> int:
        """Analytic wire bytes of one ``reduce`` of a (dim,) f32 vector
        (ring all-reduce factor 2x, all-gather 1x of the gathered shape) —
        the extended-Table-1 entries; ``repro.analysis.hlo`` measures the
        same convention."""
        raise NotImplementedError


_REDUCE_DEPRECATION_WARNED = False


def _warn_reduce_deprecated() -> None:
    # Warn once per process, not per call: ``reduce`` sits inside the power
    # method's fori_loop, and a warning per trace step would bury the signal.
    global _REDUCE_DEPRECATION_WARNED
    if not _REDUCE_DEPRECATION_WARNED:
        _REDUCE_DEPRECATION_WARNED = True
        warnings.warn(
            "Reducer.reduce(...) is deprecated; call Reducer.exchange(...) "
            "(same signature — renamed to mirror Topology.all_reduce)",
            DeprecationWarning,
            stacklevel=3,
        )


def psum(x: jax.Array, axis_name: AxisName, groups: Groups = None) -> jax.Array:
    if axis_name is None:
        return x
    return jax.lax.psum(x, axis_name, axis_index_groups=groups)


def pmax(x: jax.Array, axis_name: AxisName, groups: Groups = None) -> jax.Array:
    if axis_name is None:
        return x
    return jax.lax.pmax(x, axis_name, axis_index_groups=groups)


def fold_axis_index(key: jax.Array, axis_name: AxisName) -> jax.Array:
    """Decorrelate per-worker randomness: fold each mesh axis index into the
    (replicated) key. No-op outside shard_map."""
    if axis_name is None:
        return key
    names = (axis_name,) if isinstance(axis_name, str) else tuple(axis_name)
    for name in names:
        key = jax.random.fold_in(key, jax.lax.axis_index(name))
    return key


class DenseReducer(Reducer):
    """Exact f32 psum — byte-for-byte the paper's master aggregate.

    This is the **default** reducer: the epoch carry
    (``core/frank_wolfe.EpochCarry``) always threads a ``comm_state``, and
    dense's is the empty pytree ``()``, so the serial and sharded drivers
    run one uniform code path under every encoding (``comm="dense"`` routes
    here; its ``reduce`` *is* ``jax.lax.psum``, so trajectories are exact).
    The plumbing itself is validated bit-for-bit against a raw-psum oracle
    in ``tests/test_comm.py``.
    """

    spec = "dense"

    def exchange(self, x, state, *, slot, key, axis_name=None, weight=None,
                 groups=None):
        return psum(x, axis_name, groups), state

    def wire_bytes(self, dim: int, num_workers: int) -> int:
        return 2 * 4 * dim  # ring all-reduce: 2x the f32 vector


def make_reducer(
    spec: str,
    *,
    num_workers: int = 1,
    use_pallas: Optional[bool] = None,
    interpret: bool = False,
) -> Reducer:
    """Parse a ``comm=`` spec into a reducer.

    - ``"dense"``   exact psum
    - ``"int8"``    stochastic-rounding s8 psum (needs ``num_workers`` to
                    size the per-worker integer budget 127 // N)
    - ``"topk:r"``  keep the r largest-|.| components per vector, error
                    feedback for the rest

    ``use_pallas``/``interpret`` route the int8 quantize/dequantize pair
    through the ``kernels/quantize`` Pallas kernels (TPU) or the jnp ref.

    The string grammar (and its error messages) lives in
    ``repro.specs.parse_comm``; this function only constructs the object.
    """
    from . import int8 as int8_mod
    from . import topk as topk_mod

    c = parse_comm(spec)
    if c.kind == "dense":
        return DenseReducer()
    if c.kind == "int8":
        return int8_mod.Int8Reducer(
            num_workers=num_workers, use_pallas=use_pallas, interpret=interpret
        )
    return topk_mod.TopKReducer(k=c.k)
