"""One grammar for the repo's user-facing string specs.

Three axes are configured by short strings — *what* the LMO solves
(``solver=``), *how* bytes are encoded (``comm=``), and *what graph* they
flow over (``topology=``):

    solver    "rank1" | "block:K[:adapt][:cold]"
    comm      "dense" | "int8" | "topk:r"
    topology  "flat" | "ring" | "gossip:k" | "hier:g"

Each axis has exactly one parser here, and every entry point
(``DFWConfig`` -> ``launch.dfw.fit``/``fit_serial``, the serial
``core.frank_wolfe.fit``, ``comm.base.make_reducer``,
``comm.topology.make_topology``) routes through it, so a malformed spec
fails with the same message everywhere. Parsers return cheap ``NamedTuple``
values; object construction (reducers, topologies) stays in the owning
modules — this module imports nothing heavy and never touches jax.

All parse failures raise :class:`SpecError`, a ``ValueError`` subclass:
existing call sites (and tests) that catch ``ValueError`` keep working
unchanged.
"""
from __future__ import annotations

from typing import NamedTuple


class SpecError(ValueError):
    """A malformed spec string (solver, comm, or topology axis).

    Subclasses ``ValueError`` so pre-existing ``except ValueError`` /
    ``pytest.raises(ValueError)`` call sites are unaffected by the move to
    the shared grammar.
    """


# ---------------------------------------------------------------------------
# solver= axis (moved from core/frank_wolfe.py — re-exported there)
# ---------------------------------------------------------------------------


class SolverSpec(NamedTuple):
    """Parsed LMO solver tier (see ``parse_solver``)."""

    kind: str  # "rank1" | "block"
    k: int  # block width (1 for rank1)
    adaptive: bool  # spectral-gap-adaptive K(t): stop iterating early
    cold: bool  # ignore the carried warm-start probe (ablation knob)


def parse_solver(spec) -> SolverSpec:
    """Parse a solver spec string — THE single validation point shared by
    ``frank_wolfe.fit``, ``launch.dfw.fit``/``fit_serial`` and ``DFWConfig``.

    Grammar::

        "rank1"                  paper's rank-1 LMO (Algorithm 2)
        "block:K"                rank-K block LMO (BlockFW tier)
        "block:K:adapt"          + spectral-gap-adaptive power iterations
        "block:K:cold"           + ignore the warm-start probe (ablation)
        "block:K:adapt:cold"     flags compose in any order

    Raises ``SpecError`` on malformed specs — ``block:0``, ``block:-3``,
    ``block:`` (no k), unknown flags, unknown solver names. An already-parsed
    ``SolverSpec`` passes through unchanged.
    """
    if isinstance(spec, SolverSpec):
        return spec
    if not isinstance(spec, str):
        raise SpecError(
            f"solver spec must be a string, got {type(spec).__name__}"
        )
    if spec == "rank1":
        return SolverSpec(kind="rank1", k=1, adaptive=False, cold=False)
    if spec == "block" or spec.startswith("block:"):
        parts = spec.split(":")
        if len(parts) < 2 or parts[1] == "":
            raise SpecError(
                f"solver {spec!r}: block solver needs a width, e.g. 'block:4'"
            )
        try:
            k = int(parts[1])
        except ValueError:
            raise SpecError(
                f"solver {spec!r}: block width {parts[1]!r} is not an integer"
            ) from None
        if k < 1:
            raise SpecError(
                f"solver {spec!r}: block width must be >= 1, got {k}"
            )
        adaptive = cold = False
        for flag in parts[2:]:
            if flag == "adapt":
                adaptive = True
            elif flag == "cold":
                cold = True
            else:
                raise SpecError(
                    f"solver {spec!r}: unknown flag {flag!r} "
                    "(expected 'adapt' and/or 'cold')"
                )
        return SolverSpec(kind="block", k=k, adaptive=adaptive, cold=cold)
    raise SpecError(
        f"unknown solver {spec!r} (expected 'rank1' or 'block:K[:adapt][:cold]')"
    )


# ---------------------------------------------------------------------------
# comm= axis (string grammar moved from comm/base.make_reducer)
# ---------------------------------------------------------------------------


class CommSpec(NamedTuple):
    """Parsed wire encoding (see ``parse_comm``)."""

    kind: str  # "dense" | "int8" | "topk"
    k: int  # topk keep-count per vector (0 for dense/int8)
    spec: str  # canonical round-trippable string


def parse_comm(spec) -> CommSpec:
    """Parse a ``comm=`` encoding spec.

    Grammar::

        "dense"     exact f32 psum (the paper's master aggregate)
        "int8"      stochastic-rounding s8 psum + shared f32 scale
        "topk:r"    keep the r largest-|.| components, error feedback

    Raises ``SpecError`` on unknown names and ``topk`` with a missing,
    non-integer, or < 1 keep-count. The messages are byte-identical to the
    pre-``specs`` ``make_reducer`` errors. An already-parsed ``CommSpec``
    passes through unchanged.
    """
    if isinstance(spec, CommSpec):
        return spec
    if not isinstance(spec, str):
        raise SpecError(
            f"comm spec must be a string, got {type(spec).__name__}"
        )
    if spec == "dense":
        return CommSpec(kind="dense", k=0, spec="dense")
    if spec == "int8":
        return CommSpec(kind="int8", k=0, spec="int8")
    if spec.startswith("topk:"):
        parts = spec.split(":")
        try:
            k = int(parts[1])
        except ValueError:
            raise SpecError(
                f"comm spec {spec!r}: keep count {parts[1]!r} is not an integer"
            ) from None
        if k < 1:
            raise SpecError(f"comm spec {spec!r}: k must be >= 1")
        return CommSpec(kind="topk", k=k, spec=f"topk:{k}")
    raise SpecError(
        f"unknown comm spec {spec!r} (expected 'dense', 'int8' or 'topk:r')"
    )


# ---------------------------------------------------------------------------
# topology= axis (new in the topology-aware comm redesign)
# ---------------------------------------------------------------------------


class TopologySpec(NamedTuple):
    """Parsed exchange graph (see ``parse_topology``)."""

    kind: str  # "flat" | "gossip" | "hier"
    degree: int  # gossip neighbor degree (2 for ring; 0 otherwise)
    groups: int  # hier group count (1 otherwise)
    spec: str  # canonical round-trippable string


def parse_topology(spec) -> TopologySpec:
    """Parse a ``topology=`` exchange-graph spec.

    Grammar::

        "flat"       one global all-reduce domain (today's psum master)
        "ring"       degree-2 gossip: each worker averages with its +-1
                     ring neighbors ("gossip:2" is the same graph)
        "gossip:k"   k-regular gossip over ring offsets +-1..+-k/2
                     (k even, so the mixing matrix stays symmetric)
        "hier:g"     two-level reduce: g groups, exact psum inside each
                     group, reducer-encoded exchange across groups

    Structural validation only — constraints that depend on the worker
    count (gossip degree < N, N divisible by g) are checked by
    ``comm.topology.make_topology`` where N is known. Raises ``SpecError``
    on malformed specs; an already-parsed ``TopologySpec`` passes through
    unchanged.
    """
    if isinstance(spec, TopologySpec):
        return spec
    if not isinstance(spec, str):
        raise SpecError(
            f"topology spec must be a string, got {type(spec).__name__}"
        )
    if spec == "flat":
        return TopologySpec(kind="flat", degree=0, groups=1, spec="flat")
    if spec == "ring":
        return TopologySpec(kind="gossip", degree=2, groups=1, spec="ring")
    if spec == "gossip" or spec.startswith("gossip:"):
        parts = spec.split(":")
        if len(parts) < 2 or parts[1] == "":
            raise SpecError(
                f"topology {spec!r}: gossip needs a degree, e.g. 'gossip:2'"
            )
        try:
            k = int(parts[1])
        except ValueError:
            raise SpecError(
                f"topology {spec!r}: gossip degree {parts[1]!r} is not an "
                "integer"
            ) from None
        if k < 2:
            raise SpecError(
                f"topology {spec!r}: gossip degree must be >= 2, got {k}"
            )
        if k % 2 != 0:
            raise SpecError(
                f"topology {spec!r}: gossip degree must be even (the graph "
                f"uses symmetric ring offsets +-1..+-k/2), got {k}"
            )
        return TopologySpec(
            kind="gossip", degree=k, groups=1, spec=f"gossip:{k}"
        )
    if spec == "hier" or spec.startswith("hier:"):
        parts = spec.split(":")
        if len(parts) < 2 or parts[1] == "":
            raise SpecError(
                f"topology {spec!r}: hier needs a group count, e.g. 'hier:2'"
            )
        try:
            g = int(parts[1])
        except ValueError:
            raise SpecError(
                f"topology {spec!r}: group count {parts[1]!r} is not an "
                "integer"
            ) from None
        if g < 2:
            raise SpecError(
                f"topology {spec!r}: group count must be >= 2, got {g} "
                "(one group is just 'flat')"
            )
        return TopologySpec(kind="hier", degree=0, groups=g, spec=f"hier:{g}")
    raise SpecError(
        f"unknown topology {spec!r} "
        "(expected 'flat', 'ring', 'gossip:k' or 'hier:g')"
    )


# ---------------------------------------------------------------------------
# Cross-axis validation — the one entry-point gate
# ---------------------------------------------------------------------------


def validate(
    *, solver="rank1", comm="dense", topology="flat"
) -> "tuple[SolverSpec, CommSpec, TopologySpec]":
    """Parse and cross-validate all three axes at once.

    This is what the run entry points (``launch.dfw.fit``/``fit_serial``,
    ``core.frank_wolfe.fit``) call before any device work, so every axis
    fails early with the shared grammar's message. Cross-axis rules:

    - gossip topologies carry per-node iterates whose consensus analysis
      assumes the rank-1 LMO; the block solver is rejected,
    - gossip exchanges are neighbor *averages*, not collectives, so there
      is no wire encoding to compress: only ``comm="dense"`` composes,
    - ``hier`` composes with every encoding (that is its point: compression
      applies on the inter-group hop only).
    """
    s = parse_solver(solver)
    c = parse_comm(comm)
    t = parse_topology(topology)
    if t.kind == "gossip" and s.kind != "rank1":
        raise SpecError(
            f"topology {t.spec!r} requires solver 'rank1' (per-node gap "
            f"certificates are rank-1 quantities), got solver {s!r}"
        )
    if t.kind == "gossip" and c.kind != "dense":
        raise SpecError(
            f"topology {t.spec!r} requires comm 'dense' (gossip exchanges "
            f"are neighbor averages, not compressible collectives), got "
            f"comm {c.spec!r}"
        )
    return s, c, t
