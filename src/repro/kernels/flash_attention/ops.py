"""Public attention op: dispatches Pallas flash kernel (TPU) / jnp ref (else).

Training/dry-run currently use the ref path so XLA cost_analysis sees the
attention FLOPs (a Pallas call is an opaque custom-call to XLA); the kernel is
the serving/prefill TPU target, validated in interpret mode on CPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


@functools.partial(
    jax.jit,
    static_argnames=("scale", "causal", "block_q", "block_k", "use_pallas", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Hq, Sq, Dh)
    k: jax.Array,  # (B, Hkv, Skv, Dh)
    v: jax.Array,  # (B, Hkv, Skv, Dh)
    *,
    scale: float,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    use = jax.default_backend() == "tpu" if use_pallas is None else use_pallas
    if not use and not interpret:
        return ref.attention(q, k, v, scale=scale, causal=causal)

    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    pq, pk = (-sq) % block_q, (-skv) % block_k
    qf = jnp.pad(q, ((0, 0), (0, 0), (0, pq), (0, 0))).reshape(b * hq, sq + pq, dh)
    kf = jnp.pad(k, ((0, 0), (0, 0), (0, pk), (0, 0))).reshape(b * hkv, skv + pk, dh)
    vf = jnp.pad(v, ((0, 0), (0, 0), (0, pk), (0, 0))).reshape(b * hkv, skv + pk, dh)
    out = kernel.flash_attention(
        qf, kf, vf,
        num_q_heads=hq, num_kv_heads=hkv, kv_len=skv, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, interpret=interpret,
    )
    return out[:, :sq, :].reshape(b, hq, sq, dh)
