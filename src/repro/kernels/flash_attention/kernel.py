"""Flash attention (forward) Pallas kernel with GQA and causal masking.

TPU-native design notes (vs. the CUDA flash-attention formulation):
  - Online-softmax state (running max m, denominator l, accumulator acc) lives
    in VMEM scratch that persists across the innermost (kv) grid dimension —
    the TPU analogue of keeping state in registers/shared memory.
  - Tiles are (block_q x head_dim) and (block_k x head_dim) with head_dim=128
    so every contraction is MXU-shaped; softmax runs on the VPU in f32.
  - GQA is resolved in the BlockSpec index maps: q-head h reads kv-head
    h // (num_q_heads // num_kv_heads) — no K/V repetition in HBM.
  - Fully-masked causal tiles are skipped with pl.when (no MXU work), which is
    the TPU version of the CUDA early-exit.

Grid: (batch*q_heads, num_q_blocks, num_kv_blocks), kv innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
    *, scale: float, causal: bool, block_q: int, block_k: int,
    kv_len: int, num_kv_blocks: int,
):
    iq = pl.program_id(1)
    jk = pl.program_id(2)

    @pl.when(jk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * block_q
    k_start = jk * block_k

    def _compute():
        q = q_ref[0].astype(jnp.float32)  # (bq, dh)
        k = k_ref[0].astype(jnp.float32)  # (bk, dh)
        v = v_ref[0].astype(jnp.float32)  # (bk, dh)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * scale  # (bq, bk)

        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos < kv_len  # padded kv tail
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            mask = mask & (kpos <= qpos)
        s = jnp.where(mask, s, _NEG_INF)

        m_prev, l_prev = m_scr[...], l_scr[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v, preferred_element_type=jnp.float32
        )
        m_scr[...] = m_new
        l_scr[...] = l_new

    if causal:
        # Tile fully above the diagonal -> no work (dynamic guard on indices).
        pl.when(k_start <= q_start + block_q - 1)(_compute)
    else:
        _compute()

    @pl.when(jk == num_kv_blocks - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "scale", "block_q", "block_k", "num_q_heads",
                     "num_kv_heads", "kv_len", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B*Hq, Sq, Dh)   Sq % block_q == 0
    k: jax.Array,  # (B*Hkv, Skv, Dh) Skv % block_k == 0 (zero-padded ok)
    v: jax.Array,  # (B*Hkv, Skv, Dh)
    *,
    num_q_heads: int,
    num_kv_heads: int,
    kv_len: int,  # true (unpadded) kv length for masking
    scale: float,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    bh, sq, dh = q.shape
    _, skv, _ = k.shape
    assert sq % block_q == 0 and skv % block_k == 0
    group = num_q_heads // num_kv_heads
    nq, nk = sq // block_q, skv // block_k

    def kv_head(h):  # flat (b*Hq) index -> flat (b*Hkv) index
        return (h // num_q_heads) * num_kv_heads + (h % num_q_heads) // group

    kern = functools.partial(
        _flash_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, kv_len=kv_len, num_kv_blocks=nk,
    )
    return pl.pallas_call(
        kern,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (kv_head(h), j, 0)),
            pl.BlockSpec((1, block_k, dh), lambda h, i, j: (kv_head(h), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, dh), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
