"""Pure-jnp GQA attention oracle (also the XLA path used by dry-runs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def attention(
    q: jax.Array,  # (B, Hq, Sq, Dh)
    k: jax.Array,  # (B, Hkv, Skv, Dh)
    v: jax.Array,  # (B, Hkv, Skv, Dh)
    *,
    scale: float,
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """Dense softmax attention with GQA head-group broadcast, f32 softmax.

    ``q_offset`` positions the query block within the kv timeline (decode:
    q_offset = kv_len - sq)."""
    b, hq, sq, dh = q.shape
    _, hkv, skv, _ = k.shape
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, dh)
    # Operands stay in their storage dtype (bf16 on the wire when GSPMD
    # inserts gathers); accumulation is f32 via preferred_element_type.
    s = jnp.einsum(
        "bhgqd,bhkd->bhgqk", qg, k, preferred_element_type=jnp.float32
    )
    s = s * scale
    if causal:
        qpos = q_offset + jnp.arange(sq)[:, None]
        kpos = jnp.arange(skv)[None, :]
        s = jnp.where(kpos <= qpos, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgqk,bhkd->bhgqd", p.astype(v.dtype), v,
        preferred_element_type=jnp.float32,
    )
    return out.reshape(b, hq, sq, dh).astype(q.dtype)


# ``q_offset`` may be a traced scalar (used by the chunked-scan path).
attention_with_offset_array = attention
