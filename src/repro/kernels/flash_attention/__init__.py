from . import kernel, ops, ref
from .ops import flash_attention

__all__ = ["kernel", "ops", "ref", "flash_attention"]
