"""Pure-jnp oracles for the power-method matvec kernels."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matvec(a: jax.Array, v: jax.Array) -> jax.Array:
    """A @ v with f32 accumulation; v:(m,) or (m,1)."""
    v = v.reshape(a.shape[1], -1)
    return jnp.dot(a, v, preferred_element_type=jnp.float32)


def rmatvec(a: jax.Array, u: jax.Array) -> jax.Array:
    u = u.reshape(a.shape[0], -1)
    return jnp.dot(a.T, u, preferred_element_type=jnp.float32)


def power_iter_step(x: jax.Array, r: jax.Array, v: jax.Array):
    """One two-sided power iteration on the implicit MTLS gradient A = X^T R:
    returns (u, v') unit-normalized. Oracle for ops.power_iter_step."""
    t = matvec(r, v)  # (n,1)
    u = rmatvec(x, t)  # (d,1)
    u = u / (jnp.linalg.norm(u) + 1e-30)
    s = matvec(x, u)  # (n,1)
    v2 = rmatvec(r, s)  # (m,1)
    v2 = v2 / (jnp.linalg.norm(v2) + 1e-30)
    return u, v2
