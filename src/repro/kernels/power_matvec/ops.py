"""Public jit'd wrappers: padding, dispatch (Pallas on TPU / ref elsewhere)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _use_pallas(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad_to(x: jax.Array, mult_r: int, mult_c: int) -> jax.Array:
    n, m = x.shape
    pr, pc = (-n) % mult_r, (-m) % mult_c
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)))


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "use_pallas", "interpret"))
def matvec(
    a: jax.Array,
    v: jax.Array,
    *,
    block_r: int = 256,
    block_c: int = 256,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """A @ v -> (n,). Zero-pads to block multiples (zeros are exact no-ops)."""
    n, m = a.shape
    if not _use_pallas(use_pallas) and not interpret:
        return ref.matvec(a, v)[:, 0]
    ap = _pad_to(a, block_r, block_c)
    vp = _pad_to(v.reshape(m, 1), block_c, 1)
    out = kernel.matvec(ap, vp, block_r=block_r, block_c=block_c, interpret=interpret)
    return out[:n, 0]


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "use_pallas", "interpret"))
def rmatvec(
    a: jax.Array,
    u: jax.Array,
    *,
    block_r: int = 256,
    block_c: int = 256,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """A^T @ u -> (m,)."""
    n, m = a.shape
    if not _use_pallas(use_pallas) and not interpret:
        return ref.rmatvec(a, u)[:, 0]
    ap = _pad_to(a, block_r, block_c)
    up = _pad_to(u.reshape(n, 1), block_r, 1)
    out = kernel.rmatvec(ap, up, block_r=block_r, block_c=block_c, interpret=interpret)
    return out[:m, 0]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def power_iter_step(
    x: jax.Array,
    r: jax.Array,
    v: jax.Array,
    *,
    use_pallas: bool | None = None,
    interpret: bool = False,
):
    """One two-sided power iteration on the implicit gradient A = X^T R.

    Four streaming kernel calls; X and R are each read exactly twice per
    iteration (information-theoretic minimum for the two-sided step).
    Returns unit (u, v')."""
    kw = dict(use_pallas=use_pallas, interpret=interpret)
    t = matvec(r, v, **kw)
    u = rmatvec(x, t, **kw)
    u = u / (jnp.linalg.norm(u) + 1e-30)
    s = matvec(x, u, **kw)
    v2 = rmatvec(r, s, **kw)
    v2 = v2 / (jnp.linalg.norm(v2) + 1e-30)
    return u, v2
