from . import kernel, ops, ref
from .ops import matvec, power_iter_step, rmatvec

__all__ = ["kernel", "ops", "ref", "matvec", "rmatvec", "power_iter_step"]
