"""Blocked matvec / rmatvec Pallas kernels — the DFW-TRACE power-method hot spot.

The distributed power method on the implicit gradient A = X^T R is a chain of
four streaming matvecs per iteration (t=Rv, u=X^T t, s=Xu, v'=R^T s). Each is
bandwidth-bound (~1 FLOP/byte in bf16), so the kernel goal is exactly one HBM
pass over the matrix per call with MXU-aligned (block_r x block_c) VMEM tiles;
vectors are carried as (len, 1) matrices so the reduction runs on the MXU.

Accumulation is always f32 via ``preferred_element_type`` regardless of the
input dtype (bf16 inputs keep full-precision partial sums).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, v_ref, o_ref):
    """out[i] += A[i,j] @ v[j]; grid=(rows, cols), cols innermost."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], v_ref[...], preferred_element_type=jnp.float32
    )


def _rmatvec_kernel(a_ref, u_ref, o_ref):
    """out[j] += A[i,j]^T @ u[i]; grid=(cols, rows), rows innermost."""
    i = pl.program_id(1)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].T, u_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_c", "interpret")
)
def matvec(
    a: jax.Array,
    v: jax.Array,
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """A @ v for A:(n,m), v:(m,1) -> (n,1). Dims must divide the block shape
    (ops.py pads). VMEM/step: block_r*block_c*bytes(A) + 2 vector blocks."""
    n, m = a.shape
    assert n % block_r == 0 and m % block_c == 0, (a.shape, block_r, block_c)
    return pl.pallas_call(
        _matvec_kernel,
        grid=(n // block_r, m // block_c),
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_c, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(a, v)


@functools.partial(
    jax.jit, static_argnames=("block_r", "block_c", "interpret")
)
def rmatvec(
    a: jax.Array,
    u: jax.Array,
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """A^T @ u for A:(n,m), u:(n,1) -> (m,1)."""
    n, m = a.shape
    assert n % block_r == 0 and m % block_c == 0, (a.shape, block_r, block_c)
    return pl.pallas_call(
        _rmatvec_kernel,
        grid=(m // block_c, n // block_r),
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda j, i: (i, j)),
            pl.BlockSpec((block_r, 1), lambda j, i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_c, 1), lambda j, i: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, 1), jnp.float32),
        interpret=interpret,
    )(a, u)
