"""Pallas TPU kernels for the compute hot spots.

Layout: one subpackage per kernel with
    kernel.py — pl.pallas_call + explicit BlockSpec VMEM tiling (TPU target)
    ops.py    — jit'd public wrapper (padding, dtype policy, dispatch)
    ref.py    — pure-jnp oracle used by tests and by the CPU/dry-run path

The dry-run / roofline path uses the ref implementations so XLA's
cost_analysis sees every FLOP (Pallas lowers to an opaque custom call on TPU);
kernels are validated on CPU with interpret=True.
"""
from . import (
    factor_matvec,
    flash_attention,
    mc_matvec,
    power_matvec,
    quantize,
    rank1_update,
    wkv6_chunk,
)

__all__ = [
    "factor_matvec",
    "flash_attention",
    "mc_matvec",
    "power_matvec",
    "quantize",
    "rank1_update",
    "wkv6_chunk",
]
