"""Pure-jnp oracles for the observed-entry (COO) matvec kernels.

The matrix-completion gradient is supported on the observed entries only:
``G = P_Omega(W - M)`` with values ``vals_e`` at coordinates
``(rows_e, cols_e)``. Its matvecs are segment reductions over the entry axis;
``jax.ops.segment_sum`` is the reference the Pallas kernels are verified
against (same role as ``power_matvec/ref.py`` for the dense tasks).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matvec(
    rows: jax.Array, cols: jax.Array, vals: jax.Array, v: jax.Array, num_rows: int
) -> jax.Array:
    """G @ v -> (num_rows,): scatter vals_e * v[cols_e] into rows."""
    contrib = vals.astype(jnp.float32) * jnp.take(v, cols).astype(jnp.float32)
    return jax.ops.segment_sum(contrib, rows, num_segments=num_rows)


def rmatvec(
    rows: jax.Array, cols: jax.Array, vals: jax.Array, u: jax.Array, num_cols: int
) -> jax.Array:
    """G^T @ u -> (num_cols,): scatter vals_e * u[rows_e] into cols."""
    contrib = vals.astype(jnp.float32) * jnp.take(u, rows).astype(jnp.float32)
    return jax.ops.segment_sum(contrib, cols, num_segments=num_cols)
