"""Observed-entry (COO) matvec kernels for the matrix-completion gradient."""
from . import kernel, ops, ref
from .ops import matvec, rmatvec

__all__ = ["kernel", "ops", "ref", "matvec", "rmatvec"]
