"""Blocked COO matvec Pallas kernel — the matrix-completion power-method hot spot.

The implicit completion gradient ``G = P_Omega(W - M)`` is a COO sparse matrix
(entry shard per worker). Its matvec ``(G v)[i] = sum_e vals_e v[cols_e]
[rows_e == i]`` is a gather-multiply-scatter chain; TPUs have no native
VMEM gather/scatter, so both halves are expressed as one-hot matmuls that run
on the MXU:

    gather:  x[g_e]    = onehot(g, in_dim)  @ x          (block_e x in_dim)
    scatter: out[seg] += onehot(seg, out)^T @ contrib    (out_dim x block_e)

The grid walks entry blocks; index/value blocks stream through VMEM exactly
once per call (one HBM pass over the shard) while the dense vectors stay
resident. The extra one-hot FLOPs are the standard TPU trade for
bandwidth-bound sparse ops — each is ``block_e * dim`` MACs on the MXU, and
the entry shard, not the dense work, is the traffic that matters.
Accumulation is always f32 via ``preferred_element_type``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _coo_matvec_kernel(seg_ref, gat_ref, vals_ref, x_ref, o_ref):
    """out[seg_e] += vals_e * x[gat_e]; grid=(entry blocks,)."""
    e = pl.program_id(0)

    @pl.when(e == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    seg = seg_ref[...]  # (block_e, 1) int32 output coordinate
    gat = gat_ref[...]  # (block_e, 1) int32 gather coordinate
    vals = vals_ref[...].astype(jnp.float32)
    block_e = seg.shape[0]
    in_dim = x_ref.shape[0]
    out_dim = o_ref.shape[0]

    gather = (
        jax.lax.broadcasted_iota(jnp.int32, (block_e, in_dim), 1) == gat
    ).astype(jnp.float32)
    xe = jnp.dot(
        gather, x_ref[...].astype(jnp.float32), preferred_element_type=jnp.float32
    )
    contrib = vals * xe  # (block_e, 1)

    scatter = (
        jax.lax.broadcasted_iota(jnp.int32, (block_e, out_dim), 1) == seg
    ).astype(jnp.float32)
    o_ref[...] += jnp.dot(scatter.T, contrib, preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("out_dim", "block_e", "interpret"))
def coo_matvec(
    seg: jax.Array,
    gat: jax.Array,
    vals: jax.Array,
    x: jax.Array,
    *,
    out_dim: int,
    block_e: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Segment-reduce ``vals * x[gat]`` into ``seg`` -> (out_dim, 1) f32.

    ``seg``/``gat``/``vals`` are (p, 1) with p a block_e multiple (ops.py
    pads; vals==0 padding rows are exact no-ops regardless of their indices).
    ``x`` is (in_dim, 1). VMEM/step: 3 entry blocks + both dense vectors.
    """
    p = seg.shape[0]
    assert p % block_e == 0, (p, block_e)
    return pl.pallas_call(
        _coo_matvec_kernel,
        grid=(p // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, 1), lambda e: (e, 0)),
            pl.BlockSpec((block_e, 1), lambda e: (e, 0)),
            pl.BlockSpec((block_e, 1), lambda e: (e, 0)),
            pl.BlockSpec((x.shape[0], 1), lambda e: (0, 0)),
        ],
        out_specs=pl.BlockSpec((out_dim, 1), lambda e: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((out_dim, 1), jnp.float32),
        interpret=interpret,
    )(seg, gat, vals, x)
