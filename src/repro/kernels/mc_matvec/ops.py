"""Public jit'd wrappers: entry padding, dispatch (Pallas on TPU / ref elsewhere).

Same contract as ``power_matvec/ops.py``: callers get 1-D vectors in/out and
never see the (p, 1)/(dim, 1) carriage or the entry-block padding. Padding
entries carry vals=0 (exact no-ops) and point at coordinate 0, so ``out_dim``
never needs to grow.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _use_pallas(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad_entries(seg, gat, vals, block_e):
    p = seg.shape[0]
    pad = (-p) % block_e
    if pad:
        seg = jnp.pad(seg, (0, pad))
        gat = jnp.pad(gat, (0, pad))
        vals = jnp.pad(vals, (0, pad))  # zeros: exact no-op entries
    return (
        seg.reshape(-1, 1).astype(jnp.int32),
        gat.reshape(-1, 1).astype(jnp.int32),
        vals.reshape(-1, 1),
    )


@functools.partial(
    jax.jit, static_argnames=("num_rows", "block_e", "use_pallas", "interpret")
)
def matvec(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    v: jax.Array,
    num_rows: int,
    *,
    block_e: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """G @ v -> (num_rows,) for the COO gradient G with values ``vals``."""
    if not _use_pallas(use_pallas) and not interpret:
        return ref.matvec(rows, cols, vals, v, num_rows)
    seg, gat, valsp = _pad_entries(rows, cols, vals, block_e)
    out = kernel.coo_matvec(
        seg, gat, valsp, v.reshape(-1, 1),
        out_dim=num_rows, block_e=block_e, interpret=interpret,
    )
    return out[:, 0]


@functools.partial(
    jax.jit, static_argnames=("num_cols", "block_e", "use_pallas", "interpret")
)
def rmatvec(
    rows: jax.Array,
    cols: jax.Array,
    vals: jax.Array,
    u: jax.Array,
    num_cols: int,
    *,
    block_e: int = 512,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """G^T @ u -> (num_cols,): the same kernel with seg/gather roles swapped."""
    if not _use_pallas(use_pallas) and not interpret:
        return ref.rmatvec(rows, cols, vals, u, num_cols)
    seg, gat, valsp = _pad_entries(cols, rows, vals, block_e)
    out = kernel.coo_matvec(
        seg, gat, valsp, u.reshape(-1, 1),
        out_dim=num_cols, block_e=block_e, interpret=interpret,
    )
    return out[:, 0]
