"""Public wrappers for the fused rank-1 FW update."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _use_pallas(force):
    return jax.default_backend() == "tpu" if force is None else force


def _pad2(x, br, bc):
    n, m = x.shape
    pr, pc = (-n) % br, (-m) % bc
    return jnp.pad(x, ((0, pr), (0, pc))) if pr or pc else x


def _pad1(x, b):
    n = x.shape[0]
    p = (-n) % b
    return jnp.pad(x.reshape(n, 1), ((0, p), (0, 0)))


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "use_pallas", "interpret"))
def rank1_update(
    z, x, y, a, b, *, block_r=256, block_c=256, use_pallas=None, interpret=False
):
    """Z' = a*Z + b*x y^T, one fused HBM pass on TPU."""
    n, m = z.shape
    if not _use_pallas(use_pallas) and not interpret:
        return ref.rank1_update(z, x, y, a, b)
    scal = jnp.stack([jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32)]).reshape(2, 1)
    out = kernel.rank1_update(
        _pad2(z, block_r, block_c), _pad1(x, block_r), _pad1(y, block_c), scal,
        block_r=block_r, block_c=block_c, interpret=interpret,
    )
    return out[:n, :m]


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "use_pallas", "interpret"))
def rank1_update_axpy(
    z, y0, x, y, a, b, c, *, block_r=256, block_c=256, use_pallas=None, interpret=False
):
    """Z' = a*Z + b*x y^T + c*Y0 (the MTLS residual update), one fused pass."""
    n, m = z.shape
    if not _use_pallas(use_pallas) and not interpret:
        return ref.rank1_update_axpy(z, y0, x, y, a, b, c)
    scal = jnp.stack(
        [jnp.asarray(a, jnp.float32), jnp.asarray(b, jnp.float32), jnp.asarray(c, jnp.float32)]
    ).reshape(3, 1)
    out = kernel.rank1_update_axpy(
        _pad2(z, block_r, block_c), _pad2(y0, block_r, block_c),
        _pad1(x, block_r), _pad1(y, block_c), scal,
        block_r=block_r, block_c=block_c, interpret=interpret,
    )
    return out[:n, :m]
