from . import kernel, ops, ref
from .ops import rank1_update, rank1_update_axpy

__all__ = ["kernel", "ops", "ref", "rank1_update", "rank1_update_axpy"]
