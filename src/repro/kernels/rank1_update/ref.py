"""Pure-jnp oracle for the fused rank-1 update."""
from __future__ import annotations

import jax.numpy as jnp


def rank1_update(z, x, y, a, b):
    """a*Z + b*outer(x, y), computed in f32, cast back to Z's dtype."""
    out = a * z.astype(jnp.float32) + b * jnp.outer(
        x.reshape(-1).astype(jnp.float32), y.reshape(-1).astype(jnp.float32)
    )
    return out.astype(z.dtype)


def rank1_update_axpy(z, y0, x, y, a, b, c):
    """a*Z + b*outer(x, y) + c*Y0."""
    out = (
        a * z.astype(jnp.float32)
        + b * jnp.outer(x.reshape(-1).astype(jnp.float32), y.reshape(-1).astype(jnp.float32))
        + c * y0.astype(jnp.float32)
    )
    return out.astype(z.dtype)
