"""Fused FW rank-1 update kernel: Z' = a*Z + b*(x y^T) [+ c*Y0].

Covers every Appendix-B sufficient-information update in one HBM pass:
  MTLS residual   R <- (1-g)R - g*Y - g*mu (Xu) v^T      (a=1-g, c=-g, b=-g*mu)
  logistic logits Z <- (1-g)Z - g*mu (Xu) v^T            (a=1-g, b=-g*mu)
  dense gradient  G <- (1-g)G + g(-mu (XtX u) v^T - XtY) (a=1-g, b=-g*mu, c=-g)

Without fusion this is 3 reads + 1 write of the (n,m) operand (separate
outer-product materialization + axpy); fused it is (1 or 2) reads + 1 write.
Tiles are (block_r, block_c) in VMEM; scalars ride in SMEM-style (1,1) blocks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rank1_kernel(z_ref, x_ref, y_ref, s_ref, o_ref):
    a, b = s_ref[0, 0], s_ref[1, 0]
    xy = jnp.dot(x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = (a * z_ref[...] + b * xy).astype(o_ref.dtype)


def _rank1_axpy_kernel(z_ref, y0_ref, x_ref, y_ref, s_ref, o_ref):
    a, b, c = s_ref[0, 0], s_ref[1, 0], s_ref[2, 0]
    xy = jnp.dot(x_ref[...], y_ref[...].T, preferred_element_type=jnp.float32)
    o_ref[...] = (a * z_ref[...] + b * xy + c * y0_ref[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def rank1_update(
    z: jax.Array,
    x: jax.Array,
    y: jax.Array,
    scalars: jax.Array,  # (2,1) f32: [a, b]
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, m = z.shape
    assert n % block_r == 0 and m % block_c == 0
    return pl.pallas_call(
        _rank1_kernel,
        grid=(n // block_r, m // block_c),
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((2, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), z.dtype),
        interpret=interpret,
    )(z, x, y, scalars)


@functools.partial(jax.jit, static_argnames=("block_r", "block_c", "interpret"))
def rank1_update_axpy(
    z: jax.Array,
    y0: jax.Array,
    x: jax.Array,
    y: jax.Array,
    scalars: jax.Array,  # (3,1) f32: [a, b, c]
    *,
    block_r: int = 256,
    block_c: int = 256,
    interpret: bool = False,
) -> jax.Array:
    n, m = z.shape
    assert n % block_r == 0 and m % block_c == 0
    return pl.pallas_call(
        _rank1_axpy_kernel,
        grid=(n // block_r, m // block_c),
        in_specs=[
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
            pl.BlockSpec((block_r, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((block_c, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((3, 1), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_r, block_c), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, m), z.dtype),
        interpret=interpret,
    )(z, y0, x, y, scalars)
