from . import kernel, ops, ref
from .ops import wkv6_chunk

__all__ = ["kernel", "ops", "ref", "wkv6_chunk"]
