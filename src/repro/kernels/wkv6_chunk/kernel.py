"""WKV6 chunk-recurrence Pallas kernel — RWKV-6's compute hot spot.

One grid step processes one (batch*head) slice: the whole chunk's r/k/v/decay
tiles live in VMEM together with the (dk, dv) state, and the intra-chunk
interaction runs as masked MXU matmuls (the chunked linear-attention form),
exactly mirroring models/rwkv6.time_mix's math:

    y_t = r_t (S_in decayed to t) + sum_{s<t} (r_t . decayed k_s) v_s
          + (r_t . u . k_t) v_t
    S_out = (full-chunk decay) S_in + sum_s (tail-decayed k_s) (x) v_s

Chunk length q and head dims (64) are MXU/VPU-friendly; the factored decay
exponents are clamped like the jnp path (pairs with >e80 decay round to 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, y_ref, s_out_ref):
    r = r_ref[0].astype(jnp.float32)  # (q, dk)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)  # (q, dv)
    lw = lw_ref[0].astype(jnp.float32)  # (q, dk), <= 0
    u = u_ref[0].astype(jnp.float32)  # (1, dk)
    s0 = s0_ref[0].astype(jnp.float32)  # (dk, dv)
    q = r.shape[0]

    cw = jnp.cumsum(lw, axis=0)  # inclusive prefix
    pw = cw - lw  # exclusive prefix
    # inter-chunk: y_t += (r_t * exp(pw_t)) @ S_in
    y = jnp.dot(r * jnp.exp(jnp.clip(pw, -80.0, 0.0)), s0,
                preferred_element_type=jnp.float32)
    # intra-chunk, strictly lower triangular
    a = jnp.dot(
        r * jnp.exp(jnp.clip(pw, -80.0, 0.0)),
        (k * jnp.exp(jnp.clip(-cw, -80.0, 80.0))).T,
        preferred_element_type=jnp.float32,
    )  # (q, q)
    ti = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    si = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    a = jnp.where(si < ti, a, 0.0)
    y = y + jnp.dot(a, v, preferred_element_type=jnp.float32)
    # diagonal bonus
    diag = jnp.sum(r * u * k, axis=-1, keepdims=True)  # (q, 1)
    y = y + diag * v
    y_ref[0] = y.astype(y_ref.dtype)
    # state update
    tail = jnp.exp(jnp.clip(cw[-1:, :] - cw, -80.0, 0.0))  # (q, dk)
    s_out = s0 * jnp.exp(jnp.clip(cw[-1, :], -80.0, 0.0))[:, None] + jnp.dot(
        (k * tail).T, v, preferred_element_type=jnp.float32
    )
    s_out_ref[0] = s_out.astype(s_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wkv6_chunk(
    r: jax.Array,  # (BH, q, dk)
    k: jax.Array,
    v: jax.Array,  # (BH, q, dv)
    logw: jax.Array,  # (BH, q, dk)
    u: jax.Array,  # (BH, dk)
    s0: jax.Array,  # (BH, dk, dv)
    *,
    interpret: bool = False,
):
    bh, q, dk = r.shape
    dv = v.shape[-1]
    y, s_out = pl.pallas_call(
        _wkv6_kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, q, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, q, dk), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dk), lambda i: (i, 0)),
            pl.BlockSpec((1, dk, dv), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, dv), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, dk, dv), lambda i: (i, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, q, dv), jnp.float32),
            jax.ShapeDtypeStruct((bh, dk, dv), jnp.float32),
        ],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
    return y, s_out
