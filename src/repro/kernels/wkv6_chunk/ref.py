"""Oracle for the WKV6 chunk kernel: the exact per-token recurrence.

    S_t = diag(w_t) S_{t-1} + k_t (x) v_t
    y_t = r_t @ (S_{t-1} + diag(u) k_t (x) v_t)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def wkv6_chunk(
    r: jax.Array,  # (q, dk)
    k: jax.Array,  # (q, dk)
    v: jax.Array,  # (q, dv)
    logw: jax.Array,  # (q, dk) log decay <= 0
    u: jax.Array,  # (dk,) bonus
    s0: jax.Array,  # (dk, dv)
):
    """Sequential token-by-token reference. Returns (y (q, dv), s_out)."""

    def step(s, args):
        rt, kt, vt, lwt = args
        kv = jnp.outer(kt, vt)
        y = rt @ (s + u[:, None] * kv)
        s = s * jnp.exp(lwt)[:, None] + kv
        return s, y

    s_out, ys = jax.lax.scan(step, s0.astype(jnp.float32),
                             (r.astype(jnp.float32), k.astype(jnp.float32),
                              v.astype(jnp.float32), logw.astype(jnp.float32)))
    return ys, s_out


def wkv6_chunk_batched(r, k, v, logw, u, s0):
    """(BH, q, d*) batched reference via vmap."""
    return jax.vmap(wkv6_chunk, in_axes=(0, 0, 0, 0, 0, 0))(r, k, v, logw, u, s0)
