"""Public wrapper: Pallas on TPU, exact-recurrence reference elsewhere."""
from __future__ import annotations

import functools

import jax

from . import kernel, ref


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def wkv6_chunk(r, k, v, logw, u, s0, *, use_pallas=None, interpret=False):
    """(BH, q, ...) chunk recurrence -> (y (BH,q,dv) f32, s_out (BH,dk,dv) f32)."""
    use = jax.default_backend() == "tpu" if use_pallas is None else use_pallas
    if not use and not interpret:
        return ref.wkv6_chunk_batched(r, k, v, logw, u, s0)
    return kernel.wkv6_chunk(r, k, v, logw, u, s0, interpret=interpret)
