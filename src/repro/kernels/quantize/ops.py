"""Public jit'd wrappers: padding, dispatch (Pallas on TPU / ref elsewhere).

Same contract as the other kernel subpackages: callers pass 1-D vectors and
a scalar scale; the (n, 1)/(1, 1) carriage and block padding stay internal.
Padding rows carry x = 0 and noise = 0, which quantize to exactly 0 — no-ops
in the integer psum downstream.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _use_pallas(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad1(x: jax.Array, block_n: int) -> jax.Array:
    pad = (-x.shape[0]) % block_n
    return jnp.pad(x, (0, pad)) if pad else x


@functools.partial(
    jax.jit, static_argnames=("budget", "block_n", "use_pallas", "interpret")
)
def quantize(
    x: jax.Array,
    noise: jax.Array,
    scale: jax.Array,
    *,
    budget: int,
    block_n: int = 256,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Stochastic-round x:(n,) f32 -> (n,) int8 under the shared ``scale``.

    ``noise``:(n,) uniform [0, 1) draws; ``scale`` a nonnegative scalar;
    ``budget`` the per-worker integer capacity (see kernel.py).
    """
    n = x.shape[0]
    scale = jnp.asarray(scale, jnp.float32)
    if not _use_pallas(use_pallas) and not interpret:
        return ref.quantize(x, noise, scale, budget)
    xp = _pad1(x.astype(jnp.float32), block_n).reshape(-1, 1)
    np_ = _pad1(noise.astype(jnp.float32), block_n).reshape(-1, 1)
    out = kernel.quantize(
        xp, np_, scale.reshape(1, 1),
        budget=budget, block_n=block_n, interpret=interpret,
    )
    return out[:n, 0]


@functools.partial(
    jax.jit, static_argnames=("budget", "block_n", "use_pallas", "interpret")
)
def dequantize(
    q: jax.Array,
    scale: jax.Array,
    *,
    budget: int,
    block_n: int = 256,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Summed integers q:(n,) -> (n,) f32 under the shared ``scale``."""
    n = q.shape[0]
    scale = jnp.asarray(scale, jnp.float32)
    if not _use_pallas(use_pallas) and not interpret:
        return ref.dequantize(q, scale, budget)
    qp = _pad1(q, block_n).reshape(-1, 1)
    out = kernel.dequantize(
        qp, scale.reshape(1, 1),
        budget=budget, block_n=block_n, interpret=interpret,
    )
    return out[:n, 0]
