from . import kernel, ops, ref
from .ops import dequantize, quantize

__all__ = ["kernel", "ops", "ref", "quantize", "dequantize"]
