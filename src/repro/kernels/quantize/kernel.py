"""Fused stochastic-rounding quantize / dequantize Pallas kernels.

Hot pair of the compressed power-method collectives (``repro/comm``): before
an integer psum every worker turns its local f32 contribution into int8 under
a shared per-vector scale, and turns the summed integers back into f32 after.
The fusion target is one VMEM pass per call — scale, stochastic round, clip
and cast happen on the block in registers instead of four XLA HLOs with HBM
round-trips between them.

Stochastic rounding is ``floor(x * budget / scale + noise)`` with uniform
``noise`` in [0, 1): exactly unbiased (``E[q] = x * budget / scale``). The
noise is an explicit input (host-side ``jax.random.uniform``) rather than an
in-kernel ``pltpu.prng_random_bits`` call so the kernel is deterministic
given its operands — the interpret-mode tests and the jnp reference
(``ref.py``) then agree bit-for-bit with the TPU path.

``budget`` is the per-worker integer capacity: with N workers summing into
int8 the shared scale maps each contribution into [-budget, budget] with
``budget = 127 // N``, so any partial sum of the all-reduce is bounded by
``N * budget <= 127`` and the s8 wire dtype can never overflow.

Vectors are carried as (n, 1) matrices like the other kernels in this repo;
the scale rides along as a (1, 1) block re-fetched at every grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-30


def _quantize_kernel(x_ref, noise_ref, scale_ref, o_ref, *, budget):
    """o = clip(floor(x * budget / scale + noise), -budget, budget) as int8."""
    inv = budget / (scale_ref[0, 0] + _EPS)
    v = jnp.floor(x_ref[...].astype(jnp.float32) * inv + noise_ref[...])
    o_ref[...] = jnp.clip(v, -budget, budget).astype(jnp.int8)


def _dequantize_kernel(q_ref, scale_ref, o_ref, *, budget):
    """o = q * scale / budget as f32."""
    o_ref[...] = q_ref[...].astype(jnp.float32) * (scale_ref[0, 0] / budget)


@functools.partial(jax.jit, static_argnames=("budget", "block_n", "interpret"))
def quantize(
    x: jax.Array,
    noise: jax.Array,
    scale: jax.Array,
    *,
    budget: int,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Stochastic-round x:(n,1) f32 to int8 under ``scale``:(1,1).

    ``n`` must divide ``block_n`` (ops.py pads; zero rows quantize to 0).
    VMEM/step: two f32 blocks + the int8 output block.
    """
    n = x.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert 1 <= budget <= 127, budget
    return pl.pallas_call(
        functools.partial(_quantize_kernel, budget=budget),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.int8),
        interpret=interpret,
    )(x, noise, scale)


@functools.partial(jax.jit, static_argnames=("budget", "block_n", "interpret"))
def dequantize(
    q: jax.Array,
    scale: jax.Array,
    *,
    budget: int,
    block_n: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Map summed integers q:(n,1) back to f32 under ``scale``:(1,1)."""
    n = q.shape[0]
    assert n % block_n == 0, (n, block_n)
    assert 1 <= budget <= 127, budget
    return pl.pallas_call(
        functools.partial(_dequantize_kernel, budget=budget),
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(q, scale)
