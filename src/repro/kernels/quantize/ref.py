"""Pure-jnp oracles for the quantize kernels.

Bit-compatible with ``kernel.py`` (the stochastic noise is an explicit
operand, so both paths compute the identical floor), used by tests and as
the off-TPU dispatch target of ``ops.py``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

_EPS = 1e-30


def quantize(
    x: jax.Array, noise: jax.Array, scale: jax.Array, budget: int
) -> jax.Array:
    """clip(floor(x * budget / scale + noise), -budget, budget) as int8.

    With ``noise ~ U[0, 1)`` this is exact stochastic rounding:
    ``E[quantize(x)] = x * budget / scale`` elementwise, and for
    ``|x| <= scale`` the clip never binds (floor of a value in
    [-budget, budget + 1) lands in [-budget, budget]).
    """
    v = jnp.floor(x.astype(jnp.float32) * (budget / (scale + _EPS)) + noise)
    return jnp.clip(v, -budget, budget).astype(jnp.int8)


def dequantize(q: jax.Array, scale: jax.Array, budget: int) -> jax.Array:
    """q * scale / budget as f32 (q is the *summed* integer vector)."""
    return q.astype(jnp.float32) * (scale / budget)
