"""Public jit'd wrappers: padding, dispatch (Pallas on TPU / ref elsewhere).

Same contract as the other kernel subpackages. Callers pass the raw factor
triple and a request batch; rank/lane padding stays internal:

* batch rows pad to ``block_b`` and output columns to ``block_o`` (zero
  rows/columns, sliced off the result),
* the rank axis pads to a sublane multiple with ``s == 0`` rows — exact
  no-ops in both contractions (matching ``low_rank``'s invariant that rows
  past the live count are zero),
* the input-feature axis pads to a lane multiple with zero columns.

``alpha`` (the factored iterate's running global scale) is folded into the
``s`` operand here, so kernel and reference stay scale-free. A rank-0
triple — a freshly initialized iterate, or ``pack_live`` of an untrained
model — is well-defined: the score is exactly zero, computed without
touching the kernel (Pallas cannot tile an empty operand).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import kernel, ref


def _use_pallas(force: bool | None) -> bool:
    if force is not None:
        return force
    return jax.default_backend() == "tpu"


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit,
    static_argnames=("block_b", "block_o", "use_pallas", "interpret"),
)
def factor_matvec(
    x: jax.Array,
    a: jax.Array,
    s: jax.Array,
    b: jax.Array,
    *,
    alpha: jax.Array | float = 1.0,
    block_b: int = 128,
    block_o: int = 256,
    use_pallas: bool | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Score a request batch against a factor triple:
    ``alpha * ((X @ A^T) * s) @ B`` -> (bt, n_out) f32.

    X:(bt, n_in), A:(r, n_in), s:(r,), B:(r, n_out). Scoring the factored
    iterate ``W = alpha * A^T diag(s) B`` in either direction is a choice of
    operand order: ``X @ W`` is ``factor_matvec(x, a, s, b)`` (A = U row
    factors) and ``X @ W^T`` is ``factor_matvec(x, b, s, a)``.
    """
    bt, n_in = x.shape
    r = a.shape[0]
    n_out = b.shape[1]
    se = (jnp.asarray(alpha, jnp.float32) * s.astype(jnp.float32)).reshape(r)
    if r == 0:
        return jnp.zeros((bt, n_out), jnp.float32)
    if not _use_pallas(use_pallas) and not interpret:
        return ref.factor_matvec(x, a, se, b)
    xp = _pad_axis(_pad_axis(x, 0, block_b), 1, 128)
    ap = _pad_axis(_pad_axis(a, 0, 8), 1, 128)
    sp = _pad_axis(se, 0, 8).reshape(-1, 1)
    bp = _pad_axis(_pad_axis(b, 0, 8), 1, block_o)
    out = kernel.factor_matvec(
        xp, ap, sp, bp, block_b=block_b, block_o=block_o, interpret=interpret
    )
    return out[:bt, :n_out]
