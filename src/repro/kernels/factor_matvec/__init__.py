from . import kernel, ops, ref
from .ops import factor_matvec

__all__ = ["kernel", "ops", "ref", "factor_matvec"]
