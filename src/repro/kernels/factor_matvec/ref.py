"""Pure-jnp oracle for the factor-form scoring kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def factor_matvec(
    x: jax.Array, a: jax.Array, s: jax.Array, b: jax.Array
) -> jax.Array:
    """((X @ A^T) * s) @ B with f32 accumulation; s:(r,) or (r, 1).

    X:(bt, n_in), A:(r, n_in), B:(r, n_out) -> (bt, n_out) f32 — the exact
    contraction order the fused kernel implements (rank-r intermediate,
    never the dense n_in x n_out product).
    """
    s = s.reshape(1, a.shape[0])
    t = jnp.dot(x, a.T, preferred_element_type=jnp.float32) * s
    return jnp.dot(t, b, preferred_element_type=jnp.float32)


def dense_matvec(
    x: jax.Array, a: jax.Array, s: jax.Array, b: jax.Array
) -> jax.Array:
    """The materialized-matrix baseline: X @ (A^T diag(s) B) — O(n_in * n_out)
    memory and FLOPs. Exists so tests and the serving benchmark can compare
    factor-form scoring against exactly the computation it avoids."""
    s = s.reshape(a.shape[0])
    w = jnp.einsum("k,ki,kj->ij", s, a.astype(jnp.float32), b.astype(jnp.float32))
    return jnp.dot(x.astype(jnp.float32), w, preferred_element_type=jnp.float32)
