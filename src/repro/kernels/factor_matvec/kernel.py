"""Fused factor-form scoring Pallas kernel — the serving-path hot spot.

A DFW-Trace iterate never exists as a dense d x m matrix: it is the factor
triple ``(A, s, B)`` with ``A: (r, n_in)``, ``B: (r, n_out)`` and the scored
product ``Y = ((X @ A^T) * s) @ B`` for a request batch ``X: (b, n_in)``.
Serving cost is O(b * r * (n_in + n_out)) instead of the dense matmul's
O(b * n_in * n_out) — the whole point of keeping iterates factored
(paper §2.2; rank r <= T after T epochs).

The fusion target is the rank-r intermediate ``T = (X @ A^T) * s``: computed
once per batch block into a VMEM scratch buffer and consumed by every
``n_out`` block without ever visiting HBM. Grid is (batch blocks, out
blocks) with the out axis innermost:

    j == 0:  t_scratch = dot(x_blk, A^T) * s     one MXU pass over A
    all j:   o_blk     = dot(t_scratch, B_blk)   one MXU pass over B total

so X and A are read exactly once per batch block and B exactly once per
call — the information-theoretic minimum for the two-stage product. Both
dots accumulate in f32 via ``preferred_element_type`` regardless of input
dtype. The running iterate scale ``alpha`` is folded into ``s`` by the ops
layer, so the kernel itself is scale-free.

Rows of A/B at indices >= the live rank carry s == 0 (``low_rank`` zeroes
them by construction), so rank padding — like batch padding — is an exact
no-op, not an approximation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _factor_matvec_kernel(x_ref, a_ref, s_ref, b_ref, o_ref, t_ref):
    """o[i, j] = ((x[i] @ a^T) * s) @ b[j]; grid=(batch, out), out innermost."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _stage1():
        t_ref[...] = (
            jnp.dot(
                x_ref[...], a_ref[...].T, preferred_element_type=jnp.float32
            )
            * s_ref[...].T
        )

    o_ref[...] = jnp.dot(
        t_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_b", "block_o", "interpret")
)
def factor_matvec(
    x: jax.Array,
    a: jax.Array,
    s: jax.Array,
    b: jax.Array,
    *,
    block_b: int = 128,
    block_o: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """((X @ A^T) * s) @ B for X:(bt, n_in), A:(r, n_in), s:(r, 1),
    B:(r, n_out) -> (bt, n_out) f32.

    ``bt`` must divide ``block_b`` and ``n_out`` must divide ``block_o``
    (ops.py pads; zero rows/columns are exact no-ops). ``r`` and ``n_in``
    ride whole: VMEM/step is block_b*n_in (X) + r*(n_in + block_o) (A, B)
    + block_b*r (scratch) + the output block — serving ranks are <= the
    epoch budget, so the factors are small by construction; very large
    n_in belongs to the jnp reference path, not this kernel.
    """
    bt, n_in = x.shape
    r = a.shape[0]
    n_out = b.shape[1]
    assert a.shape == (r, n_in), (a.shape, x.shape)
    assert s.shape == (r, 1), s.shape
    assert b.shape == (r, n_out), b.shape
    assert bt % block_b == 0 and n_out % block_o == 0, (
        x.shape, b.shape, block_b, block_o,
    )
    return pl.pallas_call(
        _factor_matvec_kernel,
        grid=(bt // block_b, n_out // block_o),
        in_specs=[
            pl.BlockSpec((block_b, n_in), lambda i, j: (i, 0)),
            pl.BlockSpec((r, n_in), lambda i, j: (0, 0)),
            pl.BlockSpec((r, 1), lambda i, j: (0, 0)),
            pl.BlockSpec((r, block_o), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_o), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((bt, n_out), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_b, r), jnp.float32)],
        interpret=interpret,
    )(x, a, s, b)
