#!/usr/bin/env python
"""Repo-specific static lint CLI (the REPxxx rules in ``repro.analysis.lint``).

Checks the DFW-Trace invariants that generic linters cannot see: collectives
outside the ``repro.comm`` chokepoint, implicit device->host syncs in hot
paths, kernel-package trio completeness, recompilation hazards, and
print-on-tracer debugging leftovers. See docs/ANALYSIS.md for the catalog.

Exit status is 0 when every finding is either fixed, inline-allowed
(``# REPxxx-ok: reason``), or frozen in the checked-in baseline
(``tools/repro_lint_baseline.json``); 1 when *new* findings appear. Stale
baseline entries (debt that has since been fixed) are reported and also fail
the run so the baseline never rots — regenerate it with ``--update-baseline``.

Pure-Python AST analysis: does not import jax or run any repo code, so it is
safe (and fast) on any machine.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_REPO / "src"))

from repro.analysis import lint  # noqa: E402

DEFAULT_BASELINE = _REPO / "tools" / "repro_lint_baseline.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "paths",
        nargs="*",
        default=[str(_REPO / "src" / "repro")],
        help="files or directories to lint (default: src/repro)",
    )
    ap.add_argument(
        "--baseline",
        default=str(DEFAULT_BASELINE),
        help="baseline JSON freezing known debt (default: %(default)s)",
    )
    ap.add_argument(
        "--no-baseline",
        action="store_true",
        help="report every finding, ignoring the baseline",
    )
    ap.add_argument(
        "--update-baseline",
        action="store_true",
        help="rewrite the baseline to the current finding set and exit 0",
    )
    ap.add_argument(
        "--list-rules", action="store_true", help="print the rule catalog"
    )
    args = ap.parse_args(argv)

    if args.list_rules:
        for code in sorted(lint.RULES):
            print(f"{code}  {lint.RULES[code].summary}")
        return 0

    findings = lint.lint_paths([Path(p) for p in args.paths], root=_REPO)

    if args.update_baseline:
        baseline_path = Path(args.baseline)
        old = lint.load_baseline(baseline_path)
        lint.write_baseline(baseline_path, findings, old)
        print(
            f"baseline: wrote {len(findings)} finding(s) to "
            f"{baseline_path.relative_to(_REPO)} — fill in every "
            '"why" before committing'
        )
        return 0

    if args.no_baseline:
        for f in findings:
            print(f.format())
        print(f"{len(findings)} finding(s)")
        return 1 if findings else 0

    baseline = lint.load_baseline(Path(args.baseline))
    new, stale = lint.diff_baseline(findings, baseline)
    for f in new:
        print(f.format())
    for e in stale:
        print(
            "stale baseline entry (debt fixed — shrink with "
            f"--update-baseline): {e['code']} {e['path']}: {e['snippet']}"
        )
    if new:
        print(
            f"repro_lint: {len(new)} new finding(s). Fix the code, add an "
            "inline '# REPxxx-ok: reason', or run tools/repro_lint.py "
            "--update-baseline and justify the new entries."
        )
        return 1
    print(
        f"repro_lint: clean — {len(findings)} finding(s), all baselined"
        f"{f', {len(stale)} stale entr(ies) to shrink' if stale else ''}"
    )
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
