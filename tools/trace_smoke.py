"""Telemetry smoke: run a short instrumented train -> serve pass, export
both sinks, and validate that the trace is loadable and covers all four
instrumented layers.

What it proves (`make trace-smoke`, also run by the CI bench-smoke job):

* an instrumented fit with segment-boundary checkpointing completes with a
  live ``Telemetry`` handle threaded end to end;
* ``TRACE_smoke.jsonl`` parses line-by-line (meta first, metrics last);
* ``TRACE_smoke.trace.json`` is Chrome-trace/Perfetto-loadable (every
  event carries name/ph/ts/pid, ph in {X, i, C}, complete spans have
  nonnegative durations);
* the span stream covers engine segments, reducer exchanges, checkpoint
  writes, and serving dispatches — one name per instrumented layer.

Exit 0 on success, 1 with a reason on any failure.
"""
from __future__ import annotations

import json
import sys
import tempfile
from pathlib import Path

REQUIRED_SPANS = (
    "engine.segment",    # engine: one per scan segment
    "comm.exchange",     # comm: the segment's reducer traffic
    "checkpoint.write",  # checkpoint: async boundary saves
    "serve.dispatch",    # serving: scored batches
)


def run_instrumented(tmp: Path):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro import serve
    from repro.core import tasks
    from repro.launch import dfw
    from repro.obs import Telemetry

    tel = Telemetry()
    n, d, m = 400, 24, 18
    kx, kw = jax.random.split(jax.random.PRNGKey(0))
    w = jax.random.normal(kw, (d, m))
    w = w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=12, schedule="const:2", step_size="linesearch",
        block_epochs=4,  # several segments -> several boundary checkpoints
        checkpoint_dir=str(tmp / "ck"), telemetry=tel,
    )
    res = dfw.fit_serial(task, x, x @ w, cfg=cfg, key=jax.random.PRNGKey(1))

    # Serve from the checkpoint the run just wrote, on the same handle.
    eng = serve.ServingEngine.from_checkpoint(
        tmp / "ck",
        serve.ServeConfig(max_batch=8, verify_kernels=False, telemetry=tel),
    )
    for _ in range(3):
        eng.score(np.ones((8, d), np.float32))
    return tel, res


def validate_jsonl(path: Path) -> int:
    lines = [json.loads(s) for s in path.read_text().splitlines()]
    assert lines, f"{path} is empty"
    assert lines[0].get("type") == "meta", "first JSONL line must be meta"
    assert lines[-1].get("type") == "metrics", "last JSONL line must be metrics"
    assert lines[-1]["data"]["counters"], "metrics snapshot has no counters"
    return len(lines) - 2


def validate_chrome_trace(path: Path) -> list:
    doc = json.loads(path.read_text())
    events = doc["traceEvents"]
    assert events, f"{path} has no traceEvents"
    for ev in events:
        missing = {"name", "ph", "ts", "pid"} - set(ev)
        assert not missing, f"event {ev} missing {missing}"
        assert ev["ph"] in ("X", "i", "C"), f"unexpected phase {ev['ph']}"
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0, f"negative duration in {ev}"
    return events


def main() -> int:
    out_jsonl = Path("TRACE_smoke.jsonl")
    out_trace = Path("TRACE_smoke.trace.json")
    with tempfile.TemporaryDirectory() as tmp:
        tel, res = run_instrumented(Path(tmp))
    tel.write_jsonl(out_jsonl)
    tel.write_chrome_trace(out_trace)

    n_events = validate_jsonl(out_jsonl)
    events = validate_chrome_trace(out_trace)
    assert n_events == len(events), (
        f"sink disagreement: {n_events} JSONL events vs {len(events)} trace"
    )

    names = {ev["name"] for ev in events}
    missing = [s for s in REQUIRED_SPANS if s not in names]
    if missing:
        print(f"trace-smoke: FAIL — missing spans {missing}; got {sorted(names)}")
        return 1
    print(
        f"trace-smoke: OK — {len(events)} events, {res.epochs_run} epochs, "
        f"spans cover {', '.join(REQUIRED_SPANS)}; wrote {out_jsonl} + {out_trace}"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
