#!/usr/bin/env python
"""Run every declared HLO/dispatch contract (``repro.analysis.contracts``).

Compiles the engine, power-method, and serving layers' contract probes on
8 fake CPU devices and asserts their declared invariants against the walked
HLO and runtime counters: 2K collective rounds per epoch, one scan dispatch
per K(t) segment, and never materializing a d x m intermediate while serving.

Exit 0 when every contract holds; 1 with the offending HLO line / counter on
the first violation. Pairs with tools/repro_lint.py under ``make analyze``.
"""
from __future__ import annotations

import os
import sys
from pathlib import Path

_REPO = Path(__file__).resolve().parents[1]
sys.path.insert(0, str(_REPO / "src"))

# Must be set before jax import: the contract probes shard over 8 devices.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")


def main() -> int:
    from repro.analysis import contracts

    return contracts.verify_declared(verbose=True)


if __name__ == "__main__":
    raise SystemExit(main())
