"""Distributed DFW-Trace end to end on 8 simulated workers.

Runs the *same* shard_map program a real multi-host launch would lower, on
fake CPU devices: the sample axis is sharded row-wise across 8 workers, each
FW epoch exchanges only the O(d+m) power-iteration vectors via psum (never a
d x m gradient), and the paper's sampled-worker/straggler mode drops workers
per epoch without derailing convergence.

Run:  PYTHONPATH=src python examples/distributed_dfw.py
(sets XLA_FLAGS itself — run as a standalone script)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import low_rank, tasks  # noqa: E402
from repro.launch import dfw  # noqa: E402

# --- paper §5.1 synthetic multitask least squares --------------------------
n, d, m, rank = 4096, 64, 48, 8
key = jax.random.PRNGKey(0)
ku, kv, kx = jax.random.split(key, 3)
u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
s = jnp.linspace(1.0, 0.1, rank)
w_true = (u * (s / jnp.sum(s))) @ v.T  # ||W*||_* = 1, rank 8
x = jax.random.normal(kx, (n, d))
y = x @ w_true

cfg = dfw.DFWConfig(mu=1.0, num_epochs=30, schedule="log",
                    step_size="linesearch")

# --- serial reference vs 8-way sharded run ---------------------------------
serial = dfw.fit_serial(tasks.MultiTaskLeastSquares(d=d, m=m), x, y,
                        cfg=cfg, key=jax.random.PRNGKey(1))
shard = dfw.fit(tasks.MultiTaskLeastSquares(d=d, m=m), x, y,
                cfg=cfg, key=jax.random.PRNGKey(1), num_workers=8)
print(f"{'epoch':>5} {'K(t)':>4} {'serial loss':>12} {'sharded loss':>12} "
      f"{'gap':>10}")
for t in range(0, cfg.num_epochs, 5):
    print(f"{t:>5} {shard.history['k'][t]:>4} "
          f"{serial.history['loss'][t]:>12.5f} "
          f"{shard.history['loss'][t]:>12.5f} "
          f"{shard.history['gap'][t]:>10.5f}")
drift = max(abs(a - b) / (abs(a) + 1e-12)
            for a, b in zip(serial.history["loss"], shard.history["loss"]))
print(f"max relative serial-vs-sharded loss drift: {drift:.2e}")
assert drift < 1e-4

w_hat = low_rank.materialize(shard.iterate)
rel = float(jnp.linalg.norm(w_hat - w_true) / jnp.linalg.norm(w_true))
print(f"recovery ||W-W*||/||W*|| = {rel:.3f}, rank <= {int(shard.iterate.count)}")

# The engine ran the whole log-schedule fit as O(log T) scan dispatches with
# host transfers only at segment boundaries (vs one dispatch + four blocking
# scalar pulls per epoch in the pre-engine driver).
print(f"engine: {shard.stats['dispatches']} dispatches / "
      f"{shard.stats['host_syncs']} host syncs for {shard.epochs_run} epochs")

# --- gap-certificate early stop --------------------------------------------
# The duality gap g(W^t) >= F(W^t) - F* is computed on device every epoch;
# gap_tol stops the run at segment granularity once it certifies the iterate.
import dataclasses  # noqa: E402

cfg_g = dataclasses.replace(cfg, num_epochs=200, gap_tol=5.0,
                            block_epochs=25)
stopped = dfw.fit(tasks.MultiTaskLeastSquares(d=d, m=m), x, y,
                  cfg=cfg_g, key=jax.random.PRNGKey(1), num_workers=8)
print(f"gap_tol=5.0: certified after {stopped.epochs_run}/200 epochs "
      f"(final gap {stopped.history['gap'][-1]:.3f}, "
      f"{stopped.stats['dispatches']} dispatches)")
assert stopped.epochs_run < 200

# --- sampled-worker (straggler) mode ---------------------------------------
cfg_s = dfw.DFWConfig(mu=1.0, num_epochs=30, schedule="log",
                      step_size="linesearch", sample_prob=0.6)
sampled = dfw.fit(tasks.MultiTaskLeastSquares(d=d, m=m), x, y,
                  cfg=cfg_s, key=jax.random.PRNGKey(1), num_workers=8)
alive = jnp.sum(sampled.masks > 0, axis=1)
print(f"sampled-worker mode (p=0.6): alive/epoch min={int(jnp.min(alive))} "
      f"mean={float(jnp.mean(alive)):.1f}; "
      f"final loss {sampled.final_loss:.4f} "
      f"(full-participation {shard.final_loss:.4f})")
assert sampled.final_loss < 0.1 * sampled.history["loss"][0]

# --- compressed collectives (comm=) ----------------------------------------
# Route the power-iteration exchanges through the int8 reducer: stochastic-
# rounding quantize -> s8 psum -> dequantize, ~4x fewer wire bytes, same
# converged loss to within a couple percent (scalar psums stay exact).
cfg_q = dataclasses.replace(cfg, comm="int8")
quant = dfw.fit(tasks.MultiTaskLeastSquares(d=d, m=m), x, y,
                cfg=cfg_q, key=jax.random.PRNGKey(1), num_workers=8)
q_rel = abs(quant.final_loss - shard.final_loss) / shard.final_loss
print(f"comm='int8': final loss {quant.final_loss:.4f} "
      f"(dense {shard.final_loss:.4f}, rel diff {q_rel:.3%})")
assert q_rel < 0.05

# --- communication accounting (paper Table 1) ------------------------------
k_total = sum(shard.history["k"])
bytes_per_iter = 2 * (d + m) * 4  # psum of u (d,) + v (m,) in f32
int8_per_iter = (d + m) * 2 + 2 * 2 * 4  # s8 wire + two f32 scale pmaxes
print(f"total power iterations: {k_total}; per-worker wire traffic "
      f"{k_total * bytes_per_iter / 1e3:.1f} KB dense / "
      f"{k_total * int8_per_iter / 1e3:.1f} KB int8 vs naive gradient sync "
      f"{cfg.num_epochs * d * m * 4 / 1e3:.1f} KB")
