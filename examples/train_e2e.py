"""End-to-end driver: train a ~100M-param qwen2-family model for a few
hundred steps on the synthetic pipeline, with async checkpointing, then
fine-tune a trace-norm-constrained head with DFW-TRACE on its features.

Run:  PYTHONPATH=src python examples/train_e2e.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import dfw_head
from repro.launch import train
from repro.models import lm
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    """~100M-param member of the qwen2 family (same topology as qwen2-1.5b)."""
    return dataclasses.replace(
        get_config("qwen2_1_5b", smoke=True),
        name="qwen2-100m",
        num_layers=6,
        d_model=512,
        num_heads=8,
        num_kv_heads=2,
        d_ff=1408,
        vocab_size=32000,
        dtype="float32",
        remat="none",
        seq_chunk=256,
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    args = ap.parse_args()

    cfg = model_100m()
    n_params = sum(
        x.size for x in jax.tree.leaves(
            jax.eval_shape(lambda k: lm.init_params(cfg, k), jax.random.PRNGKey(0)))
    )
    print(f"model: {cfg.name}, {n_params/1e6:.1f}M params")

    import repro.configs as configs_pkg

    # register the custom config so the generic driver can resolve it
    class _Mod:
        SMOKE = cfg
        CONFIG = cfg

    configs_pkg.ARCH_IDS.append("qwen2_100m")
    import sys

    sys.modules["repro.configs.qwen2_100m"] = _Mod()

    with tempfile.TemporaryDirectory() as ckpt_dir:
        params, _, history = train.train(
            arch="qwen2_100m",
            steps=args.steps,
            seq_len=args.seq_len,
            global_batch=args.global_batch,
            ckpt_dir=ckpt_dir,
            ckpt_every=100,
            log_every=20,
            peak_lr=3e-4,
        )
    first, last = history[0][1], history[-1][1]
    print(f"loss: {first:.3f} -> {last:.3f} over {args.steps} steps")
    assert last < first, "training must reduce loss"

    # --- paper integration: DFW-TRACE head on the trained features ---------
    key = jax.random.PRNGKey(99)
    toks = jax.random.randint(key, (8, args.seq_len), 0, cfg.vocab_size)
    x, _ = dfw_head.extract_features(
        params, [{"tokens": toks, "labels": toks}], cfg)
    # standardize features (trained-backbone hidden states have large norms;
    # the paper's deep features are similarly normalized before the head)
    x = (x - x.mean(0)) / (x.std(0) + 1e-6)
    m = 32
    y = jnp.argmax(
        x @ jax.random.normal(jax.random.fold_in(key, 1), (x.shape[1], m)), axis=1)
    res = dfw_head.train_head(x, y, m, mu=15.0, num_epochs=40)
    print(f"DFW-TRACE head: loss {res.history['loss'][0]:.1f} -> "
          f"{res.history['loss'][-1]:.1f}, rank <= {int(res.iterate.count)}")


if __name__ == "__main__":
    main()
