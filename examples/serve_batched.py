"""Train-and-serve: factor-form scoring with live checkpoint hot-swap.

The deployment story of DFW-Trace end to end, at smoke scale:

1. fit a multi-task least-squares model partway and checkpoint it;
2. bring up a ServingEngine straight from the checkpoint directory — the
   scorer reads ONLY the packed factors (never the training state) and
   scores requests as ``alpha * ((x @ U^T) * s) @ V``, so the dense d x m
   matrix is never built;
3. push micro-batched request traffic through it (individual submits,
   one padded dispatch);
4. keep training to a better model, checkpoint again, hot-swap the server
   onto the new step WITHOUT recompiling (same rank bucket) — a ticket
   dispatched before the swap still scores against the old model, one
   submitted after scores against the new one.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro import serve
from repro.core import low_rank, tasks
from repro.launch import dfw

# --- 1. a planted low-rank problem + a partial training run ---------------
n, d, m = 2048, 64, 48
key = jax.random.PRNGKey(0)
kx, kw, kq = jax.random.split(key, 3)
w_true = jax.random.normal(kw, (d, m))
x = jax.random.normal(kx, (n, d))
y = x @ (w_true / jnp.linalg.norm(w_true, ord="nuc"))

ckpt_dir = tempfile.mkdtemp(prefix="dfw_serve_")
task = tasks.MultiTaskLeastSquares(d=d, m=m)


def fit_to(num_epochs):
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=num_epochs, schedule="const:2",
        step_size="linesearch", block_epochs=4, max_rank=24,
        checkpoint_dir=ckpt_dir,
        resume_from=ckpt_dir if num_epochs > 8 else None,
    )
    return dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))


early = fit_to(8)
print(f"trained 8 epochs: loss {early.history['loss'][-1]:.4f} -> "
      f"checkpointed at {ckpt_dir}")

# --- 2. serving engine straight from the checkpoint dir -------------------
eng = serve.ServingEngine.from_checkpoint(
    ckpt_dir, serve.ServeConfig(max_batch=16, rank_block=24)
)
print(f"serving step {eng.model.step}: live rank {eng.model.live_rank} "
      f"(bucket {eng.model.capacity}), stats {eng.stats}")

# --- 3. micro-batched request traffic -------------------------------------
queries = np.asarray(jax.random.normal(kq, (40, d)), np.float32)
batcher = serve.MicroBatcher(eng, flush_at=16)
tickets = [batcher.submit(q) for q in queries]
batcher.flush()  # tail batch (40 = 2 full dispatches + 8)

oracle = np.asarray(queries @ low_rank.materialize(early.iterate))
worst = max(float(np.abs(t.result() - oracle[i]).max())
            for i, t in enumerate(tickets))
print(f"scored {len(tickets)} requests in {eng.stats['dispatches']} padded "
      f"dispatches; max |factor - dense| = {worst:.2e}")
assert worst < 1e-4

# --- 4. train further, hot-swap, prove old/new isolation ------------------
in_flight = eng.score_async(queries[:5])        # dispatched against v0
late = fit_to(20)                               # resumes, writes newer steps
compiles_before = eng.stats["compilations"]
model = eng.load(ckpt_dir)                      # hot-swap onto latest step
assert eng.stats["compilations"] == compiles_before, "swap must not compile"

old_scores = in_flight.block()                  # completes on the OLD model
assert np.abs(old_scores - oracle[:5]).max() < 1e-4
new_ticket = batcher.submit(queries[0])
new_oracle = np.asarray(queries[:1] @ low_rank.materialize(late.iterate))
assert np.abs(new_ticket.result() - new_oracle[0]).max() < 1e-4
assert new_ticket.version == model.version != in_flight.version

print(f"hot-swapped to step {model.step} (live rank {model.live_rank}) with "
      f"zero recompiles; in-flight batch kept v{in_flight.version} scores, "
      f"new traffic scores v{new_ticket.version}")
print(f"loss {early.history['loss'][-1]:.4f} -> {late.history['loss'][-1]:.4f}; "
      f"final stats {eng.stats}")
print("train-and-serve demo OK")
