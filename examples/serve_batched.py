"""Batched serving example: prefill-free incremental decoding across the
model zoo, including the SSM/hybrid families with constant-memory state.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve

for arch in ("qwen2_1_5b", "rwkv6_7b", "zamba2_2_7b"):
    out = serve.generate(
        arch=arch, batch=4, prompt_len=12, max_new_tokens=16,
        temperature=0.8, smoke=True, seed=7,
    )
    print(f"{arch}: sample tokens {out[0][:8].tolist()}\n")
