"""Distributed matrix completion with DFW-Trace on 8 simulated workers.

The paper's third task (§2.3): recover a low-rank matrix from a sparse set of
observed entries, F(W) = 1/2 sum_{(i,j) in Omega} (W_ij - M_ij)^2 on the
trace-norm ball. The gradient lives only on Omega, so each worker stores its
entry shard in COO layout (O(|Omega_j|) sufficient information, App. B) and
the power-method matvecs are segment gather/scatter chains routed through the
``kernels/mc_matvec`` Pallas ops. Entries are sharded by row blocks and padded
to equal shard sizes with zero-weight no-op entries so shapes stay static
under shard_map.

Run:  PYTHONPATH=src python examples/matrix_completion.py
(sets XLA_FLAGS itself — run as a standalone script)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.core import low_rank, tasks  # noqa: E402
from repro.launch import dfw  # noqa: E402

# --- synthetic rank-r ground truth, sparse observations --------------------
d, m, rank, obs_frac = 256, 192, 6, 0.25
key = jax.random.PRNGKey(0)
ku, kv, ko, ks = jax.random.split(key, 4)
u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
sv = jnp.linspace(1.0, 0.2, rank)
w_true = (u * (sv / jnp.sum(sv))) @ v.T  # ||W*||_* = 1, rank 6

mask = jax.random.bernoulli(ko, obs_frac, (d, m))
rows, cols = jnp.nonzero(mask)
vals = w_true[rows, cols]

# 90/10 train / held-out split of the observed entries
holdout = jax.random.bernoulli(ks, 0.1, rows.shape)
tr, ho = jnp.nonzero(~holdout)[0], jnp.nonzero(holdout)[0]
print(f"observed {rows.size} of {d * m} entries "
      f"({100 * rows.size / (d * m):.0f}%), {ho.size} held out")

task = tasks.MatrixCompletion(d=d, m=m)
cfg = dfw.DFWConfig(mu=1.0, num_epochs=40, schedule="log",
                    step_size="linesearch")

# --- serial reference vs 8-way row-block-sharded run -----------------------
idx, yw = tasks.pack_observations(rows[tr], cols[tr], vals[tr])
serial = dfw.fit_serial(task, idx, yw, cfg=cfg, key=jax.random.PRNGKey(1))

idx8, yw8 = dfw.shard_observations(rows[tr], cols[tr], vals[tr], 8, d, m=m)
shard = dfw.fit(task, idx8, yw8, cfg=cfg, key=jax.random.PRNGKey(1),
                num_workers=8)
print(f"padding overhead: {idx8.shape[0] / tr.size - 1:.1%} "
      f"({idx8.shape[0] - tr.size} zero-weight entries)")


def holdout_rmse(it):
    pred = low_rank.gather_entries(it, rows[ho], cols[ho])
    return float(jnp.sqrt(jnp.mean((pred - vals[ho]) ** 2)))


print(f"{'epoch':>5} {'K(t)':>4} {'serial loss':>12} {'sharded loss':>12} "
      f"{'gap':>10}")
for t in range(0, cfg.num_epochs, 5):
    print(f"{t:>5} {shard.history['k'][t]:>4} "
          f"{serial.history['loss'][t]:>12.6f} "
          f"{shard.history['loss'][t]:>12.6f} "
          f"{shard.history['gap'][t]:>10.6f}")
print(f"final train loss (returned iterate): serial {serial.final_loss:.6f} "
      f"sharded {shard.final_loss:.6f}")

drift = max(abs(a - b) / (abs(a) + 1e-12)
            for a, b in zip(serial.history["loss"], shard.history["loss"]))
print(f"max relative serial-vs-sharded loss drift: {drift:.2e}")
assert drift < 1e-4

rmse = holdout_rmse(shard.iterate)
base = float(jnp.sqrt(jnp.mean(vals[ho] ** 2)))  # predict-zero baseline
print(f"held-out RMSE {rmse:.5f} vs predict-zero {base:.5f} "
      f"(rank <= {int(shard.iterate.count)})")
assert rmse < 0.35 * base
assert shard.final_loss < 0.05 * shard.history["loss"][0]

# --- communication accounting ----------------------------------------------
k_total = sum(shard.history["k"])
print(f"total power iterations: {k_total}; per-worker wire traffic "
      f"{k_total * 2 * (d + m) * 4 / 1e3:.1f} KB vs naive gradient sync "
      f"{cfg.num_epochs * d * m * 4 / 1e3:.1f} KB")
