"""Quickstart: DFW-TRACE on multi-task least squares in ~40 lines.

Reproduces the paper's core result at laptop scale: a rank-10 matrix with
unit trace norm is recovered from linear measurements using only rank-1
updates and 2 power iterations per epoch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fit, low_rank, tasks

# --- synthetic problem (paper §5.1): W* has rank 10, ||W*||_* = 1 ----------
key = jax.random.PRNGKey(0)
n, d, m, rank = 20_000, 300, 300, 10
ku, kv, kx = jax.random.split(key, 3)
u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
s = jnp.linspace(1.0, 0.1, rank)
w_true = (u * (s / s.sum())) @ v.T
x = jax.random.normal(kx, (n, d))
y = x @ w_true

# --- DFW-TRACE --------------------------------------------------------------
# The run executes on the device-resident epoch engine: a const:K schedule is
# ONE jit dispatch (epochs advance inside a lax.scan, histories stay on
# device), so the callback fires per scan *segment*, not per epoch —
# block_epochs bounds the segment length to get periodic progress. gap_tol
# stops the run once the duality-gap certificate g(W^t) <= tol (paper Thm 2),
# checked on device; FitResult.epochs_run records where it stopped.
task = tasks.MultiTaskLeastSquares(d=d, m=m)
result = fit(
    task,
    task.init_state(x, y),
    mu=1.0,  # trace-norm budget (the paper sets mu = ||W*||_* = 1)
    num_epochs=50,
    key=jax.random.PRNGKey(1),
    schedule="const:2",  # DFW-TRACE-2: 2 power iterations per epoch
    step_size="linesearch",  # closed-form for least squares (paper App. B)
    gap_tol=1e-3,  # stop on the duality-gap certificate
    block_epochs=10,  # check the certificate / report progress every 10
    # per-segment progress; rows after an early stop are NaN, so report the
    # last epoch that actually ran in this block
    callback=lambda start, aux: (lambda live: print(
        f"epochs {start:3d}-{start + live.size - 1:3d}  "
        f"F(W)={live[-1]:10.4f}  gap<={aux.gap[live.size - 1]:9.4f}  "
        f"gamma={aux.gamma[live.size - 1]:.3f}"
    ))(aux.loss[np.isfinite(aux.loss)]),
)
certified = result.epochs_run < 50
print(f"ran {result.epochs_run}/50 epochs"
      + (" (gap certificate met)" if certified else "")
      + f" in {result.stats['dispatches']} jit dispatches, "
      f"{result.stats['host_syncs']} host syncs")

w_hat = low_rank.materialize(result.iterate)
rel_err = float(jnp.linalg.norm(w_hat - w_true) / jnp.linalg.norm(w_true))
print(f"\nrecovered rank-{int(result.iterate.count)} iterate, "
      f"relative error {rel_err:.4f}")
print(f"iterate storage: factored O(t(d+m)) = "
      f"{int(result.iterate.count) * (d + m) * 4 / 1e6:.2f} MB "
      f"vs dense O(dm) = {d * m * 4 / 1e6:.2f} MB")
assert rel_err < 0.25
