"""Quickstart: DFW-TRACE on multi-task least squares in ~40 lines.

Reproduces the paper's core result at laptop scale: a rank-10 matrix with
unit trace norm is recovered from linear measurements using only rank-1
updates and 2 power iterations per epoch.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import fit, low_rank, tasks

# --- synthetic problem (paper §5.1): W* has rank 10, ||W*||_* = 1 ----------
key = jax.random.PRNGKey(0)
n, d, m, rank = 20_000, 300, 300, 10
ku, kv, kx = jax.random.split(key, 3)
u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
s = jnp.linspace(1.0, 0.1, rank)
w_true = (u * (s / s.sum())) @ v.T
x = jax.random.normal(kx, (n, d))
y = x @ w_true

# --- DFW-TRACE --------------------------------------------------------------
task = tasks.MultiTaskLeastSquares(d=d, m=m)
result = fit(
    task,
    task.init_state(x, y),
    mu=1.0,  # trace-norm budget (the paper sets mu = ||W*||_* = 1)
    num_epochs=50,
    key=jax.random.PRNGKey(1),
    schedule="const:2",  # DFW-TRACE-2: 2 power iterations per epoch
    step_size="linesearch",  # closed-form for least squares (paper App. B)
    callback=lambda t, aux: print(
        f"epoch {t:3d}  F(W)={float(aux.loss):10.4f}  gap<={float(aux.gap):9.4f} "
        f"gamma={float(aux.gamma):.3f}"
    ) if t % 10 == 0 else None,
)

w_hat = low_rank.materialize(result.iterate)
rel_err = float(jnp.linalg.norm(w_hat - w_true) / jnp.linalg.norm(w_true))
print(f"\nrecovered rank-{int(result.iterate.count)} iterate, "
      f"relative error {rel_err:.4f}")
print(f"iterate storage: factored O(t(d+m)) = "
      f"{int(result.iterate.count) * (d + m) * 4 / 1e6:.2f} MB "
      f"vs dense O(dm) = {d * m * 4 / 1e6:.2f} MB")
assert rel_err < 0.25
