"""The paper's ImageNet pipeline on the LM zoo: frozen backbone features ->
trace-norm-constrained classifier head via the DISTRIBUTED power method.

This script runs the real multi-worker code path on 8 simulated devices
(the same shard_map program the 256-chip dry-run lowers): features and labels
are sharded across workers; each FW epoch exchanges only the O(d+m)
power-iteration vectors (2*K psums), never a d x m gradient.

Run:  PYTHONPATH=src python examples/distributed_head_training.py
(sets XLA_FLAGS itself — run as a standalone script)
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import dfw_head  # noqa: E402
from repro.models import lm  # noqa: E402

# --- 1. frozen backbone features (stand-in for the paper's ResNet50) -------
cfg = get_config("qwen2_1_5b", smoke=True)
params = lm.init_params(cfg, jax.random.PRNGKey(0))
batches = []
for i in range(4):
    key = jax.random.PRNGKey(10 + i)
    toks = jax.random.randint(key, (4, 64), 0, cfg.vocab_size)
    batches.append({"tokens": toks, "labels": toks})
x, _ = dfw_head.extract_features(params, batches, cfg)
print(f"extracted features: {x.shape} from {cfg.name}")

# --- 2. planted 1000-class-style problem (low-rank class structure) --------
m = 64
key = jax.random.PRNGKey(3)
w_star = jax.random.normal(key, (x.shape[1], 10)) @ jax.random.normal(
    jax.random.fold_in(key, 1), (10, m)
)
y = jnp.argmax(x @ w_star, axis=1)

# --- 3. distributed DFW-TRACE over 8 workers -------------------------------
mesh = jax.make_mesh((8,), ("data",))
res = dfw_head.sharded_fit(mesh, x, y, m, mu=20.0, num_epochs=40,
                           schedule="const:2")
err5 = dfw_head.top_k_error(res.iterate, x, y, k=5)
print(f"final objective {res.history['loss'][-1]:.2f} "
      f"(epoch 0: {res.history['loss'][0]:.2f}), top-5 err {err5:.3f}, "
      f"head rank <= {int(res.iterate.count)}")

d, v = x.shape[1], m
per_epoch_vectors = 2 * 2 * (d + v) * 4  # 2 power iters x (u,v) x f32
print(f"per-epoch wire traffic per worker: {per_epoch_vectors/1e3:.1f} KB "
      f"(naive gradient sync would be {d*v*4/1e3:.1f} KB)")
assert res.history["loss"][-1] < res.history["loss"][0]
