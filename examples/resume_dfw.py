"""Kill-and-resume: a durable distributed DFW-Trace run on 8 workers.

Phase 1 launches an 8-way fit with segment-boundary checkpointing and kills
the *process* (SIGKILL, no cleanup) partway through — the brutal version of
a preempted worker pool. Phase 2 resumes from the last durable checkpoint
on the same 8-way mesh and must reproduce the uninterrupted trajectory bit
for bit. Phase 3 resumes the same checkpoint onto a *4*-worker mesh (half
the pool evaporated): the row-blocked state is re-sharded, per-worker comm
state re-initialized, and the run still converges to the same solution.

Run:  PYTHONPATH=src python examples/resume_dfw.py
(spawns its own subprocesses; sets XLA_FLAGS itself)
"""
import json
import os
import subprocess
import sys
import tempfile
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
CKPT = tempfile.mkdtemp(prefix="dfw_ckpt_")

# The worker program: one fit, checkpointed every segment. `nw` and
# `resume` come from argv so the same program plays victim and survivor.
WORKER = r"""
import json, os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

ckpt_dir, nw, resume = sys.argv[1], int(sys.argv[2]), sys.argv[3] == "resume"
n, d, m = 4096, 64, 48
key = jax.random.PRNGKey(0)
kx, kw = jax.random.split(key)
w = jax.random.normal(kw, (d, m))
x = jax.random.normal(kx, (n, d))
y = x @ (w / jnp.linalg.norm(w, ord="nuc"))

cfg = dfw.DFWConfig(
    mu=1.0, num_epochs=40, schedule="const:2", step_size="linesearch",
    block_epochs=5,                       # checkpoint cadence = 5 epochs
    checkpoint_dir=None if resume else ckpt_dir,
    resume_from=ckpt_dir if resume else None,
)
res = dfw.fit(tasks.MultiTaskLeastSquares(d=d, m=m), x, y, cfg=cfg,
              key=jax.random.PRNGKey(1), num_workers=nw)
print("RESULT " + json.dumps({
    "final_loss": res.final_loss,
    "loss_history": res.history["loss"],
    "epochs_run": res.epochs_run,
}), flush=True)
"""


def run_worker(nw, mode, kill_after=None):
    env = {**os.environ, "PYTHONPATH": SRC}
    env.pop("XLA_FLAGS", None)
    proc = subprocess.Popen(
        [sys.executable, "-c", WORKER, CKPT, str(nw), mode],
        stdout=subprocess.PIPE, text=True, env=env,
    )
    if kill_after is not None:
        # Wait for the first checkpoints to land, then SIGKILL mid-run.
        deadline = time.time() + 300
        while time.time() < deadline:
            steps = sorted(Path(CKPT).glob("step_*"))
            if len(steps) >= kill_after and proc.poll() is None:
                proc.kill()
                proc.wait()
                return None
            if proc.poll() is not None:
                break  # finished before we got to kill it; use its result
            time.sleep(0.05)
    out, _ = proc.communicate(timeout=600)
    assert proc.returncode == 0, out
    line = [ln for ln in out.splitlines() if ln.startswith("RESULT ")][-1]
    return json.loads(line[len("RESULT "):])


# --- uninterrupted reference (fresh checkpoint dir kept for the kill run) --
ref = run_worker(8, "fresh")
print(f"reference 8-way run: {ref['epochs_run']} epochs, "
      f"final loss {ref['final_loss']:.6f}")

# --- phase 1: same run again, SIGKILLed after two durable checkpoints ------
for p in Path(CKPT).glob("step_*"):
    for f in p.iterdir():
        f.unlink()
    p.rmdir()
killed = run_worker(8, "fresh", kill_after=2)
steps = sorted(int(p.name.split("_")[1]) for p in Path(CKPT).glob("step_*"))
assert killed is None or steps, "expected durable checkpoints"
print(f"killed mid-run; durable checkpoint steps on disk: {steps}")

# --- phase 2: resume on the same 8-way mesh → bit-exact ---------------------
resumed = run_worker(8, "resume")
assert resumed["loss_history"] == ref["loss_history"], "trajectory diverged!"
assert resumed["final_loss"] == ref["final_loss"]
print(f"8-way resume: bit-exact — {resumed['epochs_run']} total epochs, "
      f"final loss {resumed['final_loss']:.6f} (identical bits)")

# --- phase 3: elastic resume onto 4 workers --------------------------------
elastic = run_worker(4, "resume")
rel = abs(elastic["final_loss"] - ref["final_loss"]) / abs(ref["final_loss"])
print(f"elastic 8->4 resume: final loss {elastic['final_loss']:.6f} "
      f"(rel delta {rel:.2e} vs uninterrupted)")
assert rel < 1e-3
print("kill-and-resume demo OK")
