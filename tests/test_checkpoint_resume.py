"""Fault tolerance: segment-boundary checkpoint/resume (repro.checkpoint).

Covers the store's load-bearing guarantees (async-write error surfacing,
crash-mid-write manifest atomicity, pruning), the run-level payload
round-trip on real DFW carry pytrees, the two resume contracts — bit-exact
(same mesh/comm: identical trajectory bits) and elastic (8->4 remesh:
converges to the same solution) — warm restart (changing gap_tol /
schedule / comm at the resume point), and the hot-path pin (a checkpointer
adds zero dispatches; saves happen only at segment boundaries).

Multi-device coverage runs in subprocesses with 8 fake CPU devices,
matching tests/test_engine.py.
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint
from repro.checkpoint.store import CheckpointStore
from repro.core import frank_wolfe, low_rank, tasks
from repro.launch import dfw

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def _mtls(key, n=400, d=24, m=18):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (d, m))
    w = w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    return x, x @ w


# ---------------------------------------------------------------------------
# CheckpointStore: error surfacing, atomicity, pruning
# ---------------------------------------------------------------------------


def test_save_async_error_surfaces_on_wait_with_context(tmp_path):
    """A background write failure must name the step and path when wait()
    re-raises it — the tentpole makes this path load-bearing. (Failure
    injection: a FILE squatting on the .tmp staging path makes the write
    thread blow up early.)"""
    store = CheckpointStore(tmp_path / "ck")
    blocker = tmp_path / "ck" / ".tmp_step_00000007"
    blocker.write_text("a file where the staging directory must go")
    store.save_async(7, {"x": np.arange(3)})
    with pytest.raises(RuntimeError, match=r"step 7.*step_00000007") as ei:
        store.wait()
    assert ei.value.__cause__ is not None  # original OSError preserved
    assert store.latest_step() is None  # nothing durable was claimed
    # the error is consumed: the store is usable again
    store.wait()
    blocker.unlink()
    store.save_async(7, {"x": np.arange(3)})
    store.wait()
    assert store.latest_step() == 7


def test_save_async_error_surfaces_on_next_save(tmp_path):
    store = CheckpointStore(tmp_path / "ck")
    (tmp_path / "ck" / ".tmp_step_00000003").write_text("blocker")
    store.save_async(3, {"x": np.zeros(2)})
    time.sleep(0.05)
    with pytest.raises(RuntimeError, match="step 3"):
        store.save_async(4, {"x": np.zeros(2)})


def test_crash_mid_write_is_invisible(tmp_path):
    """A partial step (tmp dir never renamed) must not be listed; restore
    and latest_step see only the previous complete step."""
    store = CheckpointStore(tmp_path / "ck")
    store.save(5, {"x": np.arange(4, dtype=np.float32)})
    # simulate a crash mid-write of step 10: data present, no atomic rename
    partial = tmp_path / "ck" / ".tmp_step_00000010"
    partial.mkdir()
    np.save(partial / "leaf_00000.npy", np.arange(9))
    (partial / "manifest.json").write_text("{\"truncated")  # even a torn manifest
    assert store.steps() == [5]
    assert store.latest_step() == 5
    step, tree, _ = store.restore()
    assert step == 5
    np.testing.assert_array_equal(tree["x"], np.arange(4, dtype=np.float32))


def test_keep_last_prunes_old_steps(tmp_path):
    store = CheckpointStore(tmp_path / "ck", keep_last=2)
    for s in (1, 2, 3, 4):
        store.save(s, {"x": np.full(2, s)})
    assert store.steps() == [3, 4]
    step, tree, _ = store.restore()
    assert step == 4 and tree["x"][0] == 4


def test_manifest_format_versioning(tmp_path):
    store = CheckpointStore(tmp_path / "ck")
    out = store.save(1, {"x": np.zeros(1)})
    manifest = json.loads((out / "manifest.json").read_text())
    assert manifest["format"] == checkpoint.MANIFEST_FORMAT
    manifest["format"] = checkpoint.MANIFEST_FORMAT + 1
    (out / "manifest.json").write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="manifest format"):
        store.restore(1)


# ---------------------------------------------------------------------------
# Iterate live-prefix packing
# ---------------------------------------------------------------------------


def test_pack_unpack_live_roundtrip_bitexact():
    key = jax.random.PRNGKey(0)
    it = low_rank.init(10, 6, 4)
    for t in range(3):
        ku, kv = jax.random.split(jax.random.fold_in(key, t))
        it = low_rank.fw_update(
            it, jax.random.normal(ku, (6,)), jax.random.normal(kv, (4,)),
            jnp.float32(2.0 / (t + 2)), 1.0,
        )
    packed = low_rank.pack_live(it)
    assert packed["u"].shape == (3, 6)  # live prefix only, not capacity 10
    back = low_rank.unpack_live(packed, 10)
    for a, b in zip(back, it):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # re-padding to a LARGER capacity keeps the same factors
    grown = low_rank.unpack_live(packed, 14)
    np.testing.assert_array_equal(np.asarray(grown.u[:3]), np.asarray(it.u[:3]))
    assert not np.any(np.asarray(grown.u[3:]))
    with pytest.raises(ValueError, match="max_rank"):
        low_rank.unpack_live(packed, 2)


# ---------------------------------------------------------------------------
# Serial bit-exact resume on real carries (dense / int8 / topk)
# ---------------------------------------------------------------------------


def _fit_full_then_resume(tmp_path, comm, step_size="linesearch"):
    x, y = _mtls(jax.random.PRNGKey(0))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / f"ck_{comm.replace(':', '_')}")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=20, schedule="const:2", step_size=step_size,
        comm=comm, block_epochs=5, checkpoint_dir=ckdir, checkpoint_keep=None,
        verify_kernels=False,
    )
    full = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    rcfg = dataclasses.replace(
        cfg, checkpoint_dir=None, resume_from=ckdir, resume_step=10
    )
    res = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    return full, res


@pytest.mark.parametrize("comm", ["dense", "int8", "topk:6"])
def test_serial_resume_bitexact(tmp_path, comm):
    """Resume from an interior segment boundary reproduces the uninterrupted
    trajectory and final iterate bit for bit — including the int8
    stochastic-rounding stream (keyed off the carried epoch counter) and
    topk's per-worker error-feedback residuals (restored from the carry)."""
    full, res = _fit_full_then_resume(tmp_path, comm)
    assert res.epochs_run == full.epochs_run == 20
    for k in ("loss", "gap", "sigma", "gamma", "k"):
        assert res.history[k] == full.history[k], k
    assert res.final_loss == full.final_loss
    for name, a, b in zip(res.iterate._fields, res.iterate, full.iterate):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)
    for name, a, b in zip(res.state._fields, res.state, full.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)


def test_serial_resume_legacy_engine_matches(tmp_path):
    """The legacy (per-epoch) engine honors the same checkpoint contract."""
    x, y = _mtls(jax.random.PRNGKey(3))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=12, engine="legacy", checkpoint_dir=ckdir,
        checkpoint_keep=None, verify_kernels=False,
    )
    full = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    rcfg = dataclasses.replace(
        cfg, checkpoint_dir=None, resume_from=ckdir, resume_step=6
    )
    res = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    assert res.history["loss"] == full.history["loss"]
    assert res.final_loss == full.final_loss


def test_resume_finished_run_returns_without_engine(tmp_path):
    x, y = _mtls(jax.random.PRNGKey(4))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=10, block_epochs=5, checkpoint_dir=ckdir,
        checkpoint_keep=None, verify_kernels=False,
    )
    full = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    rcfg = dataclasses.replace(cfg, checkpoint_dir=None, resume_from=ckdir)
    res = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    assert res.epochs_run == 10
    assert res.stats["segments_run"] == 0  # nothing re-executed
    assert res.history["loss"] == full.history["loss"]
    assert res.final_loss == full.final_loss


def test_resume_rejects_wrong_problem(tmp_path):
    x, y = _mtls(jax.random.PRNGKey(5))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=6, checkpoint_dir=ckdir, verify_kernels=False
    )
    dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    other = tasks.MultiTaskLeastSquares(d=24, m=17)
    rcfg = dataclasses.replace(
        cfg, checkpoint_dir=None, resume_from=ckdir,
    )
    with pytest.raises(ValueError, match="same problem"):
        dfw.fit_serial(other, x, y[:, :17], cfg=rcfg, key=jax.random.PRNGKey(1))


def test_resume_rejects_shrunk_num_epochs(tmp_path):
    x, y = _mtls(jax.random.PRNGKey(6))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=10, block_epochs=5, checkpoint_dir=ckdir,
        checkpoint_keep=None, verify_kernels=False,
    )
    dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    rcfg = dataclasses.replace(
        cfg, num_epochs=8, checkpoint_dir=None, resume_from=ckdir,
        resume_step=10,
    )
    with pytest.raises(ValueError, match="num_epochs"):
        dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))


# ---------------------------------------------------------------------------
# Warm restart: gap_tol / schedule / comm / num_epochs change at resume
# ---------------------------------------------------------------------------


def test_warm_restart_changes_schedule_comm_gap_tol(tmp_path):
    x, y = _mtls(jax.random.PRNGKey(7))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=30, schedule="const:1", step_size="linesearch",
        block_epochs=5, checkpoint_dir=ckdir, checkpoint_keep=None,
        verify_kernels=False,
    )
    full = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    # resume at t=10 with: more power iterations, int8 comm, extended run,
    # and a gap certificate that stops it early
    tol = float(full.history["gap"][10]) * 0.3
    rcfg = dataclasses.replace(
        cfg, schedule="const:2", comm="int8", num_epochs=40, gap_tol=tol,
        checkpoint_dir=None, resume_from=ckdir, resume_step=10,
    )
    warm = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    # prefix is the checkpointed history, verbatim
    assert warm.history["loss"][:10] == full.history["loss"][:10]
    assert warm.history["k"][:10] == [1] * 10
    # the new schedule applies from the resume point
    assert all(k == 2 for k in warm.history["k"][10:])
    # the gap certificate fired (K=2 descends faster than the K=1 run)
    assert 10 < warm.epochs_run <= 40
    assert warm.history["gap"][-1] <= tol
    assert warm.final_loss < full.history["loss"][10]


def test_warm_restart_past_fired_certificate(tmp_path):
    """A run whose gap certificate fired is still resumable: loosening or
    removing gap_tol (and extending num_epochs) re-enters the engine from
    the stopped epoch instead of parroting the stopped result back."""
    x, y = _mtls(jax.random.PRNGKey(11))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    probe = dfw.fit_serial(
        task, x, y, key=jax.random.PRNGKey(1),
        cfg=dfw.DFWConfig(mu=1.0, num_epochs=40, step_size="linesearch",
                          verify_kernels=False),
    )
    tol = float(probe.history["gap"][0]) * 0.4
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=40, step_size="linesearch", gap_tol=tol,
        block_epochs=5, checkpoint_dir=ckdir, checkpoint_keep=None,
        verify_kernels=False,
    )
    stopped = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    assert 0 < stopped.epochs_run < 40  # certificate fired mid-run
    # same tol -> the stop still stands: returns without re-running
    same = dfw.fit_serial(
        task, x, y, key=jax.random.PRNGKey(1),
        cfg=dataclasses.replace(cfg, checkpoint_dir=None, resume_from=ckdir),
    )
    assert same.stats["segments_run"] == 0
    assert same.epochs_run == stopped.epochs_run
    # looser contract -> re-enters and runs further
    more = dfw.fit_serial(
        task, x, y, key=jax.random.PRNGKey(1),
        cfg=dataclasses.replace(cfg, checkpoint_dir=None, resume_from=ckdir,
                                gap_tol=None, num_epochs=50),
    )
    assert more.epochs_run == 50
    assert more.history["loss"][: stopped.epochs_run] == stopped.history["loss"]
    assert more.final_loss < stopped.final_loss


def test_store_overwrite_existing_step_stays_durable(tmp_path):
    """Re-saving an existing step id (resume from an older step writing the
    same boundaries again) replaces it without a window where readers see a
    partial step, and the store ends on the new content."""
    store = CheckpointStore(tmp_path / "ck")
    store.save(5, {"x": np.zeros(3, np.float32)})
    store.save(5, {"x": np.ones(3, np.float32)})
    assert store.steps() == [5]
    _, tree, _ = store.restore(5)
    np.testing.assert_array_equal(tree["x"], np.ones(3, np.float32))
    assert not list((tmp_path / "ck").glob(".old_step_*"))  # aside cleaned up


def test_head_fit_checkpoint_resume_single_device(tmp_path):
    """dfw_head.sharded_fit round-trips through checkpoint/resume,
    including the finished-run case (resume.t == num_epochs)."""
    from jax.sharding import Mesh
    from repro.core import dfw_head

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    kx = jax.random.PRNGKey(12)
    x = jax.random.normal(kx, (96, 16))
    yl = jax.random.randint(jax.random.fold_in(kx, 1), (96,), 0, 8)
    task = tasks.MultinomialLogistic(d=16, m=8)
    ck = checkpoint.RunCheckpointer(
        tmp_path / "ck", keep_last=None,
        extra=checkpoint.run_extra(
            task, num_workers=1, comm="dense", num_epochs=12,
            schedule="const:2", mu=5.0, step_size="default",
        ),
    )
    full = dfw_head.sharded_fit(
        mesh, x, yl, 8, mu=5.0, num_epochs=12, block_epochs=4,
        key=jax.random.PRNGKey(2), checkpointer=ck,
    )
    ck.wait()
    assert ck.store.steps() == [4, 8, 12]
    state_like = task.init_state(x, yl)
    snap = checkpoint.restore_run(
        tmp_path / "ck", state_like=state_like, step=8
    )
    res = dfw_head.sharded_fit(
        mesh, x, yl, 8, mu=5.0, num_epochs=12, block_epochs=4,
        key=jax.random.PRNGKey(2), resume=snap,
    )
    assert res.history["loss"] == full.history["loss"]
    assert res.final_loss == full.final_loss
    # finished-run resume returns the checkpoint without touching the engine
    fin = checkpoint.restore_run(tmp_path / "ck", state_like=state_like)
    assert fin.t == 12
    done_res = dfw_head.sharded_fit(
        mesh, x, yl, 8, mu=5.0, num_epochs=12,
        key=jax.random.PRNGKey(2), resume=fin,
    )
    assert done_res.history["loss"] == full.history["loss"]
    assert done_res.final_loss == full.final_loss
    # a checkpoint PAST the requested budget must also return cleanly (the
    # packed iterate holds 12 live factors; capacity must grow to fit them)
    shrunk = dfw_head.sharded_fit(
        mesh, x, yl, 8, mu=5.0, num_epochs=8,
        key=jax.random.PRNGKey(2), resume=fin,
    )
    assert shrunk.history["loss"] == full.history["loss"]
    assert int(shrunk.iterate.count) == 12


def test_run_checkpointer_requires_restorable_extra(tmp_path):
    """A checkpoint written without the config record could never be
    restored (restore_run rebuilds skeletons from it) — refuse at
    construction, not days later at restore time."""
    with pytest.raises(ValueError, match="run_extra"):
        checkpoint.RunCheckpointer(tmp_path / "ck")
    with pytest.raises(ValueError, match="comm"):
        checkpoint.RunCheckpointer(tmp_path / "ck", extra={"task": "X"})


def test_fresh_run_owns_checkpoint_dir(tmp_path):
    """A fresh (non-resume) run into a directory holding an older run's
    steps clears them — otherwise the dead run's later steps would outlive
    keep_last pruning and shadow the new run on a default restore."""
    x, y = _mtls(jax.random.PRNGKey(14))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    long = dfw.DFWConfig(
        mu=1.0, num_epochs=30, block_epochs=10, checkpoint_dir=ckdir,
        checkpoint_keep=None, verify_kernels=False,
    )
    dfw.fit_serial(task, x, y, cfg=long, key=jax.random.PRNGKey(1))
    assert CheckpointStore(ckdir).steps() == [10, 20, 30]
    short = dataclasses.replace(long, num_epochs=20)
    dfw.fit_serial(task, x, y, cfg=short, key=jax.random.PRNGKey(1))
    assert CheckpointStore(ckdir).steps() == [10, 20]  # 30 is gone
    snap = checkpoint.restore_run(ckdir, state_like=task.init_state(x, y))
    assert snap.t == 20 and int(snap.extra["num_epochs"]) == 20


def test_orphaned_old_step_recovered_on_open(tmp_path):
    """Crash between the two renames of _write's overwrite path leaves an
    .old_step_X and no step_X; opening the store puts the durable copy
    back. A stale .old with step_X present is garbage-collected."""
    store = CheckpointStore(tmp_path / "ck")
    store.save(5, {"x": np.zeros(2, np.float32)})
    # simulate the crash window: durable copy renamed aside, replacement
    # never landed
    (tmp_path / "ck" / "step_00000005").rename(
        tmp_path / "ck" / ".old_step_00000005"
    )
    store2 = CheckpointStore(tmp_path / "ck")
    assert store2.steps() == [5]
    step, tree, _ = store2.restore()
    assert step == 5
    np.testing.assert_array_equal(tree["x"], np.zeros(2, np.float32))
    # stale aside next to a complete step: reclaimed, step untouched
    (tmp_path / "ck" / ".old_step_00000005").mkdir(exist_ok=True)
    store3 = CheckpointStore(tmp_path / "ck")
    assert store3.steps() == [5]
    assert not list((tmp_path / "ck").glob(".old_step_*"))


def test_resume_into_same_dir_discards_abandoned_timeline(tmp_path):
    """Resuming from an interior step while checkpointing into the same
    directory must drop the dead run's later steps — otherwise the next
    default (latest-step) resume would splice two trajectories."""
    x, y = _mtls(jax.random.PRNGKey(13))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=20, block_epochs=5, checkpoint_dir=ckdir,
        checkpoint_keep=None, verify_kernels=False,
    )
    dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    assert CheckpointStore(ckdir).steps() == [5, 10, 15, 20]
    # resume at 10 with a coarser boundary plan, checkpointing into the
    # same dir: stale steps 15/20 must not survive
    rcfg = dataclasses.replace(
        cfg, block_epochs=10, resume_from=ckdir, resume_step=10
    )
    res = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    assert res.epochs_run == 20
    assert CheckpointStore(ckdir).steps() == [5, 10, 20]
    # and the latest step is now genuinely this run's final boundary
    snap = checkpoint.restore_run(ckdir, state_like=task.init_state(x, y))
    assert snap.t == 20


# ---------------------------------------------------------------------------
# Hot-path pin: checkpointing adds no dispatches, saves only at boundaries
# ---------------------------------------------------------------------------


def test_checkpointer_off_hot_path(tmp_path):
    """With a checkpointer enabled the engine must issue the SAME dispatch
    sequence (scan segments; no extra compiles) and only touch the host at
    segment boundaries — enforced under the device->host transfer guard,
    which forbids every *implicit* transfer. Saves are async and one per
    boundary here (save_every=1)."""
    x, y = _mtls(jax.random.PRNGKey(8))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    state = task.init_state(x, y)
    bare = frank_wolfe.fit(
        task, task.init_state(x, y), mu=1.0, num_epochs=30,
        key=jax.random.PRNGKey(1), step_size="linesearch", block_epochs=10,
    )
    ck = checkpoint.RunCheckpointer(
        tmp_path / "ck", keep_last=None,
        extra=checkpoint.run_extra(
            task, num_workers=1, comm="dense", num_epochs=30,
            schedule="const:2", mu=1.0, step_size="linesearch",
        ),
    )
    with jax.transfer_guard_device_to_host("disallow"):
        res = frank_wolfe.fit(
            task, state, mu=1.0, num_epochs=30, key=jax.random.PRNGKey(1),
            step_size="linesearch", block_epochs=10, checkpointer=ck,
        )
    ck.wait()
    assert res.stats["dispatches"] == bare.stats["dispatches"]
    assert res.stats["compilations"] == bare.stats["compilations"]
    # boundaries: 3 segments -> 3 saves, each a light (aux+scalars) fetch
    # plus a carry fetch, + the final history/epochs fetch + final loss
    assert ck.store.steps() == [10, 20, 30]
    assert res.stats["host_syncs"] <= 2 * 3 + 2
    # and the checkpointed trajectory is the bare one
    assert res.history["loss"] == bare.history["loss"]


def test_save_every_thins_checkpoints_but_keeps_final(tmp_path):
    x, y = _mtls(jax.random.PRNGKey(9))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ck = checkpoint.RunCheckpointer(
        tmp_path / "ck", save_every=3, keep_last=None,
        extra=checkpoint.run_extra(
            task, num_workers=1, comm="dense", num_epochs=20,
            schedule="const:2", mu=1.0, step_size="default",
        ),
    )
    res = frank_wolfe.fit(
        task, task.init_state(x, y), mu=1.0, num_epochs=20,
        key=jax.random.PRNGKey(1), block_epochs=4, checkpointer=ck,
    )
    ck.wait()
    # 5 boundaries at t=4,8,12,16,20: every 3rd (t=12) plus the final one
    assert ck.store.steps() == [12, 20]
    # skipped boundaries stay sync-free (no gap_tol/callback here): the two
    # batched save fetches + the final history/epochs fetch + final loss
    assert res.stats["host_syncs"] <= 4


# ---------------------------------------------------------------------------
# Payload round-trip on the actual carry pytrees (store-level, no engine)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("comm", ["dense", "int8", "topk:4"])
def test_run_payload_roundtrip_carry_pytrees(tmp_path, comm):
    from repro import comm as comm_lib

    x, y = _mtls(jax.random.PRNGKey(10), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    state = task.init_state(x, y)
    reducer = comm_lib.make_reducer(comm, num_workers=1)
    it = low_rank.init(8, 24, 18)
    carry = frank_wolfe.init_carry(
        state, it, jax.random.PRNGKey(2), reducer.init_state(24, 18), t=3
    )
    ck = checkpoint.RunCheckpointer(
        tmp_path / "ck", keep_last=None,
        extra=checkpoint.run_extra(
            task, num_workers=1, comm=reducer.spec, num_epochs=8,
            schedule="const:2", mu=1.0, step_size="default",
        ),
    )
    hist = {"loss": [1.0, 2.0, 3.0], "gap": [3.0, 2.0, 1.0],
            "sigma": [0.1] * 3, "gamma": [0.5] * 3, "k": [2, 2, 2]}
    ck.save_segment(
        t=3, carry=jax.device_get(carry), history=hist,
        masks=np.ones((8, 1), np.float32), done=False,
    )
    ck.wait()
    snap = checkpoint.restore_run(tmp_path / "ck", state_like=state)
    assert snap.t == 3 and not snap.done
    assert snap.history == hist
    assert snap.masks.shape == (8, 1)
    assert snap.extra["comm"] == reducer.spec
    for name, a, b in zip(state._fields, snap.carry.state, carry.state):
        np.testing.assert_array_equal(a, np.asarray(b), err_msg=name)
    jax.tree.map(
        lambda a, b: np.testing.assert_array_equal(a, np.asarray(b)),
        snap.carry.comm_state, carry.comm_state,
    )
    np.testing.assert_array_equal(
        np.asarray(snap.unpack_iterate(8).u), np.asarray(it.u)
    )
    assert int(snap.carry.t) == 3


def test_state_spec_matches_init_state():
    from repro import comm as comm_lib

    for spec in ("dense", "int8", "topk:5"):
        r = comm_lib.make_reducer(spec, num_workers=4)
        sds = r.state_spec(24, 18)
        st = r.init_state(24, 18)
        assert jax.tree_util.tree_structure(sds) == jax.tree_util.tree_structure(st)
        jax.tree.map(
            lambda s, x: (s.shape, s.dtype) == (x.shape, x.dtype) or
            pytest.fail(f"{spec}: {s} vs {x.shape}/{x.dtype}"),
            sds, st,
        )


# ---------------------------------------------------------------------------
# 8-way: bit-exact resume (dense + int8) and elastic 8->4 remesh
# ---------------------------------------------------------------------------


def test_sharded8_bitexact_and_elastic_resume(tmp_path):
    """The acceptance bar: kill at an interior boundary, resume on the same
    8-way mesh -> identical bits (dense AND int8, stragglers on); resume on
    a 4-way mesh -> dense within 1e-3 relative final loss (int8 looser: the
    per-worker integer budget itself changes with the worker count)."""
    out = _run(f"""
        import dataclasses, json
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)

        for comm, sample_prob in (("dense", 0.7), ("int8", 1.0)):
            ckdir = {str(tmp_path)!r} + "/ck_" + comm
            cfg = dfw.DFWConfig(mu=1.0, num_epochs=16, schedule="const:2",
                                step_size="linesearch", comm=comm,
                                sample_prob=sample_prob, block_epochs=4,
                                checkpoint_dir=ckdir, checkpoint_keep=None,
                                verify_kernels=False)
            full = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                           num_workers=8)
            rcfg = dataclasses.replace(cfg, checkpoint_dir=None,
                                       resume_from=ckdir, resume_step=8)
            res = dfw.fit(task, X, Y, cfg=rcfg, key=jax.random.PRNGKey(1),
                          num_workers=8)
            for k in ("loss", "gap", "sigma", "gamma", "k"):
                assert res.history[k] == full.history[k], (comm, k)
            assert res.final_loss == full.final_loss, comm
            for a, b in zip(res.iterate, full.iterate):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            if full.masks is not None:
                np.testing.assert_array_equal(np.asarray(res.masks),
                                              np.asarray(full.masks))
            print(comm, "bit-exact OK")

            if sample_prob == 1.0:
                continue
            # elastic needs full participation for a like-for-like loss
            ecfg = dataclasses.replace(cfg, sample_prob=1.0,
                                       checkpoint_dir=ckdir + "_e")
            efull = dfw.fit(task, X, Y, cfg=ecfg, key=jax.random.PRNGKey(1),
                            num_workers=8)
            ercfg = dataclasses.replace(ecfg, checkpoint_dir=None,
                                        resume_from=ckdir + "_e",
                                        resume_step=8)
            eres = dfw.fit(task, X, Y, cfg=ercfg, key=jax.random.PRNGKey(1),
                           num_workers=4)
            rel = abs(eres.final_loss - efull.final_loss) / abs(efull.final_loss)
            assert rel < 1e-3, rel
            assert eres.epochs_run == 16
            print("elastic 8->4 OK rel", rel)
        print("sharded resume matrix OK")
    """)
    assert "sharded resume matrix OK" in out


@pytest.mark.slow
def test_sharded8_checkpointer_dispatch_pin():
    """8-way hot-path pin under the transfer guard: checkpointing a 30-epoch
    const:2 run (block 10) leaves the dispatch/compilation counts at the
    bare run's values; the only added host traffic is the explicit
    boundary fetch."""
    out = _run("""
        import tempfile
        import jax, jax.numpy as jnp
        from repro.core import tasks
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        base = dfw.DFWConfig(mu=1.0, num_epochs=30, schedule="const:2",
                             step_size="linesearch", block_epochs=10,
                             verify_kernels=False)
        bare = dfw.fit(task, X, Y, cfg=base, key=jax.random.PRNGKey(1),
                       num_workers=8)
        import dataclasses
        cfg = dataclasses.replace(base, checkpoint_dir=tempfile.mkdtemp())
        with jax.transfer_guard_device_to_host("disallow"):
            res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                          num_workers=8)
        assert res.stats["dispatches"] == bare.stats["dispatches"], (
            res.stats, bare.stats)
        assert res.stats["compilations"] == bare.stats["compilations"]
        assert res.history["loss"] == bare.history["loss"]
        print("sharded checkpointer pin OK", res.stats)
    """)
    assert "sharded checkpointer pin OK" in out
