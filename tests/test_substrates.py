"""Substrate tests: checkpoint store, optimizer, compression, HLO analyzer,
sharding-rule coverage."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointStore
from repro.configs import ARCH_IDS, get_config
from repro.analysis import hlo as hlo_analysis
from repro.launch.params import param_pspecs
from repro.launch.sharding import pspec, use_mesh
from repro.models import lm
from repro.optim import adamw, compression, schedule

KEY = jax.random.PRNGKey(0)


# ---------------------------------------------------------------------------
# Checkpoint
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_and_async():
    tree = {"a": jnp.arange(12.0).reshape(3, 4), "b": {"c": jnp.ones((5,), jnp.int32)}}
    with tempfile.TemporaryDirectory() as d:
        st = CheckpointStore(d)
        st.save_async(3, tree, extra={"rng": 7})
        st.wait()
        st.save(10, tree)
        assert st.latest_step() == 10
        step, restored, extra = st.restore(3)
        assert step == 3 and extra == {"rng": 7}
        np.testing.assert_array_equal(restored["a"], tree["a"])


def test_checkpoint_namedtuple_state_needs_like():
    tree = {"opt": adamw.init({"w": jnp.ones((3,))})}
    with tempfile.TemporaryDirectory() as d:
        st = CheckpointStore(d)
        st.save(1, tree)
        with pytest.raises(ValueError):
            st.restore(1)
        _, restored, _ = st.restore(1, like=tree)
        assert int(restored["opt"].step) == 0


def test_checkpoint_atomicity_leaves_no_tmp():
    with tempfile.TemporaryDirectory() as d:
        st = CheckpointStore(d)
        st.save(2, {"x": jnp.zeros((4,))})
        import pathlib

        assert not list(pathlib.Path(d).glob(".tmp_*"))


# ---------------------------------------------------------------------------
# Optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw.init(params)
    for _ in range(200):
        g = {"w": 2 * params["w"]}
        params, opt = adamw.update(g, opt, params, lr=5e-2, weight_decay=0.0)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.15


def test_cosine_schedule_shape():
    s = schedule.cosine_with_warmup(
        jnp.arange(100), peak_lr=1.0, warmup=10, total=100
    )
    assert float(s[0]) == 0.0
    assert float(s[10]) == pytest.approx(1.0, rel=1e-3)
    assert float(s[99]) < 0.2


# ---------------------------------------------------------------------------
# PowerSGD compression
# ---------------------------------------------------------------------------


def test_powersgd_rank_improves_approximation():
    g = jax.random.normal(KEY, (64, 48))
    errs = []
    for rank in (1, 4, 16):
        st = compression.init({"g": g}, rank=rank, min_size=16)
        approx, _ = compression.compress_and_sync({"g": g}, st, min_size=16)
        errs.append(float(jnp.linalg.norm(approx["g"] - g) / jnp.linalg.norm(g)))
    assert errs[0] > errs[1] > errs[2]


def test_powersgd_error_feedback_recovers_signal():
    """Error feedback: the time-average of compressed updates converges to
    the true (constant) gradient at rate ||e_eq||/T, and the error-feedback
    buffer plateaus (PowerSGD self-stabilizes once e dominates M)."""
    g = jax.random.normal(KEY, (32, 24))
    st = compression.init({"g": g}, rank=4, min_size=16)
    sent = jnp.zeros_like(g)
    rels, errs = [], []
    for i in range(80):
        out, st = compression.compress_and_sync({"g": g}, st, min_size=16)
        sent = sent + out["g"]
        rels.append(float(jnp.linalg.norm(sent / (i + 1) - g) / jnp.linalg.norm(g)))
        errs.append(float(jnp.linalg.norm(st.error["g"])))
    assert rels[-1] < 0.35, rels[-1]
    assert rels[-1] < rels[20] < rels[5]  # monotone-ish convergence
    assert errs[-1] < errs[40] * 1.5  # error buffer bounded (plateau)


def test_powersgd_wire_bytes_table():
    params = {"big": jnp.zeros((512, 256)), "small": jnp.zeros((8,))}
    wb = compression.wire_bytes(params, rank=4, min_size=4096)
    assert wb["compressed"] < wb["dense"] / 10


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------


def test_hlo_walker_counts_scan_flops():
    def f(ws, x):
        def body(c, w):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, ws)
        return out.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((10, 64, 64), jnp.float32),
        jax.ShapeDtypeStruct((32, 64), jnp.float32),
    ).compile()
    res = hlo_analysis.analyze(compiled.as_text())
    want = 10 * 2 * 32 * 64 * 64
    assert res["flops"] == pytest.approx(want, rel=0.01), res["flops"]


def test_hlo_walker_nested_scan():
    def f(ws, x):
        def outer(c, w3):
            def inner(ci, w):
                return ci @ w, None
            co, _ = jax.lax.scan(inner, c, w3)
            return co, None
        out, _ = jax.lax.scan(outer, x, ws)
        return out.sum()

    compiled = jax.jit(f).lower(
        jax.ShapeDtypeStruct((4, 3, 16, 16), jnp.float32),
        jax.ShapeDtypeStruct((8, 16), jnp.float32),
    ).compile()
    res = hlo_analysis.analyze(compiled.as_text())
    want = 4 * 3 * 2 * 8 * 16 * 16
    assert res["flops"] == pytest.approx(want, rel=0.05), res["flops"]


# ---------------------------------------------------------------------------
# Sharding rules cover every arch's parameters
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_pspecs_cover_all_leaves(arch):
    cfg = get_config(arch, smoke=True)
    aparams = jax.eval_shape(lambda k: lm.init_params(cfg, k), KEY)
    specs = param_pspecs(aparams)
    flat_p = jax.tree.leaves(aparams)
    from jax.sharding import PartitionSpec as P

    flat_s = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
    assert len(flat_p) == len(flat_s)
    for leaf, spec in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (spec, leaf.shape)


def test_pspec_rules_respect_mesh_axes():
    """Outside a mesh everything resolves to unconstrained; 1D mesh drops
    the absent axes from tuples."""
    from jax.sharding import PartitionSpec as P

    assert pspec("batch", None) == P(None, None)
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
    with use_mesh(mesh):
        assert pspec("batch", None) == P("data", None)
        assert pspec("heads") == P(None)  # "model" absent from this mesh


# ---------------------------------------------------------------------------
# Hybrid optimizer (AdamW backbone + DFW-TRACE trace-norm head)
# ---------------------------------------------------------------------------


def test_hybrid_optimizer_constrains_head():
    from repro.core.trace_norm import trace_norm as exact_tn
    from repro.data import SyntheticLMStream
    from repro.models.config import ShapeSpec
    from repro.optim import hybrid

    cfg = get_config("codeqwen1_5_7b", smoke=True)  # untied head
    params = lm.init_params(cfg, KEY)
    mu = 5.0
    step = jax.jit(hybrid.make_hybrid_train_step(cfg, mu=mu, peak_lr=1e-3))
    state = hybrid.init(params)
    stream = SyntheticLMStream(cfg, ShapeSpec("t", "train", 64, 4))
    losses = []
    for t in range(8):
        batch = {k: jnp.asarray(v) for k, v in stream.batch_for_step(t).items()}
        params, state, metrics = step(params, state, batch, jax.random.PRNGKey(5))
        losses.append(float(metrics["loss"]))
        assert bool(jnp.isfinite(metrics["loss"]))
    # after the first FW step (gamma=1) the head is exactly feasible
    tn = float(exact_tn(params["unembed"].astype(jnp.float32)))
    assert tn <= mu * (1 + 1e-3), tn
    assert int(state.fw_step) == 8 and int(state.adam.step) == 8
