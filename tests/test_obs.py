"""Telemetry spine (repro/obs): registry semantics, event stream, sinks,
and the zero-sync instrumentation riding the engine / checkpoint / serving
layers. Multi-device coverage runs in a subprocess with 8 fake CPU devices
(same pattern as tests/test_engine.py — device count locks at first jax
init in the main pytest process).
"""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import frank_wolfe, tasks
from repro.obs import Histogram, MetricsRegistry, Telemetry, noop_contract

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def _mtls(key, n=400, d=24, m=18):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (d, m))
    w = w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    return x, x @ w


# ---------------------------------------------------------------------------
# MetricsRegistry
# ---------------------------------------------------------------------------


def test_registry_get_or_create_returns_same_instrument():
    reg = MetricsRegistry()
    assert reg.counter("a") is reg.counter("a")
    assert reg.gauge("g") is reg.gauge("g")
    assert reg.histogram("h") is reg.histogram("h")
    reg.counter("a").inc(3)
    assert reg.snapshot()["counters"]["a"] == 3.0


def test_registry_reset_zeroes_in_place_keeping_handles():
    reg = MetricsRegistry()
    c, g, h = reg.counter("c"), reg.gauge("g"), reg.histogram("h")
    c.inc(5)
    g.set(1.5)
    h.observe(100.0)
    reg.reset()
    assert c.value == 0.0 and g.value is None and h.count == 0
    c.inc()  # the old handle still feeds the registry
    assert reg.snapshot()["counters"]["c"] == 1.0


def test_histogram_log2_buckets_and_summary():
    h = Histogram("lat")
    for v in (0.5, 1.0, 3.0, 1000.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 4
    assert snap["min"] == 0.5 and snap["max"] == 1000.0
    assert snap["mean"] == pytest.approx((0.5 + 1 + 3 + 1000) / 4)
    # 0.5 -> bucket 0; 1.0 -> [1,2) bucket 1; 3.0 -> [2,4) bucket 2;
    # 1000 -> [512,1024) bucket 10
    assert snap["buckets"] == {"0": 1, "1": 1, "2": 1, "10": 1}


# ---------------------------------------------------------------------------
# Telemetry handle: events, bounds, no-op
# ---------------------------------------------------------------------------


def test_span_and_event_forms():
    tel = Telemetry()
    with tel.span("work", "test", detail=7):
        pass
    tel.event("marker", "test", note="x")
    tel.counter_sample("metric", 3.0)
    phs = [ev["ph"] for ev in tel.events()]
    assert phs == ["X", "i", "C"]
    span = tel.events()[0]
    assert span["name"] == "work" and span["args"] == {"detail": 7}
    assert span["dur"] >= 0.0


def test_event_stream_is_bounded_and_counts_drops():
    tel = Telemetry(max_events=3)
    for i in range(5):
        tel.event(f"e{i}")
    assert tel.event_count() == 3
    assert tel._meta()["dropped_events"] == 2


def test_noop_is_a_singleton_and_records_nothing():
    tel = Telemetry.noop()
    assert tel is Telemetry.noop()
    assert not tel.enabled and not tel.wants_hlo
    with tel.span("x"):
        pass
    tel.event("y")
    tel.complete("z", "c", 0.0, 1.0)
    assert tel.event_count() == 0
    # the declared contract agrees: spans free, stream empty
    noop_contract().check_telemetry(tel)


def test_noop_contract_rejects_an_enabled_handle():
    with pytest.raises(AssertionError):
        noop_contract().check_telemetry(Telemetry())


# ---------------------------------------------------------------------------
# Sinks: JSONL + Chrome trace from a real instrumented fit
# ---------------------------------------------------------------------------


def _instrumented_fit(tel, num_epochs=12, gap_tol=None, block_epochs=None):
    x, y = _mtls(jax.random.PRNGKey(3))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    return frank_wolfe.fit(
        task, task.init_state(x, y), mu=1.0, num_epochs=num_epochs,
        key=jax.random.PRNGKey(1), step_size="linesearch",
        gap_tol=gap_tol, block_epochs=block_epochs, telemetry=tel,
    )


def test_fit_emits_engine_and_comm_events_and_metrics():
    tel = Telemetry()
    res = _instrumented_fit(tel, num_epochs=12)
    names = {ev["name"] for ev in tel.events()}
    assert {"engine.compile", "engine.dispatch", "engine.segment",
            "engine.fetch", "comm.exchange", "engine.final_loss"} <= names
    # per-epoch scalars ride the boundary fetch: one sample per epoch
    loss_samples = [ev for ev in tel.events() if ev["name"] == "dfw.loss"]
    assert len(loss_samples) == res.epochs_run == 12
    snap = tel.registry.snapshot()
    assert snap["counters"]["engine.epochs"] == 12
    assert snap["counters"]["comm.rounds"] > 0
    assert snap["gauges"]["dfw.final_loss"] == pytest.approx(
        res.final_loss, rel=1e-5)


def test_jsonl_and_chrome_trace_sinks_are_valid(tmp_path):
    tel = Telemetry()
    _instrumented_fit(tel, num_epochs=8)
    jl = tmp_path / "run.jsonl"
    ct = tmp_path / "run.trace.json"
    tel.write_jsonl(jl)
    tel.write_chrome_trace(ct)

    lines = [json.loads(s) for s in jl.read_text().splitlines()]
    assert lines[0]["type"] == "meta" and lines[-1]["type"] == "metrics"
    assert len(lines) - 2 == tel.event_count()

    doc = json.loads(ct.read_text())
    evs = doc["traceEvents"]
    assert len(evs) == tel.event_count()
    assert {ev["ph"] for ev in evs} <= {"X", "i", "C"}
    for ev in evs:  # Perfetto's minimum: name/ph/ts/pid on every event
        assert {"name", "ph", "ts", "pid"} <= set(ev)
        if ev["ph"] == "X":
            assert ev["dur"] >= 0.0


# ---------------------------------------------------------------------------
# Early stop: event epoch == epochs_run == truncated history (serial + 8-way)
# ---------------------------------------------------------------------------


def test_early_stop_event_matches_truncated_history_serial():
    # a tolerance that certifiably fires mid-run: 40% of the starting gap
    full = _instrumented_fit(Telemetry.noop(), num_epochs=40)
    tol = float(full.history["gap"][0]) * 0.4
    tel = Telemetry()
    res = _instrumented_fit(tel, num_epochs=40, gap_tol=tol, block_epochs=5)
    assert res.epochs_run < 40
    stops = [ev for ev in tel.events() if ev["name"] == "engine.early_stop"]
    assert len(stops) == 1
    assert stops[0]["args"]["epoch"] == res.epochs_run
    assert len(res.history["loss"]) == res.epochs_run
    # and no telemetry rows for the cond-skipped NaN epochs past the stop
    loss_samples = [ev for ev in tel.events() if ev["name"] == "dfw.loss"]
    assert len(loss_samples) == res.epochs_run


def test_early_stop_event_matches_truncated_history_8way():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks
        from repro.launch import dfw
        from repro.obs import Telemetry

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        full = dfw.fit(task, X, Y,
                       cfg=dfw.DFWConfig(mu=1.0, num_epochs=40,
                                         schedule="const:2",
                                         step_size="linesearch"),
                       key=jax.random.PRNGKey(1), num_workers=8)
        tol = float(full.history["gap"][0]) * 0.4
        tel = Telemetry()
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=40, schedule="const:2",
                            step_size="linesearch", gap_tol=tol,
                            block_epochs=5, telemetry=tel)
        res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                      num_workers=8)
        assert res.epochs_run < 40
        stops = [ev for ev in tel.events() if ev["name"] == "engine.early_stop"]
        assert len(stops) == 1, [ev["name"] for ev in tel.events()]
        assert stops[0]["args"]["epoch"] == res.epochs_run
        assert len(res.history["loss"]) == res.epochs_run
        losses = [ev for ev in tel.events() if ev["name"] == "dfw.loss"]
        assert len(losses) == res.epochs_run
        print("8-way early-stop telemetry OK", res.epochs_run)
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Checkpoint + serving instrumentation
# ---------------------------------------------------------------------------


def test_checkpoint_store_stamps_writes_and_prunes(tmp_path):
    from repro.checkpoint.store import CheckpointStore

    tel = Telemetry()
    store = CheckpointStore(tmp_path / "ck", keep_last=1, telemetry=tel)
    tree = {"w": np.ones((8, 8), np.float32)}
    store.save(0, tree)
    store.save_async(1, tree)
    store.wait()
    writes = [ev for ev in tel.events() if ev["name"] == "checkpoint.write"]
    assert [w["args"]["step"] for w in writes] == [0, 1]
    assert all(w["args"]["bytes"] == 8 * 8 * 4 for w in writes)
    prunes = [ev for ev in tel.events() if ev["name"] == "checkpoint.prune"]
    assert len(prunes) == 1 and prunes[0]["args"]["steps"] == [0]
    snap = tel.registry.snapshot()
    assert snap["counters"]["checkpoint.saves"] == 2
    assert snap["histograms"]["checkpoint.write_us"]["count"] == 2


def test_serving_latency_histogram_and_hot_swap_event():
    from repro import serve
    from repro.core import low_rank

    d, m, rank = 32, 24, 4
    tel = Telemetry()
    eng = serve.ServingEngine(
        d, m, serve.ServeConfig(max_batch=8, rank_block=4,
                                verify_kernels=False, telemetry=tel))
    key = jax.random.PRNGKey(0)
    it = low_rank.FactoredIterate(
        u=jax.random.normal(key, (rank, d)),
        s=jnp.ones((rank,)),
        v=jax.random.normal(key, (rank, m)),
        alpha=jnp.asarray(1.0),
        count=jnp.asarray(rank, jnp.int32),
    )
    eng.load(it)
    for _ in range(3):
        eng.score(np.ones((8, d), np.float32))
    eng.load(it._replace(s=it.s * 0.5))  # hot swap

    hist = tel.registry.snapshot()["histograms"]["serve.latency_us"]
    assert hist["count"] == 3
    names = [ev["name"] for ev in tel.events()]
    assert names.count("serve.dispatch") == 3
    assert "serve.compile" in names and "serve.hot_swap" in names
    assert eng.stats["dispatches"] == 3 and eng.stats["loads"] == 2
    # registry and stats views agree — stats is the registry now
    assert tel.registry.snapshot()["counters"]["serve.dispatches"] == 3


def test_disabled_engines_do_not_share_counters():
    """Two telemetry-off engines must not alias each other's stats through
    the shared no-op singleton's registry."""
    from repro import serve

    a = serve.ServingEngine(16, 12, serve.ServeConfig(max_batch=4,
                                                      verify_kernels=False))
    b = serve.ServingEngine(16, 12, serve.ServeConfig(max_batch=4,
                                                      verify_kernels=False))
    a._counters["dispatches"].inc()
    assert b.stats["dispatches"] == 0
    assert Telemetry.noop().registry.snapshot()["counters"] == {}
