"""Per-arch smoke tests: reduced configs, forward/train-step on CPU,
shape + finiteness assertions; decode==forward consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import lm
from repro.optim import adamw

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=64):
    if cfg.family == "audio":
        return {
            "frames": jax.random.normal(KEY, (b, s, cfg.frontend_dim)),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        }
    if cfg.family == "vlm":
        sv = cfg.vision_tokens
        return {
            "tokens": jax.random.randint(KEY, (b, s - sv), 0, cfg.vocab_size),
            "vision_embeds": jax.random.normal(KEY, (b, sv, cfg.d_model)),
            "positions": jnp.broadcast_to(jnp.arange(s)[None, None, :], (b, 3, s)).astype(jnp.int32),
            "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        }
    return {
        "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
        "labels": jax.random.randint(KEY, (b, s), 0, cfg.vocab_size),
    }


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(cfg, KEY)
    b, s = 2, 64
    batch = _batch(cfg, b, s)
    out = jax.jit(lambda p, bt: lm.forward(p, bt, cfg, mode="train"))(params, batch)
    assert out["logits"].shape == (b, s, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(out["logits"])))

    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p, bt: lm.loss_fn(p, bt, cfg), has_aux=True)
    )(params, batch)
    assert bool(jnp.isfinite(loss))
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in jax.tree.leaves(grads)))
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0

    opt = adamw.init(params)
    params2, opt2 = adamw.update(grads, opt, params, lr=1e-3)
    assert int(opt2.step) == 1
    changed = any(
        not np.array_equal(np.asarray(a, np.float32), np.asarray(b_, np.float32))
        for a, b_ in zip(jax.tree.leaves(params), jax.tree.leaves(params2))
    )
    assert changed


@pytest.mark.parametrize("arch", ["codeqwen1_5_7b", "zamba2_2_7b", "rwkv6_7b"])
def test_decode_matches_forward(arch):
    cfg = get_config(arch, smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(42))
    b, s = 2, 32
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full = np.asarray(
        lm.forward(params, {"tokens": toks}, cfg, mode="train")["logits"], np.float32
    )
    cache = lm.init_cache(cfg, b, s)
    step = jax.jit(lambda p, c, bt: lm.decode_step(p, c, bt, cfg))
    errs = []
    for t in range(s):
        logits, cache = step(
            params, cache, {"tokens": toks[:, t : t + 1], "cache_pos": jnp.int32(t)}
        )
        errs.append(np.max(np.abs(np.asarray(logits[:, 0], np.float32) - full[:, t])))
    assert max(errs) < 2e-2, max(errs)


def test_moe_decode_matches_forward_without_drops():
    cfg = dataclasses.replace(
        get_config("arctic_480b", smoke=True), moe_capacity_factor=16.0
    )
    params = lm.init_params(cfg, jax.random.PRNGKey(42))
    b, s = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, s), 0, cfg.vocab_size)
    full = np.asarray(
        lm.forward(params, {"tokens": toks}, cfg, mode="train")["logits"], np.float32
    )
    cache = lm.init_cache(cfg, b, s)
    step = jax.jit(lambda p, c, bt: lm.decode_step(p, c, bt, cfg))
    for t in range(s):
        logits, cache = step(
            params, cache, {"tokens": toks[:, t : t + 1], "cache_pos": jnp.int32(t)}
        )
        assert np.max(np.abs(np.asarray(logits[:, 0], np.float32) - full[:, t])) < 2e-2


def test_prefill_cache_continues_decode():
    """prefill(s tokens) then decode token s must equal full forward."""
    cfg = get_config("qwen2_5_14b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(7))
    b, s = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (b, s + 1), 0, cfg.vocab_size)
    full = np.asarray(
        lm.forward(params, {"tokens": toks}, cfg, mode="train")["logits"], np.float32
    )
    out = lm.forward(params, {"tokens": toks[:, :s]}, cfg, mode="prefill")
    np.testing.assert_allclose(
        np.asarray(out["logits"][:, -1], np.float32), full[:, s - 1], atol=2e-2
    )
    # grow the prefill cache to s+1 slots and take one decode step
    cache = {
        k: jnp.pad(v, ((0, 0), (0, 0), (0, 0), (0, 1), (0, 0)))
        for k, v in out["cache"].items()
    }
    logits, _ = lm.decode_step(
        params, cache, {"tokens": toks[:, s : s + 1], "cache_pos": jnp.int32(s)}, cfg
    )
    np.testing.assert_allclose(
        np.asarray(logits[:, 0], np.float32), full[:, s], atol=2e-2
    )


def test_vlm_loss_uses_text_positions_only():
    cfg = get_config("qwen2_vl_72b", smoke=True)
    params = lm.init_params(cfg, KEY)
    batch = _batch(cfg, 2, 64)
    loss, metrics = lm.loss_fn(params, batch, cfg)
    assert bool(jnp.isfinite(loss))


def test_encoder_only_bidirectional():
    """hubert: flipping a late frame must change logits of an early position
    (bidirectional attention), unlike causal archs."""
    cfg = get_config("hubert_xlarge", smoke=True)
    params = lm.init_params(cfg, KEY)
    b, s = 1, 32
    frames = jax.random.normal(KEY, (b, s, cfg.frontend_dim))
    out1 = lm.forward(params, {"frames": frames}, cfg, mode="train")["logits"]
    frames2 = frames.at[:, -1, :].set(10.0)
    out2 = lm.forward(params, {"frames": frames2}, cfg, mode="train")["logits"]
    assert float(jnp.max(jnp.abs(out1[:, 0] - out2[:, 0]))) > 1e-6
