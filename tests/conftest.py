# NOTE: do NOT set --xla_force_host_platform_device_count here. Smoke tests
# and benches must see 1 device; only launch/dryrun.py forces 512 (and the
# multi-device tests spawn subprocesses with their own XLA_FLAGS).
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
