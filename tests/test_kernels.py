"""Pallas kernels vs pure-jnp oracles (interpret mode, shape/dtype sweeps)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import (
    factor_matvec,
    flash_attention,
    mc_matvec,
    power_matvec,
    quantize,
    rank1_update,
)

KEY = jax.random.PRNGKey(0)


def _tol(dt):
    return dict(rtol=3e-2, atol=3e-2) if dt == jnp.bfloat16 else dict(rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("n,m", [(256, 256), (300, 200), (65, 33), (128, 512), (1, 7)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_matvec_rmatvec(n, m, dt):
    a = (jax.random.normal(KEY, (n, m)) / np.sqrt(m)).astype(dt)
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (m,)).astype(dt)
    u = jax.random.normal(jax.random.fold_in(KEY, 2), (n,)).astype(dt)
    got = power_matvec.matvec(a, v, block_r=64, block_c=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(power_matvec.ref.matvec(a, v)[:, 0], np.float32), **_tol(dt))
    got = power_matvec.rmatvec(a, u, block_r=64, block_c=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(power_matvec.ref.rmatvec(a, u)[:, 0], np.float32), **_tol(dt))


def test_power_iter_step_matches_ref():
    n, d, m = 300, 40, 28
    x = jax.random.normal(KEY, (n, d)) / np.sqrt(d)
    r = jax.random.normal(jax.random.fold_in(KEY, 3), (n, m))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (m,))
    v = v / jnp.linalg.norm(v)
    u1, v1 = power_matvec.power_iter_step(x, r, v, interpret=True)
    u2, v2 = power_matvec.ref.power_iter_step(x, r, v.reshape(-1, 1))
    np.testing.assert_allclose(u1, u2[:, 0], rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(v1, v2[:, 0], rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("d,m,p", [(64, 48, 1000), (37, 23, 700), (128, 5, 64),
                                   (9, 130, 1)])
def test_mc_coo_matvec(d, m, p):
    """Observed-entry (COO) matvec kernel vs the segment_sum oracle, including
    duplicate coordinates and non-block-multiple entry counts."""
    rows = jax.random.randint(KEY, (p,), 0, d)
    cols = jax.random.randint(jax.random.fold_in(KEY, 20), (p,), 0, m)
    vals = jax.random.normal(jax.random.fold_in(KEY, 21), (p,))
    v = jax.random.normal(jax.random.fold_in(KEY, 22), (m,))
    u = jax.random.normal(jax.random.fold_in(KEY, 23), (d,))
    got = mc_matvec.matvec(rows, cols, vals, v, d, block_e=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(mc_matvec.ref.matvec(rows, cols, vals, v, d)),
        rtol=1e-5, atol=1e-5)
    got = mc_matvec.rmatvec(rows, cols, vals, u, m, block_e=128, interpret=True)
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(mc_matvec.ref.rmatvec(rows, cols, vals, u, m)),
        rtol=1e-5, atol=1e-5)


def test_mc_coo_matvec_matches_dense():
    """The segment_sum reference itself equals the dense P_Omega gradient."""
    d, m, p = 40, 30, 500
    rows = jax.random.randint(KEY, (p,), 0, d)
    cols = jax.random.randint(jax.random.fold_in(KEY, 24), (p,), 0, m)
    vals = jax.random.normal(jax.random.fold_in(KEY, 25), (p,))
    v = jax.random.normal(jax.random.fold_in(KEY, 26), (m,))
    g = np.zeros((d, m), np.float32)
    np.add.at(g, (np.asarray(rows), np.asarray(cols)), np.asarray(vals))
    np.testing.assert_allclose(
        np.asarray(mc_matvec.ref.matvec(rows, cols, vals, v, d)),
        g @ np.asarray(v), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bt,n_in,r,n_out", [
    (128, 256, 8, 256),   # block-aligned
    (130, 300, 7, 65),    # every axis off its block/sublane multiple
    (1, 7, 1, 3),         # single tiny request
    (33, 129, 12, 257),   # one past block boundaries
])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_factor_matvec_kernel(bt, n_in, r, n_out, dt):
    """Fused factor-scoring kernel (interpret) vs the jnp oracle vs the
    materialized dense product, across non-multiple-of-block shapes."""
    x = (jax.random.normal(KEY, (bt, n_in)) / np.sqrt(n_in)).astype(dt)
    a = jax.random.normal(jax.random.fold_in(KEY, 40), (r, n_in)).astype(dt)
    s = jax.random.normal(jax.random.fold_in(KEY, 41), (r,))
    b = jax.random.normal(jax.random.fold_in(KEY, 42), (r, n_out)).astype(dt)
    got = factor_matvec.factor_matvec(
        x, a, s, b, alpha=0.7, block_b=32, block_o=64, interpret=True)
    assert got.shape == (bt, n_out) and got.dtype == jnp.float32
    want_ref = factor_matvec.ref.factor_matvec(x, a, 0.7 * s, b)
    want_dense = factor_matvec.ref.dense_matvec(x, a, 0.7 * s, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_ref), **_tol(dt))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want_dense),
                               **(_tol(dt) if dt == jnp.bfloat16
                                  else dict(rtol=1e-4, atol=1e-4)))


def test_factor_matvec_rank_zero_and_dispatch():
    """Rank 0 (untrained iterate) scores exactly zero without entering the
    kernel, and the off-TPU default path (use_pallas=None on CPU) agrees
    with the interpret-mode kernel."""
    x = jax.random.normal(KEY, (9, 50))
    z = factor_matvec.factor_matvec(
        x, jnp.zeros((0, 50)), jnp.zeros((0,)), jnp.zeros((0, 30)), interpret=True)
    assert z.shape == (9, 30) and not np.any(np.asarray(z))
    a = jax.random.normal(jax.random.fold_in(KEY, 43), (5, 50))
    s = jax.random.normal(jax.random.fold_in(KEY, 44), (5,))
    b = jax.random.normal(jax.random.fold_in(KEY, 45), (5, 30))
    via_ref = factor_matvec.factor_matvec(x, a, s, b, alpha=1.3)
    via_kernel = factor_matvec.factor_matvec(
        x, a, s, b, alpha=1.3, block_b=32, block_o=32, interpret=True)
    np.testing.assert_allclose(np.asarray(via_ref), np.asarray(via_kernel),
                               rtol=1e-5, atol=1e-5)


def test_factor_matvec_zero_tail_rows_are_exact_noops():
    """The low_rank invariant the serving engine relies on: capacity rows
    with s == 0 change nothing, so bucket padding is free."""
    x = jax.random.normal(KEY, (6, 40))
    a = jax.random.normal(jax.random.fold_in(KEY, 46), (3, 40))
    s = jax.random.normal(jax.random.fold_in(KEY, 47), (3,))
    b = jax.random.normal(jax.random.fold_in(KEY, 48), (3, 20))
    def pad(t, rows):
        return jnp.concatenate([t, jnp.zeros((rows,) + t.shape[1:])])
    live = factor_matvec.factor_matvec(x, a, s, b, interpret=True,
                                       block_b=32, block_o=32)
    padded = factor_matvec.factor_matvec(
        x, pad(a, 13), pad(s, 13), pad(b, 13), interpret=True,
        block_b=32, block_o=32)
    np.testing.assert_array_equal(np.asarray(live), np.asarray(padded))


@pytest.mark.parametrize("n,m", [(128, 128), (100, 90), (33, 257)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_rank1_update(n, m, dt):
    z = jax.random.normal(KEY, (n, m)).astype(dt)
    y0 = jax.random.normal(jax.random.fold_in(KEY, 5), (n, m)).astype(dt)
    xv = jax.random.normal(jax.random.fold_in(KEY, 6), (n,)).astype(dt)
    yv = jax.random.normal(jax.random.fold_in(KEY, 7), (m,)).astype(dt)
    got = rank1_update.rank1_update(z, xv, yv, 0.7, -0.3,
                                    block_r=64, block_c=64, interpret=True)
    want = rank1_update.ref.rank1_update(z, xv, yv, 0.7, -0.3)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))
    got = rank1_update.rank1_update_axpy(z, y0, xv, yv, 0.7, -0.3, -0.5,
                                         block_r=64, block_c=64, interpret=True)
    want = rank1_update.ref.rank1_update_axpy(z, y0, xv, yv, 0.7, -0.3, -0.5)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dt))


@pytest.mark.parametrize("n,budget,block_n", [(512, 15, 128), (130, 127, 64),
                                              (31, 3, 32)])
def test_quantize_dequantize_kernel(n, budget, block_n):
    """Fused stochastic-round quantize + dequantize vs the jnp oracle.

    Exact equality: noise is an explicit operand, so kernel and ref compute
    the identical floor (see kernels/quantize/kernel.py)."""
    x = jax.random.normal(KEY, (n,)) * 3.0
    noise = jax.random.uniform(jax.random.fold_in(KEY, 30), (n,))
    scale = jnp.max(jnp.abs(x))
    q = quantize.ops.quantize(x, noise, scale, budget=budget,
                              block_n=block_n, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(q), np.asarray(quantize.ref.quantize(x, noise, scale, budget)))
    assert q.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(q.astype(jnp.int32)))) <= budget
    deq = quantize.ops.dequantize(q, scale, budget=budget,
                                  block_n=block_n, interpret=True)
    np.testing.assert_allclose(
        np.asarray(deq), np.asarray(quantize.ref.dequantize(q, scale, budget)),
        rtol=1e-6, atol=1e-6)
    # the roundtrip lands within one grid step of the input
    assert float(jnp.max(jnp.abs(deq - x))) <= float(scale) / budget * (1 + 1e-6)


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2), (8, 1)])
def test_flash_attention_gqa(causal, hq, hkv):
    b, sq, skv, dh = 2, 96, 96, 32
    q = jax.random.normal(KEY, (b, hq, sq, dh), jnp.float32)
    k = jax.random.normal(jax.random.fold_in(KEY, 8), (b, hkv, skv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 9), (b, hkv, skv, dh))
    got = flash_attention.flash_attention(
        q, k, v, scale=dh**-0.5, causal=causal, block_q=32, block_k=32, interpret=True)
    want = flash_attention.ref.attention(q, k, v, scale=dh**-0.5, causal=causal)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_ragged_padding():
    """Non-multiple seq lens exercise the kv_len mask path."""
    b, hq, hkv, sq, skv, dh = 1, 2, 2, 50, 70, 16
    q = jax.random.normal(KEY, (b, hq, sq, dh))
    k = jax.random.normal(jax.random.fold_in(KEY, 10), (b, hkv, skv, dh))
    v = jax.random.normal(jax.random.fold_in(KEY, 11), (b, hkv, skv, dh))
    got = flash_attention.flash_attention(
        q, k, v, scale=dh**-0.5, causal=False, block_q=32, block_k=32, interpret=True)
    want = flash_attention.ref.attention(q, k, v, scale=dh**-0.5, causal=False)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)


def test_flash_attention_bf16():
    b, hq, hkv, s, dh = 2, 4, 2, 64, 32
    q = jax.random.normal(KEY, (b, hq, s, dh)).astype(jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(KEY, 12), (b, hkv, s, dh)).astype(jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(KEY, 13), (b, hkv, s, dh)).astype(jnp.bfloat16)
    got = flash_attention.flash_attention(
        q, k, v, scale=dh**-0.5, causal=True, block_q=32, block_k=32, interpret=True)
    want = flash_attention.ref.attention(q, k, v, scale=dh**-0.5, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), rtol=5e-2, atol=5e-2)


@pytest.mark.parametrize("q,dk,dv", [(32, 16, 16), (64, 64, 64), (16, 32, 64)])
@pytest.mark.parametrize("dt", [jnp.float32, jnp.bfloat16])
def test_wkv6_chunk_kernel(q, dk, dv, dt):
    """WKV6 chunk kernel vs the exact sequential recurrence."""
    from repro.kernels import wkv6_chunk

    bh = 3
    ks = jax.random.split(KEY, 6)
    r = (jax.random.normal(ks[0], (bh, q, dk)) * 0.5).astype(dt)
    k = (jax.random.normal(ks[1], (bh, q, dk)) * 0.5).astype(dt)
    v = jax.random.normal(ks[2], (bh, q, dv)).astype(dt)
    logw = (-jnp.exp(jax.random.normal(ks[3], (bh, q, dk)) * 0.3 - 1.0)).astype(dt)
    u = (jax.random.normal(ks[4], (bh, dk)) * 0.2).astype(dt)
    s0 = (jax.random.normal(ks[5], (bh, dk, dv)) * 0.3).astype(jnp.float32)

    y_ref, s_ref = wkv6_chunk.ref.wkv6_chunk_batched(r, k, v, logw, u, s0)
    y_k, s_k = wkv6_chunk.kernel.wkv6_chunk(r, k, v, logw, u, s0, interpret=True)
    tol = dict(rtol=5e-2, atol=5e-2) if dt == jnp.bfloat16 else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_ref), **tol)
    np.testing.assert_allclose(np.asarray(s_k), np.asarray(s_ref), **tol)


def test_wkv6_chunk_matches_model_time_mix_step():
    """The kernel computes the same chunk transition the rwkv6 model uses."""
    from repro.configs import get_config
    from repro.kernels import wkv6_chunk
    from repro.models import rwkv6

    cfg = get_config("rwkv6_7b", smoke=True)
    b, s, d = 1, 32, cfg.d_model
    h = d // rwkv6.HEAD
    p = rwkv6.init_rwkv(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(4), (b, s, d)) * 0.5
    x_prev = jnp.zeros((b, d))
    s0 = jnp.zeros((b, h, rwkv6.HEAD, rwkv6.HEAD))
    y_model, s_model, _ = rwkv6.time_mix(p, x, cfg, x_prev, s0)

    # recompute the same projections and feed the kernel chunk-by-chunk
    xs = rwkv6._token_shift(x, x_prev)
    r = (rwkv6._mix(x, xs, p["mu_r"]) @ p["wr"]).reshape(b, s, h, rwkv6.HEAD)
    k = (rwkv6._mix(x, xs, p["mu_k"]) @ p["wk"]).reshape(b, s, h, rwkv6.HEAD)
    v = (rwkv6._mix(x, xs, p["mu_v"]) @ p["wv"]).reshape(b, s, h, rwkv6.HEAD)
    wx = rwkv6._mix(x, xs, p["mu_w"])
    logw = (
        -jnp.exp(p["w_base"] + jnp.tanh(wx @ p["w_lora_a"]) @ p["w_lora_b"])
    ).reshape(b, s, h, rwkv6.HEAD)
    q = cfg.ssm_chunk
    state = jnp.zeros((b * h, rwkv6.HEAD, rwkv6.HEAD))
    u = jnp.broadcast_to(p["u_bonus"], (b, h, rwkv6.HEAD)).reshape(b * h, rwkv6.HEAD)
    for c0 in range(0, s, q):
        args = [t[:, c0 : c0 + q].transpose(0, 2, 1, 3).reshape(b * h, q, rwkv6.HEAD)
                for t in (r, k, v, logw)]
        _, state = wkv6_chunk.kernel.wkv6_chunk(*args, u, state, interpret=True)
    np.testing.assert_allclose(
        np.asarray(state.reshape(b, h, rwkv6.HEAD, rwkv6.HEAD)),
        np.asarray(s_model), rtol=1e-3, atol=1e-3)
