"""MatrixCompletion task: sparse sufficient information vs dense oracles.

The task state is O(|Omega_j|) COO shards; every check here compares the
segment-gather/scatter chains against an explicitly materialized d x m
simulation of the same FW trajectory (small instances only).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fit, low_rank, tasks
from repro.launch import dfw

KEY = jax.random.PRNGKey(0)


def _mc_problem(key, d=30, m=24, rank=3, obs=0.4):
    ku, kv, kx = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
    v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
    s = jnp.linspace(1.0, 0.3, rank)
    s = s / jnp.sum(s)  # trace norm exactly 1
    w_true = (u * s) @ v.T
    mask = jax.random.bernoulli(kx, obs, (d, m))
    rows, cols = jnp.nonzero(mask)
    return rows, cols, w_true[rows, cols], w_true


def _dense_grad(d, m, rows, cols, resid):
    g = np.zeros((d, m), np.float32)
    np.add.at(g, (np.asarray(rows), np.asarray(cols)), np.asarray(resid))
    return g


def test_matvec_rmatvec_match_dense_oracle():
    rows, cols, vals, _ = _mc_problem(KEY)
    task = tasks.MatrixCompletion(d=30, m=24)
    s = task.init_state(*tasks.pack_observations(rows, cols, vals))
    g = _dense_grad(30, 24, rows, cols, s.resid)
    v = jax.random.normal(jax.random.fold_in(KEY, 1), (24,))
    u = jax.random.normal(jax.random.fold_in(KEY, 2), (30,))
    np.testing.assert_allclose(np.asarray(task.matvec(s, v)), g @ np.asarray(v),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(task.rmatvec(s, u)), g.T @ np.asarray(u),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(task.local_grad(s)), g,
                               rtol=1e-5, atol=1e-6)


def test_fw_trajectory_matches_dense_simulation():
    """Run real FW epochs on the sparse state and replay them densely: the
    materialize-free losses/gaps must match the dense-oracle bookkeeping."""
    rows, cols, vals, _ = _mc_problem(KEY)
    d, m, mu = 30, 24, 1.2
    task = tasks.MatrixCompletion(d=d, m=m)
    res = fit(task, task.init_state(*tasks.pack_observations(rows, cols, vals)),
              mu=mu, num_epochs=10, key=jax.random.PRNGKey(1),
              schedule="const:2", step_size="linesearch")
    w = low_rank.materialize(res.iterate)
    # state residual == dense residual of the factored iterate
    np.testing.assert_allclose(np.asarray(res.state.resid),
                               np.asarray(w[rows, cols] - vals),
                               rtol=1e-3, atol=1e-5)
    # sufficient-information loss == dense objective
    dense_loss = 0.5 * float(jnp.sum((w[rows, cols] - vals) ** 2))
    np.testing.assert_allclose(float(task.local_loss(res.state)), dense_loss,
                               rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(res.final_loss, dense_loss, rtol=1e-4, atol=1e-7)


def test_inner_w_grad_matches_dense():
    rows, cols, vals, _ = _mc_problem(KEY)
    task = tasks.MatrixCompletion(d=30, m=24)
    s = task.init_state(*tasks.pack_observations(rows, cols, vals))
    u = jax.random.normal(jax.random.fold_in(KEY, 3), (30,))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (24,))
    u, v = u / jnp.linalg.norm(u), v / jnp.linalg.norm(v)
    s = task.update(s, u, v, 0.4, 1.5)  # some nonzero iterate
    w = np.zeros((30, 24), np.float32)
    w[np.asarray(rows), np.asarray(cols)] = np.asarray(s.resid + vals)
    g = _dense_grad(30, 24, rows, cols, s.resid)
    np.testing.assert_allclose(float(task.inner_w_grad(s)), float((w * g).sum()),
                               rtol=1e-4, atol=1e-5)


def test_linesearch_is_exact_quadratic_minimizer():
    rows, cols, vals, _ = _mc_problem(KEY)
    d, m, mu = 30, 24, 1.5
    task = tasks.MatrixCompletion(d=d, m=m)
    s = task.init_state(*tasks.pack_observations(rows, cols, vals))
    u = jax.random.normal(jax.random.fold_in(KEY, 5), (d,))
    v = jax.random.normal(jax.random.fold_in(KEY, 6), (m,))
    u, v = u / jnp.linalg.norm(u), v / jnp.linalg.norm(v)
    s = task.update(s, u, v, 0.3, mu)  # move off W=0 first
    numer, denom = task.linesearch_terms(s, u, v, mu)
    gamma_star = float(numer) / float(denom)

    def dense_loss(gamma):
        w = np.zeros((d, m), np.float32)
        w[np.asarray(rows), np.asarray(cols)] = np.asarray(s.resid + vals)
        w2 = (1 - gamma) * w - gamma * mu * np.outer(u, v)
        return 0.5 * ((w2[np.asarray(rows), np.asarray(cols)]
                       - np.asarray(vals)) ** 2).sum()

    eps = 1e-3
    assert dense_loss(gamma_star) <= dense_loss(gamma_star + eps) + 1e-9
    assert dense_loss(gamma_star) <= dense_loss(gamma_star - eps) + 1e-9


def test_zero_weight_padding_is_noop():
    """Padded states must produce bit-identical losses, matvecs and updates —
    the invariant the shard_map driver's static shapes rest on."""
    rows, cols, vals, _ = _mc_problem(KEY)
    task = tasks.MatrixCompletion(d=30, m=24)
    s0 = task.init_state(*tasks.pack_observations(rows, cols, vals))

    pad = 17  # arbitrary coordinates with weight 0 — values must not matter
    rows_p = jnp.concatenate([rows, jnp.full((pad,), 3, rows.dtype)])
    cols_p = jnp.concatenate([cols, jnp.full((pad,), 5, cols.dtype)])
    vals_p = jnp.concatenate([vals, jnp.full((pad,), 123.0)])
    w_p = jnp.concatenate([jnp.ones_like(vals), jnp.zeros((pad,))])
    s1 = task.init_state(*tasks.pack_observations(rows_p, cols_p, vals_p, w_p))

    v = jax.random.normal(jax.random.fold_in(KEY, 7), (24,))
    u = jax.random.normal(jax.random.fold_in(KEY, 8), (30,))
    np.testing.assert_array_equal(np.asarray(task.matvec(s0, v)),
                                  np.asarray(task.matvec(s1, v)))
    np.testing.assert_array_equal(float(task.local_loss(s0)),
                                  float(task.local_loss(s1)))
    u, v = u / jnp.linalg.norm(u), v / jnp.linalg.norm(v)
    s0u, s1u = task.update(s0, u, v, 0.5, 1.0), task.update(s1, u, v, 0.5, 1.0)
    np.testing.assert_array_equal(float(task.local_loss(s0u)),
                                  float(task.local_loss(s1u)))
    np.testing.assert_allclose(task.linesearch_terms(s0u, u, v, 1.0),
                               task.linesearch_terms(s1u, u, v, 1.0),
                               rtol=1e-6)
    # padded residuals stay exactly zero through updates
    assert float(jnp.max(jnp.abs(s1u.resid[-pad:]))) == 0.0


def test_gather_entries_matches_materialize():
    rows, cols, vals, _ = _mc_problem(KEY)
    task = tasks.MatrixCompletion(d=30, m=24)
    res = fit(task, task.init_state(*tasks.pack_observations(rows, cols, vals)),
              mu=1.0, num_epochs=6, key=jax.random.PRNGKey(2),
              schedule="const:1")
    w = low_rank.materialize(res.iterate)
    got = low_rank.gather_entries(res.iterate, rows, cols)
    np.testing.assert_allclose(np.asarray(got), np.asarray(w[rows, cols]),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.slow  # 80-epoch recovery sweep
def test_completion_recovers_low_rank_matrix():
    """Acceptance: held-out RMSE decreasing, duality gap reaching tolerance."""
    rows, cols, vals, w_true = _mc_problem(jax.random.PRNGKey(3),
                                           d=48, m=36, rank=3, obs=0.45)
    ks = jax.random.fold_in(KEY, 9)
    holdout = jax.random.bernoulli(ks, 0.15, rows.shape)
    tr = jnp.nonzero(~holdout)[0]
    ho = jnp.nonzero(holdout)[0]
    task = tasks.MatrixCompletion(d=48, m=36)

    def ho_rmse(it):
        pred = low_rank.gather_entries(it, rows[ho], cols[ho])
        return float(jnp.sqrt(jnp.mean((pred - vals[ho]) ** 2)))

    state0 = task.init_state(*tasks.pack_observations(rows[tr], cols[tr],
                                                      vals[tr]))
    short = fit(task, state0, mu=1.0, num_epochs=10, key=jax.random.PRNGKey(4),
                schedule="const:2", step_size="linesearch")
    res = fit(task, state0, mu=1.0, num_epochs=80, key=jax.random.PRNGKey(4),
              schedule="const:2", step_size="linesearch")
    # train loss collapses; gap reaches tolerance
    assert res.final_loss < 0.02 * res.history["loss"][0]
    assert res.history["gap"][-1] < 0.1 * res.history["gap"][0]
    # held-out RMSE decreases with epochs and beats the predict-zero baseline
    base = float(jnp.sqrt(jnp.mean(vals[ho] ** 2)))
    assert ho_rmse(res.iterate) < ho_rmse(short.iterate) < base
    assert ho_rmse(res.iterate) < 0.5 * base


def test_kernelized_mc_matches_base_task():
    rows, cols, vals, _ = _mc_problem(KEY)
    task = tasks.MatrixCompletion(d=30, m=24)
    s = task.init_state(*tasks.pack_observations(rows, cols, vals))
    ktask = dfw.kernelize(task)
    v = jax.random.normal(jax.random.fold_in(KEY, 10), (24,))
    u = jax.random.normal(jax.random.fold_in(KEY, 11), (30,))
    np.testing.assert_allclose(np.asarray(ktask.matvec(s, v)),
                               np.asarray(task.matvec(s, v)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ktask.rmatvec(s, u)),
                               np.asarray(task.rmatvec(s, u)),
                               rtol=1e-5, atol=1e-5)
    err = dfw.verify_kernelized(task, ktask, s, jax.random.fold_in(KEY, 12))
    assert err < 1e-4


def test_shard_observations_row_blocks():
    d, nw = 30, 4
    rows, cols, vals, _ = _mc_problem(KEY, d=d)
    idx, yw = dfw.shard_observations(rows, cols, vals, nw, d, m=24)
    assert idx.shape[0] % nw == 0
    p = idx.shape[0] // nw
    block = -(-d // nw)
    for j in range(nw):
        sl = slice(j * p, (j + 1) * p)
        w = np.asarray(yw[sl, 1])
        r = np.asarray(idx[sl, 0])
        # live entries sit in worker j's row block; padding has weight 0
        live = w > 0
        assert np.all(r[live] // block == j) or not live.any()
    # no observation lost or duplicated: weighted values reassemble exactly
    got = np.zeros((d, 24), np.float32)
    np.add.at(got, (np.asarray(idx[:, 0]), np.asarray(idx[:, 1])),
              np.asarray(yw[:, 0] * yw[:, 1]))
    want = np.zeros((d, 24), np.float32)
    np.add.at(want, (np.asarray(rows), np.asarray(cols)), np.asarray(vals))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    assert float(jnp.sum(yw[:, 1])) == rows.shape[0]


def test_shard_observations_rejects_bad_indices():
    with pytest.raises(ValueError, match="row indices"):
        dfw.shard_observations(jnp.array([0, 40]), jnp.array([0, 1]),
                               jnp.array([1.0, 2.0]), 4, 30)
    # out-of-range columns would be silently clipped by the downstream
    # gather/segment chains — the host-side layout must reject them
    with pytest.raises(ValueError, match="column indices"):
        dfw.shard_observations(jnp.array([0, 1]), jnp.array([0, 24]),
                               jnp.array([1.0, 2.0]), 4, 30, m=24)
    with pytest.raises(ValueError, match="nonnegative"):
        dfw.shard_observations(jnp.array([0, 1]), jnp.array([0, -1]),
                               jnp.array([1.0, 2.0]), 4, 30)
