"""Tests for the distributed DFW-Trace execution layer (launch/dfw.py).

Multi-device coverage runs in subprocesses with 8 fake CPU devices (the
device count locks at the first jax init in the main pytest process); the
kernel-routing and worker-schedule units run in-process on one device.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tasks
from repro.kernels.power_matvec import ref as pm_ref
from repro.launch import dfw

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


_PROBLEM = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks, low_rank
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        yl = jnp.argmax(X @ W, axis=1)
"""


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_sharded_equals_serial_mtls():
    """shard_map driver == serial driver on MTLS + line search (8 workers)."""
    out = _run(_PROBLEM + """
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=8, schedule="const:2",
                            step_size="linesearch")
        ser = dfw.fit_serial(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1))
        dist = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                       num_workers=8)
        np.testing.assert_allclose(ser.history["loss"], dist.history["loss"],
                                   rtol=1e-5)
        np.testing.assert_allclose(ser.history["gap"], dist.history["gap"],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ser.history["sigma"], dist.history["sigma"],
                                   rtol=1e-4)
        W1 = low_rank.materialize(ser.iterate)
        W2 = low_rank.materialize(dist.iterate)
        assert float(jnp.max(jnp.abs(W1 - W2))) < 1e-6
        print("mtls sharded == serial OK")
    """)
    assert "OK" in out


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_sharded_equals_serial_logistic():
    """shard_map driver == serial driver on multinomial logistic (8 workers)."""
    out = _run(_PROBLEM + """
        task = tasks.MultinomialLogistic(d=d, m=m)
        cfg = dfw.DFWConfig(mu=10.0, num_epochs=8, schedule="log")
        ser = dfw.fit_serial(task, X, yl, cfg=cfg, key=jax.random.PRNGKey(1))
        dist = dfw.fit(task, X, yl, cfg=cfg, key=jax.random.PRNGKey(1),
                       num_workers=8)
        np.testing.assert_allclose(ser.history["loss"], dist.history["loss"],
                                   rtol=1e-5)
        np.testing.assert_allclose(ser.history["gap"], dist.history["gap"],
                                   rtol=1e-4, atol=1e-4)
        assert ser.history["k"] == dist.history["k"]  # same K(t) compilations
        print("logistic sharded == serial OK")
    """)
    assert "OK" in out


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_sharded_equals_serial_matrix_completion():
    """shard_map driver == serial driver on matrix completion: row-block entry
    sharding with zero-weight padding, COO sufficient information (8 workers)."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks, low_rank
        from repro.launch import dfw

        d, m, rank = 64, 48, 5
        key = jax.random.PRNGKey(0)
        ku, kv, ko = jax.random.split(key, 3)
        U = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
        V = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
        sv = jnp.linspace(1.0, 0.2, rank); sv = sv / jnp.sum(sv)
        W = (U * sv) @ V.T
        mask = jax.random.bernoulli(ko, 0.35, (d, m))
        rows, cols = jnp.nonzero(mask)
        vals = W[rows, cols]

        task = tasks.MatrixCompletion(d=d, m=m)
        cfg = dfw.DFWConfig(mu=1.5, num_epochs=10, schedule="const:2",
                            step_size="linesearch")
        idx, yw = tasks.pack_observations(rows, cols, vals)
        ser = dfw.fit_serial(task, idx, yw, cfg=cfg, key=jax.random.PRNGKey(1))
        idx8, yw8 = dfw.shard_observations(rows, cols, vals, 8, d, m=m)
        dist = dfw.fit(task, idx8, yw8, cfg=cfg, key=jax.random.PRNGKey(1),
                       num_workers=8)
        np.testing.assert_allclose(ser.history["loss"], dist.history["loss"],
                                   rtol=1e-5)
        np.testing.assert_allclose(ser.history["gap"], dist.history["gap"],
                                   rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(ser.history["sigma"], dist.history["sigma"],
                                   rtol=1e-4)
        np.testing.assert_allclose(ser.final_loss, dist.final_loss, rtol=1e-5)
        W1 = low_rank.materialize(ser.iterate)
        W2 = low_rank.materialize(dist.iterate)
        assert float(jnp.max(jnp.abs(W1 - W2))) < 1e-6
        assert dist.final_loss < 0.3 * dist.history["loss"][0]  # it converges
        print("matrix completion sharded == serial OK")
    """)
    assert "OK" in out


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_sampled_worker_mode_converges():
    """Bernoulli worker sampling (paper's straggler model): some workers drop
    every epoch, the run still converges, and masks are recorded."""
    out = _run(_PROBLEM + """
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=25, schedule="const:2",
                            step_size="linesearch", sample_prob=0.6)
        res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(2),
                      num_workers=8)
        assert res.masks.shape == (25, 8)
        alive = jnp.sum(res.masks > 0, axis=1)
        assert float(jnp.min(alive)) >= 1          # LMO always defined
        assert float(jnp.max(alive)) <= 8
        assert bool(jnp.any(alive < 8))            # sampling actually dropped
        # reweighting keeps the psum an unbiased full-data estimate
        np.testing.assert_allclose(jnp.sum(res.masks, axis=1), 8.0, rtol=1e-5)
        assert res.history["loss"][-1] < 0.35 * res.history["loss"][0]
        print("sampled-worker mode OK", res.history["loss"][-1])
    """)
    assert "OK" in out


def test_uneven_rows_rejected():
    out = _run(_PROBLEM + """
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=2)
        try:
            dfw.fit(task, X[:1597], Y[:1597], cfg=cfg,
                    key=jax.random.PRNGKey(0), num_workers=8)
        except ValueError as e:
            assert "divisible" in str(e)
            print("uneven rows rejected OK")
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Kernel routing (single device; ops dispatch to the jnp ref off-TPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("taskcls", [tasks.MultiTaskLeastSquares,
                                     tasks.MultinomialLogistic])
def test_kernelized_matches_base_task(taskcls):
    n, d, m = 192, 24, 16
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    task = taskcls(d=d, m=m)
    if taskcls is tasks.MultinomialLogistic:
        y = jax.random.randint(jax.random.fold_in(key, 1), (n,), 0, m)
    else:
        y = jax.random.normal(jax.random.fold_in(key, 1), (n, m))
    state = task.init_state(x, y)
    ktask = dfw.kernelize(task)
    v = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    u = jax.random.normal(jax.random.fold_in(key, 3), (d,))
    np.testing.assert_allclose(np.asarray(ktask.matvec(state, v)),
                               np.asarray(task.matvec(state, v)),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(ktask.rmatvec(state, u)),
                               np.asarray(task.rmatvec(state, u)),
                               rtol=1e-5, atol=1e-5)
    # the up-front driver check agrees too
    err = dfw.verify_kernelized(task, ktask, state, jax.random.fold_in(key, 4))
    assert err < 1e-4
    # delegation: everything but matvec/rmatvec reaches the base task
    assert ktask.d == d and ktask.m == m
    assert float(ktask.local_loss(state)) == float(task.local_loss(state))


def test_kernelized_mtls_matches_power_matvec_ref():
    """The kernel route == an explicit chain through power_matvec/ref.py."""
    n, d, m = 128, 20, 12
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (n, d))
    y = jax.random.normal(jax.random.fold_in(key, 1), (n, m))
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    s = task.init_state(x, y)
    ktask = dfw.kernelize(task)
    v = jax.random.normal(jax.random.fold_in(key, 2), (m,))
    want = pm_ref.rmatvec(s.x, pm_ref.matvec(s.r, v))[:, 0]
    np.testing.assert_allclose(np.asarray(ktask.matvec(s, v)),
                               np.asarray(want), rtol=1e-5, atol=1e-5)


def test_verify_kernelized_catches_divergence():
    task = tasks.MultiTaskLeastSquares(d=8, m=6)
    key = jax.random.PRNGKey(6)
    s = task.init_state(jax.random.normal(key, (32, 8)),
                        jax.random.normal(jax.random.fold_in(key, 1), (32, 6)))

    class Broken(dfw.KernelizedTask):
        def matvec(self, st, v):
            return 2.0 * super().matvec(st, v)

    with pytest.raises(AssertionError, match="diverges"):
        dfw.verify_kernelized(task, Broken(task), s, key)


def test_fit_serial_rejects_sample_prob():
    """Regression: fit_serial used to silently ignore sample_prob < 1 (and
    reweight), so a 'straggler mode' serial benchmark measured nothing. One
    worker has nobody to sample — reject loudly."""
    task = tasks.MultiTaskLeastSquares(d=8, m=6)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (64, 8))
    y = jax.random.normal(jax.random.fold_in(key, 1), (64, 6))
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=2, sample_prob=0.5)
    with pytest.raises(ValueError, match="sample_prob"):
        dfw.fit_serial(task, x, y, cfg=cfg, key=key)
    # sample_prob=1.0 (the default) still runs
    ok = dfw.fit_serial(task, x, y,
                        cfg=dfw.DFWConfig(mu=1.0, num_epochs=2), key=key)
    assert ok.epochs_run == 2


def test_max_rank_underflow_rejected():
    """One factor is appended per epoch; an undersized iterate store would be
    silently corrupted by fw_update's clamped writes, so fit() rejects it."""
    task = tasks.MultiTaskLeastSquares(d=8, m=6)
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (64, 8))
    y = jax.random.normal(jax.random.fold_in(key, 1), (64, 6))
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=5, max_rank=3)
    with pytest.raises(ValueError, match="max_rank"):
        dfw.fit(task, x, y, cfg=cfg, key=key, num_workers=1)


# ---------------------------------------------------------------------------
# Worker-sampling schedule units
# ---------------------------------------------------------------------------


def test_worker_schedule_always_keeps_one_alive():
    masks = dfw.worker_schedule(jax.random.PRNGKey(0), 200, 8, 0.05,
                                reweight=False)
    assert masks.shape == (200, 8)
    alive = np.asarray(jnp.sum(masks > 0, axis=1))
    assert alive.min() >= 1
    assert set(np.unique(masks)).issubset({0.0, 1.0})


def test_worker_schedule_reweight_is_unbiased():
    masks = dfw.worker_schedule(jax.random.PRNGKey(1), 100, 8, 0.5,
                                reweight=True)
    np.testing.assert_allclose(np.asarray(jnp.sum(masks, axis=1)),
                               np.full(100, 8.0), rtol=1e-5)
    alive = np.asarray(jnp.sum(masks > 0, axis=1))
    # with p=0.5 over 100 epochs we should see real variation
    assert alive.min() < 8


def test_worker_schedule_full_participation():
    masks = dfw.worker_schedule(jax.random.PRNGKey(2), 10, 4, 1.0)
    np.testing.assert_allclose(np.asarray(masks), np.ones((10, 4)))
