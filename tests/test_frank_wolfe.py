"""DFW-TRACE convergence vs paper claims (Thm 1/2 rates, baselines §3.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    baselines,
    fit,
    low_rank,
    tasks,
    trace_norm,
)
from repro.core.frank_wolfe import k_schedule


# ---------------------------------------------------------------------------
# K(t) schedules (paper Thm 2 + §5 experimental settings)
# ---------------------------------------------------------------------------


def test_k_schedule_const():
    for k in (1, 2, 8):
        sched = k_schedule(f"const:{k}")
        assert [sched(t) for t in (0, 1, 10, 100)] == [k] * 4


def test_k_schedule_log_variants():
    log = k_schedule("log")
    half = k_schedule("log_half")
    assert log(0) == 1 and half(0) == 1
    vals_log = [log(t) for t in range(200)]
    vals_half = [half(t) for t in range(200)]
    # nondecreasing, integer, and the half schedule never exceeds the full one
    assert all(b >= a for a, b in zip(vals_log, vals_log[1:]))
    assert all(b >= a for a, b in zip(vals_half, vals_half[1:]))
    assert all(h <= g for h, g in zip(vals_half, vals_log))
    assert all(isinstance(v, int) and v >= 1 for v in vals_log + vals_half)
    assert vals_log[-1] > vals_log[0]  # actually grows


def test_k_schedule_linear():
    sched = k_schedule("linear:0.5")
    vals = [sched(t) for t in range(50)]
    assert all(b >= a for a, b in zip(vals, vals[1:]))
    assert sched(0) == 1 + int(np.ceil(0.5 * 2))
    # slope ~ c: over 40 steps the schedule grows by ~20
    assert 18 <= vals[40] - vals[0] <= 22


def test_k_schedule_unknown_name_raises():
    with pytest.raises(ValueError, match="unknown K schedule"):
        k_schedule("fibonacci")
    with pytest.raises(ValueError):
        k_schedule("const")  # malformed: missing :K suffix


def test_k_schedule_rejects_zero_iterations():
    """Regression: const:0 used to be accepted and power_iterations(0) then
    returned u=0, sigma=0 — silently corrupting the FW update and the gap."""
    with pytest.raises(ValueError, match="K must be >= 1"):
        k_schedule("const:0")
    with pytest.raises(ValueError, match="K must be >= 1"):
        k_schedule("const:-3")
    with pytest.raises(ValueError, match="c must be > 0"):
        k_schedule("linear:0")
    with pytest.raises(ValueError, match="c must be > 0"):
        k_schedule("linear:-0.5")


def test_zero_power_iterations_rejected_everywhere():
    from repro.core.frank_wolfe import make_epoch_step
    from repro.core.power_method import power_iterations

    task = tasks.MultiTaskLeastSquares(d=8, m=6)
    with pytest.raises(ValueError, match="num_power_iters"):
        make_epoch_step(task, 1.0, 0)
    with pytest.raises(ValueError, match="num_iters"):
        power_iterations(lambda v: v, lambda u: u,
                         jnp.ones((6,)), 0)


def test_fw_update_gamma_one_annihilates_old_factors():
    """Regression: a full step (gamma==1, reachable at any t since the line
    search clips to [0,1]) means W <- S = -mu u v^T. The alpha-underflow floor
    used to keep the old factors' s entries live, resurrecting the previous
    iterate at full scale."""
    d, m, mu = 7, 5, 2.0
    key = jax.random.PRNGKey(0)
    it = low_rank.init(4, d, m)
    for t in range(2):  # build a nontrivial iterate first (t > 0)
        u = jax.random.normal(jax.random.fold_in(key, t), (d,))
        v = jax.random.normal(jax.random.fold_in(key, 10 + t), (m,))
        u, v = u / jnp.linalg.norm(u), v / jnp.linalg.norm(v)
        it = low_rank.fw_update(it, u, v, 0.5, mu)
    assert float(jnp.linalg.norm(low_rank.materialize(it))) > 0.1

    u1 = jax.random.normal(jax.random.fold_in(key, 99), (d,))
    v1 = jax.random.normal(jax.random.fold_in(key, 98), (m,))
    u1, v1 = u1 / jnp.linalg.norm(u1), v1 / jnp.linalg.norm(v1)
    it = low_rank.fw_update(it, u1, v1, 1.0, mu)
    np.testing.assert_allclose(np.asarray(low_rank.materialize(it)),
                               np.asarray(-mu * jnp.outer(u1, v1)),
                               rtol=1e-6, atol=1e-6)
    # the follow-up epoch still behaves: a partial step blends S into the new W
    u2 = jax.random.normal(jax.random.fold_in(key, 97), (d,))
    v2 = jax.random.normal(jax.random.fold_in(key, 96), (m,))
    u2, v2 = u2 / jnp.linalg.norm(u2), v2 / jnp.linalg.norm(v2)
    w_next = low_rank.materialize(low_rank.fw_update(it, u2, v2, 0.25, mu))
    want = 0.75 * np.asarray(-mu * jnp.outer(u1, v1)) + 0.25 * np.asarray(
        -mu * jnp.outer(u2, v2))
    np.testing.assert_allclose(np.asarray(w_next), want, rtol=1e-5, atol=1e-6)


def test_fit_final_loss_is_returned_iterate_loss():
    """history[t] is the *pre-update* loss (documented contract); the loss of
    the returned iterate is exposed as final_loss and must match an explicit
    evaluation of the returned state."""
    x, y, _ = _mtls_problem(jax.random.PRNGKey(20), n=400, d=20, m=15)
    task = tasks.MultiTaskLeastSquares(d=20, m=15)
    res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=6,
              key=jax.random.PRNGKey(21), schedule="const:2",
              step_size="linesearch")
    want = float(task.local_loss(res.state))
    np.testing.assert_allclose(res.final_loss, want, rtol=1e-6)
    # on a strictly-decreasing run the stale history[-1] overstates the loss
    assert res.final_loss < res.history["loss"][-1]


def test_fit_max_rank_capacity_contract():
    """Regression: ``fit`` used to hardcode ``low_rank.init(num_epochs, ...)``
    with no way to preallocate extra capacity, and an undersized store would
    be silently corrupted by fw_update's clamped writes. ``max_rank=`` now
    follows the same validated contract as ``launch/dfw.DFWConfig``."""
    x, y, _ = _mtls_problem(jax.random.PRNGKey(30), n=200, d=12, m=10)
    task = tasks.MultiTaskLeastSquares(d=12, m=10)
    with pytest.raises(ValueError, match="max_rank"):
        fit(task, task.init_state(x, y), mu=1.0, num_epochs=5,
            key=jax.random.PRNGKey(31), max_rank=3)
    res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=5,
              key=jax.random.PRNGKey(31), max_rank=9)
    assert res.iterate.u.shape == (9, 12)  # requested capacity, not epochs
    assert int(res.iterate.count) == 5
    # extra capacity changes storage only, never the trajectory
    default = fit(task, task.init_state(x, y), mu=1.0, num_epochs=5,
                  key=jax.random.PRNGKey(31))
    np.testing.assert_allclose(res.history["loss"], default.history["loss"],
                               rtol=1e-6)
    np.testing.assert_allclose(
        np.asarray(low_rank.materialize(res.iterate)),
        np.asarray(low_rank.materialize(default.iterate)),
        rtol=1e-6, atol=1e-7)


def _mtls_problem(key, n=1500, d=40, m=30, rank=5):
    ku, kv, kx = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
    v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
    s = jnp.linspace(0.4, 0.05, rank)
    s = s / jnp.sum(s)  # trace norm exactly 1
    w_true = (u * s) @ v.T
    x = jax.random.normal(kx, (n, d))
    return x, x @ w_true, w_true


def test_dfw_trace_converges_and_recovers():
    x, y, w_true = _mtls_problem(jax.random.PRNGKey(0))
    task = tasks.MultiTaskLeastSquares(d=40, m=30)
    res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=80,
              key=jax.random.PRNGKey(1), schedule="const:2", step_size="linesearch")
    w = low_rank.materialize(res.iterate)
    rel = float(jnp.linalg.norm(w - w_true) / jnp.linalg.norm(w_true))
    assert res.history["loss"][-1] < 0.05 * res.history["loss"][0]
    assert rel < 0.2
    # iterate feasibility: ||W||_* <= mu (+ float slack)
    assert float(trace_norm(w)) <= 1.0 + 1e-3
    # factored upper bound dominates the true trace norm
    assert float(low_rank.trace_norm_upper_bound(res.iterate)) >= float(trace_norm(w)) - 1e-4


def test_sublinear_rate_envelope():
    """F(W^t)-F* <= 2C(1+delta)/(t+2): check an O(1/t) envelope empirically."""
    x, y, _ = _mtls_problem(jax.random.PRNGKey(2))
    task = tasks.MultiTaskLeastSquares(d=40, m=30)
    res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=60,
              key=jax.random.PRNGKey(3), schedule="const:2", step_size="linesearch")
    losses = np.array(res.history["loss"])
    fstar = 0.0  # realizable problem
    # envelope from t=5 using the observed constant at t=5
    c = (losses[5] - fstar) * (5 + 2)
    for t in range(10, 60, 10):
        assert losses[t] - fstar <= 2.0 * c / (t + 2), t


def test_more_power_iters_helps_per_epoch():
    x, y, _ = _mtls_problem(jax.random.PRNGKey(4))
    task = tasks.MultiTaskLeastSquares(d=40, m=30)
    out = {}
    for sched in ("const:1", "const:2", "const:8"):
        res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=25,
                  key=jax.random.PRNGKey(5), schedule=sched, step_size="linesearch")
        out[sched] = res.history["loss"][-1]
    assert out["const:8"] <= out["const:1"] * 1.05


def test_naive_dfw_is_per_epoch_oracle():
    """NAIVE-DFW (exact LMO) should be at least as good per epoch (paper §5)."""
    x, y, _ = _mtls_problem(jax.random.PRNGKey(6))
    task = tasks.MultiTaskLeastSquares(d=40, m=30)

    res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=30,
              key=jax.random.PRNGKey(7), schedule="const:1", step_size="linesearch")

    st = task.init_state(x, y)
    it = low_rank.init(30, 40, 30)
    ep = jax.jit(baselines.make_naive_epoch_step(task, 1.0, step_size="linesearch"))
    naive_losses = []
    for t in range(30):
        st, it, aux = ep(st, it, jnp.float32(t), None)
        naive_losses.append(float(aux.loss))
    assert naive_losses[-1] <= res.history["loss"][-1] * 1.10


def test_sva_converges_worse_than_dfw_trace():
    """SVA is biased; on multi-worker-style splits it plateaus above DFW-TRACE
    (paper Fig. 1-2). Emulate 8 workers by comparing against the local-SVD
    epoch on a thin shard."""
    x, y, _ = _mtls_problem(jax.random.PRNGKey(8), n=1600, d=60, m=50)
    task = tasks.MultiTaskLeastSquares(d=60, m=50)

    dfw = fit(task, task.init_state(x, y), mu=1.0, num_epochs=40,
              key=jax.random.PRNGKey(9), schedule="const:2", step_size="linesearch")

    # SVA with a single worker == exact LMO; to expose the bias we give SVA
    # only 1/8 of the data (a worker's-eye view of the direction).
    st_local = task.init_state(x[:200], y[:200])
    it = low_rank.init(40, 60, 50)
    sva_local = baselines.make_sva_epoch_step(task, 1.0, step_size="linesearch")
    losses = []
    for t in range(40):
        # direction from the shard
        _, _, aux_dir = jax.jit(sva_local)(st_local, it, jnp.float32(t), None)
        st_local, it, aux = jax.jit(sva_local)(st_local, it, jnp.float32(t), None)
        losses.append(float(aux.loss))
    # relative progress on its own shard is fine, but the duality-gap estimate
    # of DFW-TRACE on full data should beat the shard-biased run's final loss
    assert dfw.history["loss"][-1] < dfw.history["loss"][0] * 0.05


def test_logistic_task_converges():
    key = jax.random.PRNGKey(10)
    n, d, m = 1200, 30, 20
    kx, kw = jax.random.split(key)
    w_true = jax.random.normal(kw, (d, m))
    w_true = 5.0 * w_true / jnp.linalg.norm(w_true, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    yv = jnp.argmax(x @ w_true, axis=1)
    task = tasks.MultinomialLogistic(d=d, m=m)
    res = fit(task, task.init_state(x, yv), mu=8.0, num_epochs=80,
              key=jax.random.PRNGKey(11), schedule="const:2", step_size="default")
    assert res.history["loss"][-1] < 0.75 * res.history["loss"][0]
    # error metric decreases
    errs = task.errors(res.state, top_k=1)
    assert float(errs) / n < 0.5


def test_duality_gap_upper_bounds_suboptimality():
    x, y, _ = _mtls_problem(jax.random.PRNGKey(12))
    task = tasks.MultiTaskLeastSquares(d=40, m=30)
    res = fit(task, task.init_state(x, y), mu=1.0, num_epochs=50,
              key=jax.random.PRNGKey(13), schedule="const:8", step_size="linesearch")
    f_best = min(res.history["loss"])
    for t in range(5, 50, 5):
        # gap_t >= F(W^t) - F* >= F(W^t) - f_best  (gap uses power-method
        # sigma (underestimate), allow small slack)
        assert res.history["gap"][t] >= (res.history["loss"][t] - f_best) * 0.9 - 1e-4


def test_dense_and_factored_mtls_agree():
    x, y, _ = _mtls_problem(jax.random.PRNGKey(14))
    t1 = tasks.MultiTaskLeastSquares(d=40, m=30)
    t2 = tasks.MultiTaskLeastSquaresDense(d=40, m=30)
    s1, s2 = t1.init_state(x, y), t2.init_state(x, y)
    v = jax.random.normal(jax.random.PRNGKey(15), (30,))
    np.testing.assert_allclose(t1.matvec(s1, v), t2.matvec(s2, v), rtol=2e-4, atol=2e-3)
    u, vv = jax.random.normal(jax.random.PRNGKey(16), (40,)), v
    u = u / jnp.linalg.norm(u)
    vv = vv / jnp.linalg.norm(vv)
    s1b = t1.update(s1, u, vv, 0.5, 1.0)
    s2b = t2.update(s2, u, vv, 0.5, 1.0)
    w = jax.random.normal(jax.random.PRNGKey(17), (30,))
    np.testing.assert_allclose(t1.matvec(s1b, w), t2.matvec(s2b, w), rtol=2e-4, atol=2e-3)
