"""Distributed power method: accuracy, two-sided sign property, K(t) regimes."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import power_method, sphere_vector, top_singular_pair


@pytest.mark.parametrize("d,m", [(30, 20), (64, 64), (17, 51)])
def test_converges_to_top_pair(d, m):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (d, m))
    u, s, vt = np.linalg.svd(np.asarray(a), full_matrices=False)
    # iteration budget must cover the worst spectral gap across the
    # parametrized shapes: (17, 51) has s2/s1 ~ 0.983, so ~100 iterations
    # only contract the off-axis mass to ~0.18 — 300 converge fully
    res = top_singular_pair(a, jax.random.PRNGKey(1), num_iters=300)
    assert res.sigma == pytest.approx(s[0], rel=1e-4)
    # direction match up to sign (sign fixed by two-sided iteration: u^T A v >= 0)
    assert abs(float(jnp.dot(res.u, u[:, 0]))) > 0.999
    assert abs(float(jnp.dot(res.v, vt[0]))) > 0.999
    assert float(res.u @ np.asarray(a) @ res.v) >= 0.0


def test_sigma_underestimates_monotone():
    """||A^T u_K|| is nondecreasing in K and bounded by sigma1."""
    a = jax.random.normal(jax.random.PRNGKey(3), (40, 30))
    s1 = float(jnp.linalg.svd(a, compute_uv=False)[0])
    prev = 0.0
    for k in [1, 2, 4, 8, 16]:
        res = top_singular_pair(a, jax.random.PRNGKey(7), num_iters=k)
        sig = float(res.sigma)
        assert sig <= s1 * (1 + 1e-5)
        assert sig >= prev - 1e-5
        prev = sig


def test_kuczynski_expected_error_bound():
    """Thm (Kuczyński & Woźniakowski): E|sigma_est-s1|/s1 <= 0.871 ln(m)/(K-1)
    for the eigenvalue estimate of A^T A. Monte-Carlo over random starts."""
    key = jax.random.PRNGKey(11)
    a = jax.random.normal(key, (50, 32))
    s1sq = float(jnp.linalg.svd(a, compute_uv=False)[0]) ** 2
    m = 32
    for K in (3, 6, 12):
        errs = []
        for trial in range(64):
            res = top_singular_pair(a, jax.random.fold_in(key, 1000 + trial * 13 + K), num_iters=K)
            errs.append(abs(float(res.sigma) ** 2 - s1sq) / s1sq)
        bound = 0.871 * np.log(m) / (K - 1)
        assert np.mean(errs) <= bound, (K, np.mean(errs), bound)


def test_sphere_vector_unit_norm():
    for i in range(5):
        v = sphere_vector(jax.random.PRNGKey(i), 33)
        assert float(jnp.linalg.norm(v)) == pytest.approx(1.0, abs=1e-5)


def test_worker_weight_zero_removes_contribution():
    """Straggler masking: weight=0 must reproduce the masked-out result."""
    a = jax.random.normal(jax.random.PRNGKey(0), (20, 10))
    res_w = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u,
        sphere_vector(jax.random.PRNGKey(1), 10), 50,
        worker_weight=jnp.float32(0.5),  # scale-invariant: same direction
    )
    res = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u,
        sphere_vector(jax.random.PRNGKey(1), 10), 50,
    )
    np.testing.assert_allclose(res_w.u, res.u, atol=1e-5)
    assert float(res_w.sigma) == pytest.approx(0.5 * float(res.sigma), rel=1e-5)


# ---------------------------------------------------------------------------
# Perf fix regression: sigma is carried out of the loop, not recomputed
# ---------------------------------------------------------------------------


def _reference_power_iterations(matvec, rmatvec, v0, num_iters):
    """The pre-fix implementation (2K+1 aggregation rounds): loop carries
    (u, v) only and sigma is recomputed with an extra rmatvec afterwards.
    Kept verbatim as the trajectory oracle for the carried-sigma version."""
    def body(_, carry):
        _, v = carry
        u = matvec(v)
        u = u / (jnp.linalg.norm(u) + 1e-30)
        vv = rmatvec(u)
        v = vv / (jnp.linalg.norm(vv) + 1e-30)
        return (u, v)

    u0 = jnp.zeros_like(matvec(v0))
    u, v = jax.lax.fori_loop(0, num_iters, body, (u0, v0))
    sigma = jnp.linalg.norm(rmatvec(u))
    return power_method.PowerResult(u=u, v=v, sigma=sigma)


@pytest.mark.parametrize("k", [1, 2, 5])
def test_carried_sigma_trajectory_unchanged(k):
    """The 2K-round version must produce the identical (u, v, sigma): the
    last loop iteration's aggregated rmatvec IS the old post-loop recompute."""
    a = jax.random.normal(jax.random.PRNGKey(42), (40, 30))
    v0 = sphere_vector(jax.random.PRNGKey(43), 30)
    got = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u, v0, k
    )
    want = _reference_power_iterations(lambda v: a @ v, lambda u: a.T @ u, v0, k)
    assert np.array_equal(np.asarray(got.u), np.asarray(want.u))
    assert np.array_equal(np.asarray(got.v), np.asarray(want.v))
    assert np.array_equal(np.asarray(got.sigma), np.asarray(want.sigma))


def test_collective_rounds_per_epoch_is_2k():
    """An epoch's power method costs exactly 2K collective rounds (was 2K+1
    before the sigma carry). The bound itself lives with the code that owns
    it — ``power_method.collective_rounds_contract(K)`` — and this test (like
    ``tools/repro_contracts.py``) just checks that declaration against the
    compiled HLO of a shard_map'd power_iterations on 8 fake devices."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map_compat
        from repro.core import power_method

        # Row-shard an explicit (n, m) matrix: each worker holds a (n/8, m)
        # summand A_j, so the implicit operator A = sum_j A_j is (n/8, m).
        K, n, m = 3, 512, 48
        mesh = jax.make_mesh((8,), ("data",))

        def run(a, v0):
            return power_method.power_iterations(
                lambda v: a @ v, lambda u: a.T @ u, v0, K, axis_name="data")

        wrapped = shard_map_compat(
            run, mesh, in_specs=(P("data"), P()),
            out_specs=power_method.PowerResult(u=P(), v=P(), sigma=P()))
        a = jax.ShapeDtypeStruct((n, m), jnp.float32)
        v0 = jax.ShapeDtypeStruct((m,), jnp.float32)
        contract = power_method.collective_rounds_contract(K)
        analysis = contract.check_hlo(wrapped, a, v0)
        print("collective rounds:", analysis["collective_count"])
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    assert "collective rounds" in out.stdout
