"""Block Frank-Wolfe solver tier (``solver="block:k"``).

Covers the shared solver-spec grammar (the single validation point for
``frank_wolfe.fit`` / ``fit_serial`` / ``DFWConfig``), the block power
primitives (Cholesky-QR orthonormalization, rank-k LMO recovery), the
rank-k iterate update, ``block:1`` == ``rank1`` trajectory equivalence
(serial + 8-way), the spectral-gap-adaptive iteration budget, warm-start
vs cold-start convergence, engine dispatch pins with the block solver,
and checkpoint format v2 (probe-carrying payloads resume bit-exactly;
v1 payloads restore with a cold probe and still converge).
"""
import dataclasses
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, frank_wolfe, low_rank, power_method, tasks
from repro.launch import dfw

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def _mtls(key, n=400, d=24, m=18, rank=None):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (d, m))
    if rank is not None:
        u, s, vt = jnp.linalg.svd(w, full_matrices=False)
        w = (u[:, :rank] * s[:rank]) @ vt[:rank]
    w = w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    return x, x @ w


# ---------------------------------------------------------------------------
# Solver-spec grammar: one shared validation point
# ---------------------------------------------------------------------------


def test_parse_solver_grammar():
    s = frank_wolfe.parse_solver("rank1")
    assert s == frank_wolfe.SolverSpec("rank1", 1, False, False)
    s = frank_wolfe.parse_solver("block:4")
    assert (s.kind, s.k, s.adaptive, s.cold) == ("block", 4, False, False)
    s = frank_wolfe.parse_solver("block:2:adapt")
    assert s.adaptive and not s.cold
    s = frank_wolfe.parse_solver("block:2:cold:adapt")
    assert s.adaptive and s.cold
    # an already-parsed spec passes through
    assert frank_wolfe.parse_solver(s) is s


@pytest.mark.parametrize(
    "bad",
    ["block:0", "block:-3", "block:", "block", "block:x", "block:2:warm",
     "svd", ""],
)
def test_parse_solver_rejects_malformed(bad):
    with pytest.raises(ValueError):
        frank_wolfe.parse_solver(bad)


def test_parse_solver_rejects_non_string():
    with pytest.raises(ValueError, match="string"):
        frank_wolfe.parse_solver(4)


def test_all_entry_points_share_validation(tmp_path):
    """frank_wolfe.fit, fit_serial, and the sharded driver all reject a
    malformed spec with the same parse error — no driver-specific grammar."""
    x, y = _mtls(jax.random.PRNGKey(0), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    state = task.init_state(x, y)
    with pytest.raises(ValueError, match="block width"):
        frank_wolfe.fit(task, state, mu=1.0, num_epochs=2,
                        key=jax.random.PRNGKey(1), solver="block:0")
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=2, solver="block:-3",
                        verify_kernels=False)
    with pytest.raises(ValueError, match="block width"):
        dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=2, solver="block:",
                        verify_kernels=False)
    with pytest.raises(ValueError, match="needs a width"):
        dfw.fit(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1), num_workers=1)


def test_block_width_exceeding_dims_rejected():
    x, y = _mtls(jax.random.PRNGKey(0), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    state = task.init_state(x, y)
    with pytest.raises(ValueError, match="exceeds"):
        frank_wolfe.fit(task, state, mu=1.0, num_epochs=2,
                        key=jax.random.PRNGKey(1), solver="block:19")


def test_init_probe_shapes():
    assert frank_wolfe.init_probe("rank1", 10) == ()
    p = frank_wolfe.init_probe("block:3", 10)
    assert p.shape == (10, 3)
    np.testing.assert_allclose(np.asarray(p.T @ p), np.eye(3), atol=1e-5)


# ---------------------------------------------------------------------------
# Block power primitives
# ---------------------------------------------------------------------------


def test_orthonormalize_block():
    b = jax.random.normal(jax.random.PRNGKey(0), (50, 6))
    q = power_method.orthonormalize_block(b)
    np.testing.assert_allclose(np.asarray(q.T @ q), np.eye(6), atol=1e-4)
    # span is preserved: projection of b onto span(q) equals b
    np.testing.assert_allclose(
        np.asarray(q @ (q.T @ b)), np.asarray(b), atol=1e-3
    )
    # all-zero block maps to all-zero block (jitter keeps cholesky defined)
    z = power_method.orthonormalize_block(jnp.zeros((50, 6)))
    assert np.all(np.isfinite(np.asarray(z)))


def test_block_power_recovers_top_k():
    # Controlled spectrum: well-separated top-k so K iterations provably
    # converge (a raw Gaussian can have arbitrarily small sigma_k gaps).
    key = jax.random.PRNGKey(1)
    qu, _ = jnp.linalg.qr(jax.random.normal(key, (40, 30)))
    qv, _ = jnp.linalg.qr(jax.random.normal(jax.random.fold_in(key, 1), (30, 30)))
    spec = jnp.concatenate([jnp.asarray([8.0, 6.0, 4.0, 2.5]),
                            jnp.full((26,), 0.5)])
    a = (qu * spec) @ qv.T
    k = 4
    v0 = frank_wolfe.init_probe(f"block:{k}", 30)
    res, cs = power_method.block_power_iterations(
        lambda v: a @ v, lambda u: a.T @ u, v0, 40
    )
    assert cs == ()
    true_s = np.linalg.svd(np.asarray(a), compute_uv=False)[:k]
    np.testing.assert_allclose(
        np.sort(np.asarray(res.sigma))[::-1], true_s, rtol=1e-3
    )
    # u/v columns pair as atoms: u_j^T A v_j == sigma_j
    pairs = np.asarray(jnp.einsum("dk,dm,mk->k", res.u, a, res.v))
    np.testing.assert_allclose(pairs, np.asarray(res.sigma), rtol=1e-3)
    # the probe is orthonormal — a valid warm start
    np.testing.assert_allclose(
        np.asarray(res.probe.T @ res.probe), np.eye(k), atol=1e-4
    )
    assert int(res.iters) == 40


def test_block_collective_rounds_contract_fields():
    c = power_method.block_collective_rounds_contract(3, 4)
    assert c.collective_counts == {"all-reduce": 6.0}
    assert "k=4" in c.name


def test_fw_update_block_matches_dense_recurrence():
    key = jax.random.PRNGKey(2)
    d, m, k, mu = 12, 9, 3, 2.0
    it = low_rank.init(10, d, m)
    # seed with one rank-1 step so alpha-folding is exercised
    u1 = jax.random.normal(jax.random.fold_in(key, 0), (d,))
    v1 = jax.random.normal(jax.random.fold_in(key, 1), (m,))
    u1, v1 = u1 / jnp.linalg.norm(u1), v1 / jnp.linalg.norm(v1)
    it = low_rank.fw_update(it, u1, v1, jnp.float32(0.7), mu)
    ub = jax.random.normal(jax.random.fold_in(key, 2), (d, k))
    ub = ub / jnp.linalg.norm(ub, axis=0)
    vb = jax.random.normal(jax.random.fold_in(key, 3), (m, k))
    vb = vb / jnp.linalg.norm(vb, axis=0)
    c = jnp.asarray([0.5, 0.3, 0.2])
    gamma = jnp.float32(0.4)
    w_before = low_rank.materialize(it)
    it2 = low_rank.fw_update_block(it, ub, vb, c, gamma, mu)
    s_block = -mu * jnp.einsum("k,dk,mk->dm", c, ub, vb)
    expect = (1.0 - gamma) * w_before + gamma * s_block
    np.testing.assert_allclose(
        np.asarray(low_rank.materialize(it2)), np.asarray(expect), atol=1e-5
    )
    assert int(it2.count) == int(it.count) + k
    # gamma == 1 annihilates the old iterate, exactly like fw_update
    it3 = low_rank.fw_update_block(it, ub, vb, c, jnp.float32(1.0), mu)
    np.testing.assert_allclose(
        np.asarray(low_rank.materialize(it3)), np.asarray(s_block), atol=1e-5
    )


# ---------------------------------------------------------------------------
# Solver behavior: equivalence, adaptivity, warm start
# ---------------------------------------------------------------------------


def test_block1_cold_matches_rank1_serial():
    """block:1:cold and rank1 compute the same top singular atom each epoch
    up to LMO convergence (different v0 draws, same fixed point up to sign —
    the atom u v^T is sign-invariant), so the trajectories coincide to the
    (sigma_2/sigma_1)^K power-iteration error, not bit-exactly."""
    x, y = _mtls(jax.random.PRNGKey(4))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    kwargs = dict(mu=1.0, num_epochs=10, key=jax.random.PRNGKey(1),
                  schedule="const:25", step_size="linesearch")
    r1 = frank_wolfe.fit(task, task.init_state(x, y), **kwargs)
    rb = frank_wolfe.fit(task, task.init_state(x, y), solver="block:1:cold",
                         **kwargs)
    # Epoch 0 is pre-update: identical state, so identical loss exactly.
    assert rb.history["loss"][0] == r1.history["loss"][0]
    np.testing.assert_allclose(rb.history["loss"], r1.history["loss"],
                               rtol=2e-2)
    np.testing.assert_allclose(rb.history["gap"], r1.history["gap"],
                               rtol=5e-2, atol=1e-4)
    assert rb.epochs_run == r1.epochs_run


def test_adaptive_stops_power_iterations_early():
    """The spectral-gap-adaptive budget executes fewer iterations once the
    warm-started probe is converged; the history-visible trajectory is
    intact and per-epoch piters (captured via the segment callback's aux
    rows) never exceeds K and drops below it on later epochs."""
    x, y = _mtls(jax.random.PRNGKey(5), rank=3)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    K = 12
    piters = []
    res = frank_wolfe.fit(
        task, task.init_state(x, y), mu=1.0, num_epochs=12,
        key=jax.random.PRNGKey(1), schedule=f"const:{K}",
        step_size="linesearch", solver="block:3:adapt",
        callback=lambda t, aux: piters.extend(np.asarray(aux.piters)),
    )
    piters = [p for p in piters if not np.isnan(p)]
    assert len(piters) == res.epochs_run
    assert max(piters) <= K
    assert min(piters[1:]) < K, piters
    assert res.history["gap"][-1] < res.history["gap"][0]


def test_warm_start_beats_cold_start():
    """Carrying the converged right block between epochs reaches a lower
    duality gap than re-randomizing it every epoch, at the same per-epoch
    iteration budget — the reason the probe leaf exists."""
    x, y = _mtls(jax.random.PRNGKey(6), n=600, d=32, m=24, rank=6)
    task = tasks.MultiTaskLeastSquares(d=32, m=24)
    kwargs = dict(mu=1.0, num_epochs=15, key=jax.random.PRNGKey(1),
                  schedule="const:2", step_size="linesearch")
    warm = frank_wolfe.fit(task, task.init_state(x, y), solver="block:6",
                           **kwargs)
    cold = frank_wolfe.fit(task, task.init_state(x, y), solver="block:6:cold",
                           **kwargs)
    assert warm.history["gap"][-1] < cold.history["gap"][-1]


def test_block_beats_rank1_epochs_to_gap():
    """The tentpole claim at test scale: on a low-rank MTLS problem the
    block solver reaches a fixed duality gap in >= 5x fewer epochs than
    rank1 (the benchmark suite pins this at Table-1 scale)."""
    x, y = _mtls(jax.random.PRNGKey(7), n=600, d=32, m=24, rank=6)
    task = tasks.MultiTaskLeastSquares(d=32, m=24)
    kwargs = dict(mu=1.0, num_epochs=60, key=jax.random.PRNGKey(1),
                  schedule="const:2", step_size="linesearch")
    r1 = frank_wolfe.fit(task, task.init_state(x, y), **kwargs)
    rb = frank_wolfe.fit(task, task.init_state(x, y), solver="block:6",
                         **kwargs)
    target = r1.history["gap"][0] * 0.05

    def epochs_to(hist):
        for i, g in enumerate(hist):
            if g <= target:
                return i + 1
        return None

    e1, eb = epochs_to(r1.history["gap"]), epochs_to(rb.history["gap"])
    assert eb is not None, "block solver never reached the target gap"
    assert e1 is None or e1 >= 5 * eb, (e1, eb)


def test_engine_dispatch_pins_hold_with_block_solver():
    """A const:K block run is still one scan dispatch + the final loss eval,
    device-resident under the transfer guard — the block tier changes the
    epoch math, not the execution discipline."""
    x, y = _mtls(jax.random.PRNGKey(8))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    c = engine.dispatch_contract()
    with c.guard():
        res = frank_wolfe.fit(
            task, task.init_state(x, y), mu=1.0, num_epochs=20,
            key=jax.random.PRNGKey(1), step_size="linesearch",
            solver="block:4:adapt",
        )
    c.check_stats(res.stats)
    assert int(res.iterate.count) == 20 * 4


def test_max_rank_capacity_scales_with_block_width():
    x, y = _mtls(jax.random.PRNGKey(9), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    state = task.init_state(x, y)
    with pytest.raises(ValueError, match="overflow"):
        frank_wolfe.fit(task, state, mu=1.0, num_epochs=4, max_rank=4,
                        key=jax.random.PRNGKey(1), solver="block:3")
    res = frank_wolfe.fit(task, state, mu=1.0, num_epochs=4, max_rank=12,
                          key=jax.random.PRNGKey(1), solver="block:3")
    assert res.iterate.s.shape[0] == 12


def test_block_telemetry_through_registry():
    """dfw.block.k / dfw.block.power_iters ride the existing obs registry —
    no ad-hoc counters, no extra syncs."""
    from repro.obs import Telemetry

    x, y = _mtls(jax.random.PRNGKey(10), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    tel = Telemetry()
    frank_wolfe.fit(task, task.init_state(x, y), mu=1.0, num_epochs=6,
                    key=jax.random.PRNGKey(1), solver="block:3",
                    telemetry=tel)
    snap = tel.registry.snapshot()
    assert snap["gauges"]["dfw.block.k"] == 3
    assert snap["counters"]["dfw.block.power_iters"] == 6 * 2  # const:2


# ---------------------------------------------------------------------------
# 8-way SPMD equivalence (subprocess: device count locks at first jax init)
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_block_sharded_equals_serial_and_block1_equals_rank1_8way():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import tasks, frank_wolfe, low_rank, engine

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        mesh = jax.make_mesh((8,), ("data",))
        ss = tasks.MTLSState(x=P("data"), y=P("data"), r=P("data"))

        def fit(**kw):
            return frank_wolfe.fit(task, task.init_state(X, Y), mu=1.0,
                                   num_epochs=8, key=jax.random.PRNGKey(1),
                                   step_size="linesearch", **kw)

        # --- block:4 sharded == serial (same reducer, same seed) ---
        serial = fit(schedule="const:3", solver="block:4")
        wrap = engine.shard_map_segment_wrapper(
            mesh, "data", ss,
            probe_example=frank_wolfe.init_probe("block:4", m))
        dist = fit(schedule="const:3", solver="block:4", axis_name="data",
                   segment_wrapper=wrap)
        np.testing.assert_allclose(serial.history["loss"],
                                   dist.history["loss"], rtol=1e-4)
        W1 = low_rank.materialize(serial.iterate)
        W2 = low_rank.materialize(dist.iterate)
        assert float(jnp.max(jnp.abs(W1 - W2))) < 1e-4
        print("block shard_map == serial OK")

        # --- block:1:cold == rank1, 8-way (converged LMO) ---
        wrap1 = engine.shard_map_segment_wrapper(
            mesh, "data", ss,
            probe_example=frank_wolfe.init_probe("block:1", m))
        wrap_r = engine.shard_map_segment_wrapper(mesh, "data", ss)
        r1 = fit(schedule="const:25", axis_name="data", segment_wrapper=wrap_r)
        b1 = fit(schedule="const:25", solver="block:1:cold",
                 axis_name="data", segment_wrapper=wrap1)
        assert b1.history["loss"][0] == r1.history["loss"][0]
        np.testing.assert_allclose(b1.history["loss"], r1.history["loss"],
                                   rtol=2e-2)
        np.testing.assert_allclose(b1.history["gap"], r1.history["gap"],
                                   rtol=5e-2, atol=1e-4)
        print("block:1 == rank1 8-way OK")
    """)
    assert out.count("OK") == 2


@pytest.mark.slow
def test_block_collective_rounds_hlo_8way():
    out = _run("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map_compat
        from repro.core import power_method

        K, k, n, m = 3, 4, 512, 48
        mesh = jax.make_mesh((8,), ("data",))

        def run(a, v0):
            return power_method.block_power_iterations(
                lambda v: a @ v, lambda u: a.T @ u, v0, K, axis_name="data")

        bspec = power_method.BlockPowerResult(
            u=P(), v=P(), sigma=P(), probe=P(), iters=P())
        wrapped = shard_map_compat(run, mesh, in_specs=(P("data"), P()),
                                   out_specs=(bspec, ()))
        c = power_method.block_collective_rounds_contract(K, k)
        c.check_hlo(wrapped,
                    jax.ShapeDtypeStruct((n, m), jnp.float32),
                    jax.ShapeDtypeStruct((m, k), jnp.float32))
        print("block 2K rounds OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_block_int8_topk_reducers_compose_8way():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks, frank_wolfe
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        for comm in ("int8", "topk:64"):
            cfg = dfw.DFWConfig(mu=1.0, num_epochs=10, schedule="const:2",
                                step_size="linesearch", comm=comm,
                                solver="block:4", verify_kernels=False)
            res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                          num_workers=8)
            assert res.epochs_run == 10
            assert res.history["gap"][-1] < res.history["gap"][0], comm
            print(comm, "block OK", res.history["gap"][-1])
    """)
    assert out.count("OK") == 2


# ---------------------------------------------------------------------------
# Checkpoint format v2: probe-carrying payloads
# ---------------------------------------------------------------------------


def test_block_resume_bitexact_v2_probe(tmp_path):
    """Same-mesh resume of a block run restores the warm-start probe from
    the v2 payload and reproduces the uninterrupted trajectory bit for
    bit — the probe is part of the carry, not re-derived."""
    x, y = _mtls(jax.random.PRNGKey(11))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck_block")
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=20, schedule="const:2", step_size="linesearch",
        solver="block:3", block_epochs=5, checkpoint_dir=ckdir,
        checkpoint_keep=None, verify_kernels=False,
    )
    full = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    rcfg = dataclasses.replace(
        cfg, checkpoint_dir=None, resume_from=ckdir, resume_step=10
    )
    res = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    assert res.epochs_run == full.epochs_run == 20
    for k in ("loss", "gap", "sigma", "gamma", "k"):
        assert res.history[k] == full.history[k], k
    assert res.final_loss == full.final_loss
    for name, a, b in zip(res.iterate._fields, res.iterate, full.iterate):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=name)


def test_v2_checkpoint_stamps_solver_and_probe(tmp_path):
    from repro.checkpoint import dfw as ckpt

    x, y = _mtls(jax.random.PRNGKey(12), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = str(tmp_path / "ck")
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=6, solver="block:3",
                        block_epochs=3, checkpoint_dir=ckdir,
                        verify_kernels=False)
    dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    _, extra = ckpt.read_run_extra(ckdir)
    assert extra["payload_format"] == ckpt.PAYLOAD_FORMAT
    assert extra["solver"] == "block:3"
    state = task.init_state(x, y)
    snap = ckpt.restore_run(ckdir, state_like=state)
    assert np.asarray(snap.carry.probe).shape == (18, 3)


def test_v1_payload_restores_with_cold_probe_and_converges(tmp_path):
    """A rank1 checkpoint rewritten to payload_format=1 with no solver key
    (byte-identical to what the pre-block build wrote) restores into a
    block-solver run: the probe falls back to the deterministic cold start
    and the resumed run still converges."""
    x, y = _mtls(jax.random.PRNGKey(13))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = tmp_path / "ck_v1"
    cfg = dfw.DFWConfig(
        mu=1.0, num_epochs=20, schedule="const:2", step_size="linesearch",
        block_epochs=5, checkpoint_dir=str(ckdir), checkpoint_keep=None,
        verify_kernels=False,
    )
    dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    # Rewrite the step-10 manifest to the v1 schema: format 1, no solver.
    mpath = ckdir / "step_00000010" / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["extra"]["payload_format"] = 1
    manifest["extra"].pop("solver", None)
    mpath.write_text(json.dumps(manifest))

    rcfg = dataclasses.replace(
        cfg, checkpoint_dir=None, resume_from=str(ckdir), resume_step=10,
        solver="block:3",
    )
    res = dfw.fit_serial(task, x, y, cfg=rcfg, key=jax.random.PRNGKey(1))
    assert res.epochs_run == 20
    assert len(res.history["gap"]) == 20
    assert res.history["gap"][-1] < res.history["gap"][9]


def test_unknown_payload_format_rejected(tmp_path):
    from repro.checkpoint import dfw as ckpt

    x, y = _mtls(jax.random.PRNGKey(14), n=64)
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    ckdir = tmp_path / "ck"
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=4, checkpoint_dir=str(ckdir),
                        verify_kernels=False)
    dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    step_dirs = sorted(ckdir.glob("step_*"))
    mpath = step_dirs[-1] / "manifest.json"
    manifest = json.loads(mpath.read_text())
    manifest["extra"]["payload_format"] = 99
    mpath.write_text(json.dumps(manifest))
    with pytest.raises(ValueError, match="payload format"):
        ckpt.restore_run(str(ckdir), state_like=task.init_state(x, y))
