"""Factor-form serving engine (repro/serve).

Covers the three serving contracts: scoring correctness against the dense
materialized oracle, hot-swap semantics (zero recompiles inside a rank
bucket, in-flight batches complete against the model they were dispatched
with, no stale scores after a swap), and the no-implicit-transfer
discipline — dispatch and swap run under ``transfer_guard`` with the
engine's own compilation counter as the regression pin, mirroring
tests/test_engine.py's stats pins. Plus the checkpoint restore path
(``read_iterate_packed`` / ``from_checkpoint``) and the micro-batcher.
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import serve
from repro.checkpoint import CheckpointStore, RunCheckpointer, read_iterate_packed
from repro.core import low_rank
from repro.core.frank_wolfe import EpochCarry

D, M = 40, 28


def _iterate(k, d=D, m=M, max_rank=12, seed=0, alpha=0.8):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    return low_rank.FactoredIterate(
        u=jnp.zeros((max_rank, d)).at[:k].set(jax.random.normal(ks[0], (k, d))),
        s=jnp.zeros((max_rank,)).at[:k].set(jax.random.normal(ks[1], (k,))),
        v=jnp.zeros((max_rank, m)).at[:k].set(jax.random.normal(ks[2], (k, m))),
        alpha=jnp.asarray(alpha, jnp.float32),
        count=jnp.asarray(k, jnp.int32),
    )


def _engine(max_batch=8, rank_block=8, **kw):
    return serve.ServingEngine(
        D, M, serve.ServeConfig(max_batch=max_batch, rank_block=rank_block, **kw)
    )


def _dense(it):
    return np.asarray(low_rank.materialize(it))


def _save_step(ckpt, t, it, d=D, m=M):
    carry = EpochCarry(
        state={"r": np.zeros(3, np.float32)}, iterate=it,
        comm_state=np.zeros(1, np.float32), t=np.asarray(t, np.int32),
        key=jax.random.PRNGKey(0),
    )
    ckpt.save_segment(
        t=t, carry=carry, history={k: [] for k in ("loss", "gap", "sigma", "gamma", "k")},
        masks=None, done=False,
    )
    ckpt.wait()


def _checkpointer(tmpdir, d=D, m=M):
    return RunCheckpointer(
        tmpdir, keep_last=None,
        extra=dict(task="MultiTaskLeastSquares", d=d, m=m, num_workers=1, comm="dense"),
    )


# ---------------------------------------------------------------------------
# Scoring correctness
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("batch", [1, 3, 8])
@pytest.mark.parametrize("live", [0, 1, 5])
def test_score_matches_dense_oracle(batch, live):
    eng = _engine()
    it = _iterate(live)
    eng.load(it)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(7), (batch, D)))
    np.testing.assert_allclose(eng.score(x), x @ _dense(it), rtol=1e-4, atol=1e-5)


def test_single_request_vector_and_transpose():
    it = _iterate(4)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(8), (M,)))
    eng = serve.ServingEngine(D, M, serve.ServeConfig(max_batch=4, transpose=True))
    eng.load(it)
    got = eng.score(x)
    assert got.shape == (1, D)
    np.testing.assert_allclose(got[0], _dense(it) @ x, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Hot-swap: the acceptance pins
# ---------------------------------------------------------------------------


def test_hot_swap_zero_recompiles_no_drops_no_stale_scores():
    """Swap mid-stream inside one rank bucket: the in-flight batch completes
    against the OLD model, post-swap traffic scores the NEW one, and the
    engine compiles exactly once — all without a single implicit
    device->host transfer (scores leave the device only via the handle's
    explicit ``block``). The compile/transfer bounds are the serving layer's
    own declaration (``ServingEngine.contract``), shared with
    ``tools/repro_contracts.py``; ``check_contract`` additionally walks every
    compiled executable's HLO for forbidden d x m materializations."""
    eng = _engine(rank_block=8, verify_kernels=False)
    contract = eng.contract(max_compilations=1)
    it_old, it_new = _iterate(3, seed=1), _iterate(7, seed=2)
    # Host-side packed models: the checkpoint-restore shape of a swap.
    packed_old = low_rank.pack_live(it_old)
    packed_new = low_rank.pack_live(it_new)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(9), (5, D)))

    with contract.guard():
        eng.load(packed_old)
        in_flight = eng.score_async(x)
        model = eng.load(packed_new)  # swap while the batch is in flight
        after = eng.score_async(x)
        old_scores = in_flight.block()  # explicit transfer — allowed
        new_scores = after.block()

    eng.check_contract(contract)  # == 1 AOT build; no d x m in any executable
    assert eng.stats["compilations"] == 1, eng.stats  # same bucket, tight
    assert eng.stats["loads"] == 2 and eng.stats["dispatches"] == 2
    np.testing.assert_allclose(old_scores, x @ _dense(it_old), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(new_scores, x @ _dense(it_new), rtol=1e-4, atol=1e-5)
    # version stamps prove which model served each batch — no stale reads
    assert in_flight.version == 0 and after.version == model.version == 1


def test_bucket_crossing_compiles_once_per_bucket():
    eng = _engine(rank_block=4, verify_kernels=False)
    for live, want_compiles in ((0, 1), (2, 1), (4, 1), (5, 2), (8, 2), (3, 2)):
        eng.load(_iterate(live, seed=live))
        assert eng.stats["compilations"] == want_compiles, (live, eng.stats)
    # buckets stay cached: revisiting either costs nothing
    assert eng.stats["loads"] == 6


def test_rank_bucket_contract():
    assert serve.rank_bucket(0, 8) == 8  # untrained model shares bucket 1
    assert serve.rank_bucket(1, 8) == 8
    assert serve.rank_bucket(8, 8) == 8
    assert serve.rank_bucket(9, 8) == 16
    assert serve.rank_bucket(5, 1) == 5


def test_scorer_never_materializes_dxm():
    """Factor-form serving's core claim, checked on the compiled artifact:
    no executable — across rank buckets, plain and transposed — emits a
    (D, M) or (M, D) tensor. O(t(d+m)) per request, never O(dm)."""
    for transpose in (False, True):
        eng = _engine(rank_block=4, verify_kernels=False, transpose=transpose)
        eng.load(_iterate(3, seed=1))
        eng.load(_iterate(7, seed=2))  # second bucket -> second executable
        eng.check_contract()
        assert len(eng._compiled) == 2  # the walk covered both buckets


# ---------------------------------------------------------------------------
# Checkpoint restore path
# ---------------------------------------------------------------------------


def test_from_checkpoint_scores_and_follows_steps():
    it5, it9 = _iterate(5, seed=3), _iterate(9, seed=4)
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(10), (4, D)))
    with tempfile.TemporaryDirectory() as td:
        ckpt = _checkpointer(td)
        _save_step(ckpt, 5, it5)
        eng = serve.ServingEngine.from_checkpoint(
            td, serve.ServeConfig(max_batch=4, rank_block=12, verify_kernels=False)
        )
        assert (eng.d, eng.m) == (D, M)  # sized from the manifest
        assert eng.model.step == 5 and eng.model.live_rank == 5
        np.testing.assert_allclose(eng.score(x), x @ _dense(it5), rtol=1e-4, atol=1e-5)

        # training writes a newer step; load(dir) follows latest, step= pins
        _save_step(ckpt, 9, it9)
        model = eng.load(td)
        assert model.step == 9 and eng.stats["compilations"] == 1  # same bucket
        np.testing.assert_allclose(eng.score(x), x @ _dense(it9), rtol=1e-4, atol=1e-5)
        model = eng.load(td, step=5)
        assert model.step == 5 and model.version == 2


def test_read_iterate_packed_roundtrips_pack_live():
    it = _iterate(6, seed=5)
    with tempfile.TemporaryDirectory() as td:
        _save_step(_checkpointer(td), 6, it)
        step, packed, extra = read_iterate_packed(td)
        assert step == 6 and extra["d"] == D
        want = low_rank.pack_live(it)
        for k in want:
            np.testing.assert_array_equal(packed[k], want[k])
        # and it re-pads to any capacity bit-exactly
        np.testing.assert_array_equal(
            np.asarray(low_rank.unpack_live(packed, 20).u[:6]), want["u"]
        )


def test_read_iterate_packed_rejects_foreign_checkpoints():
    with tempfile.TemporaryDirectory() as td:
        store = CheckpointStore(td)
        store.save_async(
            1, {"weights": np.ones(3, np.float32)}, extra={"payload_format": 1}
        )
        store.wait()
        with pytest.raises(ValueError, match="no packed iterate"):
            read_iterate_packed(td)
        store.save_async(2, {"x": np.ones(2, np.float32)}, extra={})
        store.wait()
        with pytest.raises(ValueError, match="payload format"):
            read_iterate_packed(td)


def test_engine_rejects_mismatched_checkpoint_dims():
    with tempfile.TemporaryDirectory() as td:
        _save_step(_checkpointer(td), 3, _iterate(3))
        eng = serve.ServingEngine(D + 1, M, serve.ServeConfig(verify_kernels=False))
        with pytest.raises(ValueError, match="serves"):
            eng.load(td)


# ---------------------------------------------------------------------------
# Micro-batcher
# ---------------------------------------------------------------------------


def test_microbatcher_accumulates_and_auto_flushes():
    eng = _engine(verify_kernels=False)
    it = _iterate(4, seed=6)
    eng.load(it)
    w = _dense(it)
    b = serve.MicroBatcher(eng, flush_at=4)
    qs = np.asarray(jax.random.normal(jax.random.PRNGKey(11), (6, D)))
    tickets = [b.submit(q) for q in qs]
    # 6 submits at flush_at=4: one auto-flush, two requests still queued
    assert eng.stats["dispatches"] == 1 and b.pending_count == 2
    assert tickets[3].dispatched and not tickets[4].dispatched
    # result() on a queued ticket flushes the tail rather than deadlocking
    np.testing.assert_allclose(tickets[5].result(), qs[5] @ w, rtol=1e-4, atol=1e-5)
    assert eng.stats["dispatches"] == 2 and b.pending_count == 0
    for i, t in enumerate(tickets):
        np.testing.assert_allclose(t.result(), qs[i] @ w, rtol=1e-4, atol=1e-5)
    assert eng.stats["dispatches"] == 2  # results are cached, not re-scored


def test_microbatcher_stamps_versions_across_swap():
    eng = _engine(verify_kernels=False)
    it0, it1 = _iterate(2, seed=7), _iterate(6, seed=8)
    eng.load(it0)
    b = serve.MicroBatcher(eng, flush_at=8)
    q = np.asarray(jax.random.normal(jax.random.PRNGKey(12), (D,)))
    before = b.submit(q)
    b.flush()  # dispatched against v0
    queued = b.submit(q)  # still queued at swap time
    eng.load(it1)
    b.flush()  # dispatches against v1 — versions bind at dispatch, not submit
    assert before.version == 0 and queued.version == 1
    np.testing.assert_allclose(before.result(), q @ _dense(it0), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(queued.result(), q @ _dense(it1), rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Guardrails
# ---------------------------------------------------------------------------


def test_engine_input_validation():
    eng = _engine(max_batch=4, verify_kernels=False)
    with pytest.raises(RuntimeError, match="no model"):
        eng.score(np.zeros((1, D), np.float32))
    eng.load(_iterate(2))
    with pytest.raises(ValueError, match="max_batch"):
        eng.score(np.zeros((5, D), np.float32))
    with pytest.raises(ValueError, match="scores"):
        eng.score(np.zeros((2, D + 1), np.float32))
    with pytest.raises(ValueError, match="missing"):
        eng.load({"u": np.zeros((1, D))})
    with pytest.raises(TypeError, match="cannot load"):
        eng.load(42)
    with pytest.raises(ValueError, match="max_batch"):
        serve.ServeConfig(max_batch=0)
    b = serve.MicroBatcher(eng)
    with pytest.raises(ValueError, match="one"):
        b.submit(np.zeros((2, D), np.float32))
    with pytest.raises(ValueError, match="flush_at"):
        serve.MicroBatcher(eng, flush_at=9)


def test_verify_factor_kernels_runs_on_first_load_only():
    eng = _engine()  # verify_kernels=True (default)
    eng.load(_iterate(2, seed=9))
    eng.load(_iterate(3, seed=10))  # second load must not re-verify (cheap swap)
    assert eng._verified
    err = serve.verify_factor_kernels(jax.random.PRNGKey(0), d=D, m=M)
    assert err < 1e-4
