"""End-to-end behaviour tests: training loop learns, serving generates,
DFW-TRACE head training on backbone features works (the paper's pipeline)."""
import os
import subprocess
import sys
import tempfile
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import dfw_head
from repro.launch import serve, train
from repro.models import lm


@pytest.mark.slow  # full train-checkpoint-resume convergence loop
def test_train_loop_reduces_loss_and_resumes():
    with tempfile.TemporaryDirectory() as d:
        _, _, hist1 = train.train(
            arch="qwen2_1_5b", steps=40, seq_len=64, global_batch=8,
            ckpt_dir=d, ckpt_every=20, log_every=5, peak_lr=1e-3,
        )
        losses = [v for _, v in hist1]
        assert losses[-1] < losses[0], losses
        # resume from the checkpoint and keep going
        _, _, hist2 = train.train(
            arch="qwen2_1_5b", steps=50, seq_len=64, global_batch=8,
            ckpt_dir=d, ckpt_every=20, log_every=5, peak_lr=1e-3,
        )
        assert hist2[0][0] > 40  # started past the restored step


def test_serve_generates_tokens():
    out = serve.generate(
        arch="rwkv6_7b", batch=2, prompt_len=8, max_new_tokens=8, smoke=True
    )
    assert out.shape == (2, 8)
    cfg = get_config("rwkv6_7b", smoke=True)
    assert out.min() >= 0 and out.max() < cfg.vocab_size


@pytest.mark.slow  # trains -> checkpoints -> serves -> hot-swaps end to end
def test_serve_batched_example_runs():
    """examples/serve_batched.py is the factor-form serving walkthrough; it
    self-asserts (oracle agreement, zero-recompile swap, old/new isolation)
    and must stay runnable — it is the serving quickstart the README points
    at."""
    root = Path(__file__).resolve().parent.parent
    env = {**os.environ, "PYTHONPATH": str(root / "src")}
    res = subprocess.run(
        [sys.executable, str(root / "examples" / "serve_batched.py")],
        capture_output=True, text=True, timeout=900, env=env,
    )
    assert res.returncode == 0, (
        f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    )
    assert "train-and-serve demo OK" in res.stdout
    assert "zero recompiles" in res.stdout


def test_dfw_head_on_backbone_features():
    """The paper's ImageNet pipeline at smoke scale: frozen backbone ->
    features -> trace-norm constrained logistic head via DFW-TRACE."""
    cfg = get_config("qwen2_1_5b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batches = []
    for i in range(2):
        key = jax.random.PRNGKey(10 + i)
        toks = jax.random.randint(key, (2, 64), 0, cfg.vocab_size)
        batches.append({"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)})
    x, y = dfw_head.extract_features(params, batches, cfg)
    assert x.shape == (2 * 2 * 64, cfg.d_model)

    # learnable structure: labels from a planted low-rank head
    w_plant = jax.random.normal(jax.random.PRNGKey(3), (cfg.d_model, 32))
    y_plant = jnp.argmax(x @ w_plant, axis=1)
    res = dfw_head.train_head(x, y_plant, 32, mu=10.0, num_epochs=30)
    assert res.history["loss"][-1] < res.history["loss"][0]
    assert res.head_matrix().shape == (cfg.d_model, 32)
    err5 = dfw_head.top_k_error(res.iterate, x, y_plant, k=5)
    assert err5 < 0.6, err5
