"""Exchange-graph tests: gossip consensus, hierarchical reduce, the spec
surface, and the topology-threaded DFW drivers.

Pins the claims the topology layer makes for itself:

- gossip ``all_reduce`` converges to the flat psum mean at the analytic
  λ₂^R rate, and at consensus every node's gap certificate equals the
  global (flat) one;
- ``hier:<g>`` with the dense reducer reproduces the flat psum *bit-exactly*
  on integer-grid inputs (every partial sum representable in f32);
- the 8-way sharded drivers match the serial driver — standard tolerances
  for ``hier:2`` (same consensus semantics as flat), ≤1% final-loss drift
  for ``ring`` (inexact consensus is part of the contract);
- ``Reducer.reduce`` survives as a once-warning alias of ``exchange``;
- bad specs fail with ``specs.SpecError`` at construction, not at trace.

Multi-device coverage uses the same 8-fake-CPU-device subprocess pattern as
``tests/test_dfw_launch.py`` (device count locks at first jax init).
"""
import os
import subprocess
import sys
import textwrap
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm, specs
from repro.comm import base as comm_base
from repro.comm import topology as topo_mod

SRC = str(Path(__file__).resolve().parent.parent / "src")

KEY = jax.random.PRNGKey(0)


def _run(script: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# Mesh + shard_map harness for exercising a topology's all_reduce directly:
# each of the 8 workers contributes a distinct row of `vals`, and the
# per-node results come back stacked along the worker axis.
_EXCHANGE = """
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import Mesh, PartitionSpec as P
        from repro import comm, compat

        nw = 8
        mesh = Mesh(np.asarray(jax.devices()[:nw]), ("data",))

        def exchange(topo, vals):
            def body(x):
                est, _ = topo.all_reduce(
                    x[0], (), slot="u",
                    key=jax.random.PRNGKey(0), axis_name="data")
                return est[None]
            return compat.shard_map_compat(
                body, mesh, P("data"), P("data"))(vals)
"""


# ---------------------------------------------------------------------------
# Gossip: consensus to the psum mean, per-node certificates
# ---------------------------------------------------------------------------


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_gossip_ring_consensus_converges_to_psum_mean():
    """Each node's estimate/nw approaches the true mean at the λ₂^R rate:
    loose at R=3, inside CONSENSUS_TARGET at the auto-sized default, and
    essentially exact at R=64."""
    out = _run(_EXCHANGE + """
        vals = jax.random.normal(jax.random.PRNGKey(7), (nw, 96))
        true_sum = jnp.sum(vals, axis=0)
        # CONSENSUS_TARGET bounds the *contraction* of the initial per-node
        # disagreement (error <= lam2^R * spread), so normalize by the
        # worst initial deviation from the mean, not by |sum|.
        spread = float(jnp.max(jnp.linalg.norm(
            vals - true_sum[None] / nw, axis=1)))
        for rounds in (3, None, 64):
            topo = comm.make_topology("ring", num_workers=nw, rounds=rounds)
            est = exchange(topo, vals)  # (nw, 96): per-node estimates
            err = float(jnp.max(jnp.linalg.norm(
                est / nw - true_sum[None] / nw, axis=1)))
            print("R", topo.rounds, "contraction", err / spread)
    """)
    lines = dict()
    for ln in out.strip().splitlines():
        _, r, _, e = ln.split()
        lines[int(r)] = float(e)
    rs = sorted(lines)
    assert len(rs) == 3 and rs[-1] == 64
    # monotone improvement, auto-sized R hits the documented target, and
    # long mixing is numerically indistinguishable from the flat psum
    assert lines[rs[0]] > lines[rs[1]] > lines[rs[2]]
    auto = topo_mod.default_gossip_rounds(8, 2)
    assert rs[1] == auto
    assert lines[auto] <= topo_mod.CONSENSUS_TARGET
    assert lines[64] < 1e-5


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_gossip_per_node_gap_equals_global_at_consensus():
    """At consensus the per-node duality gaps coincide with the gap computed
    from the exact psum — the pmax'd certificate is the global certificate."""
    out = _run(_EXCHANGE + """
        # Gap shape: gap(v) = <v, r> + mu * |v| for per-node estimate v of
        # the replicated residual-gradient contraction r (rank-1 LMO).
        r = jax.random.normal(jax.random.PRNGKey(3), (nw, 64))
        true_sum = jnp.sum(r, axis=0)
        mu = 1.0
        topo = comm.make_topology("ring", num_workers=nw, rounds=64)
        est = exchange(topo, r)
        gaps = mu * jnp.linalg.norm(est, axis=1)
        global_gap = mu * jnp.linalg.norm(true_sum)
        print("max_dev", float(jnp.max(jnp.abs(gaps - global_gap))),
              "pmax", float(jnp.max(gaps)), "global", float(global_gap))
    """)
    _, dev, _, pmax, _, glob = out.split()
    assert float(dev) <= 1e-3 * float(glob)
    assert abs(float(pmax) - float(glob)) <= 1e-3 * float(glob)


def test_gossip_serial_is_identity_and_estimate_is_unbiased_scale():
    """axis_name=None: one node is its own consensus (exact identity)."""
    topo = comm.make_topology("ring", num_workers=1)
    x = jax.random.normal(KEY, (33,))
    y, st = topo.all_reduce(x, (), slot="u", key=KEY, axis_name=None)
    np.testing.assert_array_equal(np.asarray(y), np.asarray(x))
    assert st == ()


def test_gossip_rounds_auto_sizing_tracks_lambda2():
    lam2 = topo_mod.gossip_lambda2(8, 2)
    R = topo_mod.default_gossip_rounds(8, 2)
    assert 0.0 < lam2 < 1.0
    assert lam2 ** R <= topo_mod.CONSENSUS_TARGET < lam2 ** (R - 1)
    # offsets +-1, +-2 on 5 nodes touch every other node: complete graph,
    # uniform mixing matrix, consensus in one round
    assert topo_mod.default_gossip_rounds(5, 4) == 1


# ---------------------------------------------------------------------------
# Hier: bit-exact vs flat on integer grids
# ---------------------------------------------------------------------------


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_hier_dense_bit_exact_vs_flat_psum_on_integer_grid():
    """Two-level psum re-associates the sum; on integer-valued f32 inputs
    every partial sum is exactly representable, so hier:2 and hier:4 must
    equal the flat global psum bit for bit."""
    out = _run(_EXCHANGE + """
        vals = jnp.asarray(jax.random.randint(
            jax.random.PRNGKey(11), (nw, 128), -1000, 1000), jnp.float32)
        flat = exchange(comm.make_topology("flat", num_workers=nw), vals)
        for g in (2, 4):
            topo = comm.make_topology(f"hier:{g}", num_workers=nw)
            est = exchange(topo, vals)
            print(f"hier:{g}", "bitexact",
                  bool(np.array_equal(np.asarray(est), np.asarray(flat))))
    """)
    for ln in out.strip().splitlines():
        spec, _, ok = ln.split()
        assert ok == "True", f"{spec} diverged from flat psum on integer grid"


def test_hier_serial_applies_reducer_encoding_at_group_width():
    """Serial hier:g == the bare reducer built for g participants (the wire
    noise the sharded run would see on the inter hop)."""
    topo = comm.make_topology("hier:4", num_workers=1, comm="int8")
    assert isinstance(topo.reducer, comm.Int8Reducer)
    assert topo.reducer.num_workers == 4
    x = jax.random.normal(KEY, (48,))
    y_t, _ = topo.all_reduce(x, (), slot="u", key=KEY, axis_name=None)
    y_r, _ = topo.reducer.exchange(x, (), slot="u", key=KEY, axis_name=None)
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_r))


def test_hop_wire_bytes_split_by_hop_and_compression_lands_on_inter():
    """The per-hop accounting behind the engine counters and the benchmark
    gate: flat is one global hop, hier splits into intra + inter with the
    encoding applied to the inter hop only (so hier:2 + int8 spends an
    order of magnitude fewer inter bytes than flat dense spends globally),
    and gossip is pure neighbor traffic scaling with rounds * degree."""
    d = 256
    flat = comm.make_topology("flat", num_workers=8).hop_wire_bytes(d)
    hier = comm.make_topology("hier:2", num_workers=8).hop_wire_bytes(d)
    assert set(flat) == {"global"} and set(hier) == {"inter", "intra"}
    hier8 = comm.make_topology("hier:2", num_workers=8, comm="int8")
    assert hier8.hop_wire_bytes(d)["inter"] * 3 < flat["global"]
    assert hier8.hop_wire_bytes(d)["intra"] == hier["intra"]
    topo = comm.make_topology("ring", num_workers=8)
    ring = topo.hop_wire_bytes(d)
    assert set(ring) == {"neighbor"}
    assert ring["neighbor"] == topo.rounds * 2 * 4 * d
    # the reducer-compatible total is the sum over hops
    assert hier8.wire_bytes(d, 8) == sum(hier8.hop_wire_bytes(d).values())


# ---------------------------------------------------------------------------
# Sharded drivers == serial (ring within 1%, hier exact-tolerance)
# ---------------------------------------------------------------------------

_PROBLEM = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
"""


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_sharded_hier2_equals_serial_mtls():
    out = _run(_PROBLEM + """
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=8, schedule="const:2",
                            step_size="linesearch", topology="hier:2")
        ser = dfw.fit_serial(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1))
        dist = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                       num_workers=8)
        np.testing.assert_allclose(np.asarray(dist.history["loss"]),
                                   np.asarray(ser.history["loss"]),
                                   rtol=2e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(dist.history["gap"]),
                                   np.asarray(ser.history["gap"]),
                                   rtol=2e-4, atol=1e-4)
        print("final", float(dist.final_loss), float(ser.final_loss))
    """)
    _, dl, sl = out.split()
    assert abs(float(dl) - float(sl)) <= 1e-4 * max(1.0, abs(float(sl)))


@pytest.mark.slow  # subprocess: fresh jax init + 8 fake devices
def test_sharded_ring_within_one_percent_of_serial_mtls():
    """Gossip's inexact consensus may drift per epoch; the contract is the
    final loss (≤1% relative, the acceptance bound) and a per-node-pmax gap
    history that tracks the serial one."""
    out = _run(_PROBLEM + """
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=12, schedule="const:2",
                            step_size="linesearch", topology="ring")
        ser = dfw.fit_serial(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1))
        dist = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                       num_workers=8)
        rel = abs(float(dist.final_loss) - float(ser.final_loss)) / float(
            ser.final_loss)
        gap_rel = float(jnp.max(jnp.abs(
            jnp.asarray(dist.history["gap"]) - jnp.asarray(ser.history["gap"])
        ) / jnp.asarray(ser.history["gap"])))
        print("rel", rel, "gap_rel", gap_rel)
    """)
    _, rel, _, gap_rel = out.split()
    assert float(rel) <= 0.01
    assert float(gap_rel) <= 0.05


# ---------------------------------------------------------------------------
# API surface: exchange alias, spec errors
# ---------------------------------------------------------------------------


def test_reduce_alias_delegates_and_warns_exactly_once(monkeypatch):
    monkeypatch.setattr(comm_base, "_REDUCE_DEPRECATION_WARNED", False)
    r = comm.DenseReducer()
    x = jax.random.normal(KEY, (17,))
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        y1, _ = r.reduce(x, (), slot="u", key=KEY, axis_name=None)
        y2, _ = r.reduce(x, (), slot="u", key=KEY, axis_name=None)
    deps = [m for m in w if issubclass(m.category, DeprecationWarning)]
    assert len(deps) == 1  # once per process, not per call
    assert "exchange" in str(deps[0].message)
    ye, _ = r.exchange(x, (), slot="u", key=KEY, axis_name=None)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(ye))
    np.testing.assert_array_equal(np.asarray(y2), np.asarray(ye))


@pytest.mark.parametrize("spec,comm_spec,nw,msg", [
    ("ring", "int8", 8, "requires comm 'dense'"),
    ("gossip:4", "dense", 4, "needs more than 4 workers"),
    ("hier:3", "dense", 8, "not divisible"),
    ("gossip:3", "dense", 8, "degree"),   # odd degree: grammar-level
    ("hier:0", "dense", 8, "group"),
    ("mesh", "dense", 8, "topology"),
])
def test_bad_topology_specs_raise_spec_error(spec, comm_spec, nw, msg):
    with pytest.raises(specs.SpecError, match=msg):
        comm.make_topology(spec, num_workers=nw, comm=comm_spec)


def test_specs_validate_cross_rules():
    s, c, t = specs.validate(solver="rank1", comm="dense", topology="ring")
    assert (s.kind, c.kind, t.kind) == ("rank1", "dense", "gossip")
    with pytest.raises(specs.SpecError, match="rank1"):
        specs.validate(solver="block:4", comm="dense", topology="ring")
    with pytest.raises(specs.SpecError, match="dense"):
        specs.validate(solver="rank1", comm="int8", topology="gossip:2")


def test_topology_exchange_rejects_groups():
    topo = comm.make_topology("flat", num_workers=4)
    with pytest.raises(ValueError, match="groups"):
        topo.exchange(jnp.zeros((4,)), (), slot="u", key=KEY, groups=[[0, 1]])


def test_collective_contract_declares_graph_collectives():
    flat = comm.make_topology("flat", num_workers=8, comm="int8")
    assert flat.collective_contract(3).collective_counts == {"all-reduce": 6.0}
    hier = comm.make_topology("hier:2", num_workers=8, comm="int8")
    assert hier.collective_contract(1).collective_counts == {"all-reduce": 3.0}
    ring = comm.make_topology("ring", num_workers=8, rounds=5)
    assert ring.collective_contract(2).collective_counts == {
        "collective-permute": 20.0
    }
