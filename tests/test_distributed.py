"""Multi-device SPMD tests (8 fake CPU devices via a subprocess, since the
device count locks at first jax init in the main pytest process)."""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


def test_sharded_dfw_trace_equals_serial():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import tasks, frank_wolfe, low_rank

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)

        serial = frank_wolfe.fit(task, task.init_state(X, Y), mu=1.0, num_epochs=8,
                                 key=jax.random.PRNGKey(1), schedule="const:2",
                                 step_size="linesearch")

        mesh = jax.make_mesh((8,), ("data",))
        ss = tasks.MTLSState(x=P("data"), y=P("data"), r=P("data"))
        from repro.core import engine
        wrap = engine.shard_map_segment_wrapper(mesh, "data", ss)
        dist = frank_wolfe.fit(task, task.init_state(X, Y), mu=1.0, num_epochs=8,
                               key=jax.random.PRNGKey(1), schedule="const:2",
                               step_size="linesearch", axis_name="data",
                               segment_wrapper=wrap)
        np.testing.assert_allclose(serial.history["loss"], dist.history["loss"], rtol=1e-4)
        W1 = low_rank.materialize(serial.iterate); W2 = low_rank.materialize(dist.iterate)
        assert float(jnp.max(jnp.abs(W1 - W2))) < 1e-5
        print("DFW shard_map == serial OK")
    """)
    assert "OK" in out


def test_sharded_head_training_and_powersgd():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import dfw_head
        from repro.optim import compression

        # --- dfw_head.sharded_fit converges on separable features ---
        n, d, m = 2048, 32, 16
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (d, m))
        X = jax.random.normal(jax.random.fold_in(key, 1), (n, d))
        y = jnp.argmax(X @ W, axis=1)
        mesh = jax.make_mesh((8,), ("data",))
        res = dfw_head.sharded_fit(mesh, X, y, m, mu=8.0, num_epochs=25)
        assert res.history["loss"][-1] < 0.7 * res.history["loss"][0]
        err = dfw_head.top_k_error(res.iterate, X, y, k=5)
        assert err < 0.5, err
        print("sharded head fit OK", res.history["loss"][-1], err)

        # --- PowerSGD: the psum'd (distributed) compression must equal the
        # single-process compression of the MEAN gradient ---
        g_shards = jax.random.normal(jax.random.fold_in(key, 2), (8, 64, 48))
        params = {"w": jnp.zeros((64, 48))}
        st = compression.init(params, rank=8, min_size=16)
        def per_shard(g):
            synced, _ = compression.compress_and_sync({"w": g[0]}, st, min_size=16,
                                                      axis_name="data")
            return synced["w"][None]
        from repro.compat import shard_map_compat
        out_dist = shard_map_compat(per_shard, mesh,
                                    in_specs=(P("data", None, None),),
                                    out_specs=P("data", None, None))(g_shards)
        g_mean = jnp.mean(g_shards, axis=0)
        out_ser, _ = compression.compress_and_sync({"w": g_mean}, st, min_size=16)
        np.testing.assert_allclose(np.asarray(out_dist[0]), np.asarray(out_ser["w"]),
                                   rtol=1e-3, atol=1e-4)
        print("powersgd distributed == mean-gradient OK")
    """)
    assert "sharded head fit OK" in out


def test_seq_sharded_flash_decode():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch.sharding import use_mesh
        from repro.models import layers
        from repro.kernels.flash_attention import ref

        mesh = jax.make_mesh((8, 1), ("data", "model"))
        b, hq, hkv, S, dh = 1, 4, 2, 128, 16
        key = jax.random.PRNGKey(0)
        q = jax.random.normal(key, (b, hq, 1, dh))
        k = jax.random.normal(jax.random.fold_in(key, 1), (b, hkv, S, dh))
        v = jax.random.normal(jax.random.fold_in(key, 2), (b, hkv, S, dh))
        pos = 100
        with use_mesh(mesh):
            got = layers.decode_attention_seq_sharded(
                q, k, v, scale=dh**-0.5, cache_pos=jnp.int32(pos), mesh=mesh)
        want = ref.attention(q, k[:, :, :pos], v[:, :, :pos], scale=dh**-0.5, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)
        print("seq-sharded flash decode OK")
    """)
    assert "OK" in out


@pytest.mark.slow  # ~2.5 min: 200-epoch convergence under worker dropout
def test_straggler_dropout_still_converges():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.core import tasks, frank_wolfe, low_rank
        from repro.compat import shard_map_compat

        n, d, m = 1600, 30, 20
        key = jax.random.PRNGKey(0)
        W = jax.random.normal(key, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(jax.random.fold_in(key, 1), (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        mesh = jax.make_mesh((8,), ("data",))
        ss = tasks.MTLSState(x=P("data"), y=P("data"), r=P("data"))
        isp = low_rank.FactoredIterate(u=P(), s=P(), v=P(), alpha=P(), count=P())
        asp = frank_wolfe.EpochAux(P(), P(), P(), P(), P())
        csp = frank_wolfe.EpochCarry(state=ss, iterate=isp, comm_state=(),
                                     t=P(), key=P())

        # one random straggler dropped per epoch (BSP timeout simulation),
        # driven through the unified-carry epoch contract directly
        ep = frank_wolfe.make_epoch_step(task, 1.0, 2,
            step_size="linesearch", axis_name="data")
        def step(carry, mask):
            return ep(carry, worker_weight=mask[0])
        wrap = jax.jit(shard_map_compat(step, mesh,
            in_specs=(csp, P("data")), out_specs=(csp, asp)))

        losses = []
        carry = frank_wolfe.init_carry(task.init_state(X, Y),
                                       low_rank.init(30, d, m),
                                       jax.random.PRNGKey(1))
        for t in range(30):
            drop = int(jax.random.randint(jax.random.fold_in(key, 100+t), (), 0, 8))
            mask = jnp.ones((8,)).at[drop].set(0.0)
            carry, aux = wrap(carry, mask)
            losses.append(float(aux.loss))
        assert int(carry.t) == 30
        assert losses[-1] < 0.15 * losses[0], losses[-1] / losses[0]
        print("straggler-robust convergence OK", losses[0], losses[-1])
    """)
    assert "OK" in out


def test_elastic_checkpoint_remesh():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointStore

        mesh8 = jax.make_mesh((8,), ("data",))
        mesh2 = jax.make_mesh((2, 4), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh8, P("data", None)))
        with tempfile.TemporaryDirectory() as dd:
            st = CheckpointStore(dd)
            st.save(1, {"w": xs})
            # restore onto a DIFFERENT mesh/sharding (elastic re-shard)
            _, tree, _ = st.restore(like={"w": x},
                shardings={"w": NamedSharding(mesh2, P("model", "data"))})
            np.testing.assert_array_equal(np.asarray(tree["w"]), np.asarray(x))
            assert tree["w"].sharding.mesh.shape == {"data": 2, "model": 4}
        print("elastic remesh OK")
    """)
    assert "OK" in out


def test_moe_ep_shard_map_matches_local():
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, dataclasses
        from repro.configs import get_config
        from repro.models import moe
        from repro.launch.sharding import use_mesh

        cfg = dataclasses.replace(get_config("arctic_480b", smoke=True),
                                  moe_capacity_factor=32.0)
        key = jax.random.PRNGKey(0)
        p = moe.init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 1), (4, 16, cfg.d_model))

        out_local, aux_local = moe.moe_block(p, x, cfg)

        mesh = jax.make_mesh((2, 4), ("data", "model"))
        with use_mesh(mesh):
            out_ep, aux_ep = jax.jit(lambda p, x: moe.moe_block(p, x, cfg))(p, x)
        np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_ep),
                                   rtol=2e-3, atol=2e-3)
        print("MoE EP == local OK")
    """)
    assert "OK" in out
