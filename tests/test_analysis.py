"""repro.analysis: the REPxxx lint rules and the declarative contracts.

Rule-by-rule fixture files with *known* violations, the inline-allow and
baseline workflows, and negative Contract tests — a deliberately broken
function (extra collective round, d x m materialization, counter over cap)
must FAIL its contract, and the correct one must pass. The collective-round
pair runs in a subprocess on 8 fake CPU devices, like the pins it backs.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import contracts, lint
from repro.analysis.contracts import Contract, ContractViolation


def _lint_src(tmp_path: Path, rel: str, source: str):
    """Write ``source`` at tmp_path/rel and lint it rooted at tmp_path."""
    p = tmp_path / rel
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    return lint.lint_paths([p], root=tmp_path)


def _codes(findings):
    return [f.code for f in findings]


# ---------------------------------------------------------------------------
# REP001 — raw collectives outside repro/comm
# ---------------------------------------------------------------------------


def test_rep001_flags_raw_collectives_and_from_imports(tmp_path):
    findings = _lint_src(tmp_path, "core/grad.py", """
        import jax
        from jax.lax import psum, all_gather

        def agg(x):
            y = jax.lax.psum(x, "data")
            return jax.lax.pmax(y, "data")
    """)
    rep1 = [f for f in findings if f.code == "REP001"]
    assert len(rep1) == 3  # the import line + the two call sites
    assert {f.line for f in rep1} == {3, 6, 7}
    assert "psum/all_gather" in rep1[0].message


def test_rep001_exempts_the_comm_layer(tmp_path):
    findings = _lint_src(tmp_path, "comm/base.py", """
        import jax

        def psum(x, axis_name):
            return jax.lax.psum(x, axis_name)
    """)
    assert _codes(findings) == []


def test_rep001_inline_allow_requires_a_reason(tmp_path):
    bare = _lint_src(tmp_path, "core/a.py", """
        import jax

        def f(x):
            return jax.lax.psum(x, "data")  # REP001-ok:
    """)
    assert _codes(bare) == ["REP001"]  # bare marker: not suppressed
    justified = _lint_src(tmp_path, "core/b.py", """
        import jax

        def f(x):
            return jax.lax.psum(x, "data")  # REP001-ok: comm bootstrap probe
    """)
    assert _codes(justified) == []


# ---------------------------------------------------------------------------
# REP002 — implicit host syncs in hot paths
# ---------------------------------------------------------------------------


def test_rep002_flags_implicit_syncs_in_hot_paths_only(tmp_path):
    src = """
        import numpy as np

        def pull(x):
            a = float(x.sum())
            b = x.mean().item()
            c = np.asarray(x)
            return a, b, c
    """
    hot = _lint_src(tmp_path, "core/loop.py", src)
    assert _codes(hot) == ["REP002"] * 3
    cold = _lint_src(tmp_path, "viz/plot.py", src)
    assert _codes(cold) == []  # host-side analysis code is out of scope


def test_rep002_literal_and_name_args_are_fine(tmp_path):
    findings = _lint_src(tmp_path, "core/cfg.py", """
        def parse(tok, n):
            return float(tok), bool(n), float("1e-3")
    """)
    assert _codes(findings) == []


def test_rep002_device_get_boundary_suppresses(tmp_path):
    findings = _lint_src(tmp_path, "core/fetch.py", """
        import jax
        import numpy as np

        def pull(x):
            host = jax.device_get(x)
            return float(host.sum()), np.asarray(host)
    """)
    assert _codes(findings) == []  # explicit boundary established


# ---------------------------------------------------------------------------
# REP003 — kernel trio completeness (project-level)
# ---------------------------------------------------------------------------


def _kernel_pkg(tmp_path, name, files):
    pkg = tmp_path / "kernels" / name
    pkg.mkdir(parents=True)
    for fname, content in files.items():
        (pkg / fname).write_text(textwrap.dedent(content))
    return pkg


def test_rep003_complete_trio_is_clean(tmp_path):
    _kernel_pkg(tmp_path, "good", {
        "kernel.py": "def matvec_tpu(x):\n    return x\n",
        "ops.py": """
            from . import kernel, ref

            def matvec(x, use_pallas=False):
                return kernel.matvec_tpu(x) if use_pallas else ref.matvec(x)
        """,
        "ref.py": "def matvec(x):\n    return x\n",
    })
    findings = lint.lint_paths([tmp_path], root=tmp_path)
    assert _codes(findings) == []


def test_rep003_missing_ref_and_unrouted_ops_are_flagged(tmp_path):
    _kernel_pkg(tmp_path, "noref", {
        "kernel.py": "x = 1\n",
        "ops.py": "def f(x, use_pallas=True):\n    return x\n",
    })
    _kernel_pkg(tmp_path, "norouting", {
        "kernel.py": "x = 1\n",
        # trio present, but ops never falls back to ref off-TPU
        "ops.py": "def f(x):\n    return x\n",
        "ref.py": "def f(x):\n    return x\n",
    })
    findings = lint.lint_paths([tmp_path], root=tmp_path)
    rep3 = {f.path: f.message for f in findings if f.code == "REP003"}
    assert "kernels/noref" in rep3 and "ref.py" in rep3["kernels/noref"]
    assert "kernels/norouting/ops.py" in rep3


# ---------------------------------------------------------------------------
# REP004 — recompilation hazards at jit boundaries
# ---------------------------------------------------------------------------


def test_rep004_branch_on_nonstatic_param(tmp_path):
    findings = _lint_src(tmp_path, "core/step.py", """
        import functools
        import jax

        @jax.jit
        def bad(x, mode):
            if mode:
                return -x
            return x

        @functools.partial(jax.jit, static_argnames=("mode",))
        def good(x, mode):
            if mode:
                return -x
            return x
    """)
    assert _codes(findings) == ["REP004"]
    assert "bad" in findings[0].message and "mode" in findings[0].message


# ---------------------------------------------------------------------------
# REP005 — print / f-string on tracers inside jit
# ---------------------------------------------------------------------------


def test_rep005_print_and_traced_fstring(tmp_path):
    findings = _lint_src(tmp_path, "core/dbg.py", """
        import jax

        @jax.jit
        def f(x, y):
            print("tracing")
            msg = f"x is {x}"
            return x + y

        def not_jitted(x):
            print(f"fine here {x}")
            return x
    """)
    # The jitted prints are REP005's domain (and exempt from REP006); the
    # bare print in the plain function is library-code output -> REP006.
    assert _codes(findings) == ["REP005", "REP005", "REP006"]
    assert {f.line for f in findings if f.code == "REP005"} == {6, 7}
    assert [f.line for f in findings if f.code == "REP006"] == [11]


# ---------------------------------------------------------------------------
# REP006 — bare print in library code
# ---------------------------------------------------------------------------


def test_rep006_flags_library_prints_only(tmp_path):
    src = """
        def helper(x):
            print("debug", x)
            return x
    """
    assert _codes(_lint_src(tmp_path, "core/util.py", src)) == ["REP006"]
    # tools/ and examples/ are CLI/demo surfaces — out of scope
    assert _codes(_lint_src(tmp_path, "tools/report.py", src)) == []
    assert _codes(_lint_src(tmp_path, "examples/demo.py", src)) == []


def test_rep006_exempts_main_bodies_and_dunder_main(tmp_path):
    findings = _lint_src(tmp_path, "launch/cli.py", """
        def work(x):
            return x * 2

        def main():
            print("result:", work(21))

        if __name__ == "__main__":
            print("starting")
            main()
    """)
    assert _codes(findings) == []


def test_rep006_inline_allow_requires_a_reason(tmp_path):
    bare = _lint_src(tmp_path, "core/a6.py", """
        def f(x):
            print(x)  # REP006-ok:
    """)
    assert _codes(bare) == ["REP006"]
    justified = _lint_src(tmp_path, "core/b6.py", """
        def f(x):
            print(x)  # REP006-ok: one-shot migration warning, stderr-free env
    """)
    assert _codes(justified) == []


# ---------------------------------------------------------------------------
# REP007 — imports of retired modules (deleted compat shims)
# ---------------------------------------------------------------------------


def test_rep007_flags_every_import_spelling(tmp_path):
    findings = _lint_src(tmp_path, "launch/old_importer.py", """
        import repro.launch.hlo_analysis
        from repro.launch import hlo_analysis
        from repro.launch.hlo_analysis import analyze
        from ..launch import hlo_analysis as ha
        from .hlo_analysis import COLLECTIVES
    """)
    rep7 = [f for f in findings if f.code == "REP007"]
    assert {f.line for f in rep7} == {2, 3, 4, 5, 6}
    assert "repro.analysis.hlo" in rep7[0].message  # names the replacement


def test_rep007_new_path_and_local_alias_are_clean(tmp_path):
    findings = _lint_src(tmp_path, "launch/new_importer.py", """
        from repro.analysis import hlo as hlo_analysis
        from repro.analysis.hlo import analyze

        res = hlo_analysis.analyze("HloModule m")
    """)
    assert _codes(findings) == []


def test_rep007_retired_shim_is_really_gone():
    with pytest.raises(ModuleNotFoundError):
        import repro.launch.hlo_analysis  # noqa: F401  # REP007-ok: asserting the shim stays deleted


# ---------------------------------------------------------------------------
# Baseline workflow: freeze debt, fail on new, report stale
# ---------------------------------------------------------------------------


def test_baseline_freezes_known_debt_and_catches_new(tmp_path):
    findings = _lint_src(tmp_path, "core/debt.py", """
        import jax

        def f(x):
            return jax.lax.psum(x, "data")
    """)
    assert len(findings) == 1
    bl_path = tmp_path / "baseline.json"
    lint.write_baseline(bl_path, findings, None)
    baseline = lint.load_baseline(bl_path)
    # the frozen finding is budgeted, new entries carry the review marker
    new, stale = lint.diff_baseline(findings, baseline)
    assert new == [] and stale == []
    assert list(baseline.values())[0]["why"].startswith("UNREVIEWED")

    # a second, different violation exceeds the budget -> new finding
    more = _lint_src(tmp_path, "core/debt2.py", """
        import jax

        def g(x):
            return jax.lax.pmax(x, "data")
    """)
    new, stale = lint.diff_baseline(findings + more, baseline)
    assert [f.path for f in new] == ["core/debt2.py"] and stale == []

    # fixing the original debt leaves a stale entry (baseline shrink prompt)
    new, stale = lint.diff_baseline([], baseline)
    assert new == [] and len(stale) == 1


def test_baseline_roundtrip_preserves_justifications(tmp_path):
    findings = _lint_src(tmp_path, "core/debt.py", """
        import jax

        def f(x):
            return jax.lax.psum(x, "data")
    """)
    bl_path = tmp_path / "baseline.json"
    lint.write_baseline(bl_path, findings, None)
    old = lint.load_baseline(bl_path)
    for e in old.values():
        e["why"] = "reviewed: bootstrap probe, off the epoch path"
    lint.write_baseline(bl_path, findings, old)
    again = lint.load_baseline(bl_path)
    assert [e["why"] for e in again.values()] == [
        "reviewed: bootstrap probe, off the epoch path"
    ]


def test_missing_baseline_is_empty_and_everything_is_new(tmp_path):
    baseline = lint.load_baseline(tmp_path / "nope.json")
    assert baseline == {}
    findings = _lint_src(tmp_path, "core/debt.py", """
        import jax

        def f(x):
            return jax.lax.psum(x, "data")
    """)
    new, stale = lint.diff_baseline(findings, baseline)
    assert len(new) == 1 and stale == []


# ---------------------------------------------------------------------------
# Contracts: a broken artifact must fail its declaration
# ---------------------------------------------------------------------------

_D, _M = 12, 7


def _factored_score(u, s, v, x):
    return ((x @ u.T) * s) @ v  # O(t(d+m)) — never forms (d, m)


def _dense_score(u, s, v, x):
    w = (u.T * s) @ v  # materializes the (d, m) matrix
    return x @ w


def _score_args(t=3, b=4):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    return (
        jax.random.normal(ks[0], (t, _D)),
        jax.random.normal(ks[1], (t,)),
        jax.random.normal(ks[2], (t, _M)),
        jax.random.normal(ks[3], (b, _D)),
    )


def test_forbid_shapes_passes_factored_fails_dense():
    c = Contract(name="t.never_materialize", forbid_shapes=((_D, _M), (_M, _D)))
    c.check_hlo(_factored_score, *_score_args())  # no (12,7) anywhere
    with pytest.raises(ContractViolation, match="forbid_shapes"):
        c.check_hlo(_dense_score, *_score_args())


def test_check_stats_caps_and_missing_counters():
    c = Contract(name="t.stats", max_dispatches=2, max_host_syncs=1)
    c.check_stats({"dispatches": 2, "host_syncs": 1})  # at the cap: fine
    with pytest.raises(ContractViolation, match="dispatches"):
        c.check_stats({"dispatches": 3, "host_syncs": 0})
    with pytest.raises(ContractViolation, match="host_syncs"):
        c.check_stats({"dispatches": 1})  # declared counter absent


def test_guard_is_the_transfer_guard_when_declared():
    """``guard()`` arms ``jax.transfer_guard_device_to_host`` only when the
    contract declares ``no_host_transfers``. (On CPU backends the guard is
    zero-copy-silent, so this checks the plumbing, not a raise — the raise
    is exercised on accelerator runs of the same contracts.)"""
    import contextlib

    armed = Contract(name="t.guard", no_host_transfers=True).guard()
    assert not isinstance(armed, contextlib.nullcontext)
    x = jnp.arange(8.0)
    with armed:
        _ = float(jax.device_get(x.sum()))  # explicit: always allowed
    # a contract without the clause is a no-op context
    noop = Contract(name="t.noop").guard()
    assert isinstance(noop, contextlib.nullcontext)
    with noop:
        float(jax.device_get(x.sum() + 2.0))


def test_measure_exposes_the_hlo_walk():
    res = contracts.measure(_factored_score, *_score_args())
    assert res["collective_count"] == {}
    assert res["flops"] > 0


def test_replica_groups_parsing_and_partition_crossing():
    """The topology-aware byte classifier: explicit and iota replica-group
    spellings parse, and crossing/local classification against a host
    partition matches what hier two-level reduce promises."""
    from repro.analysis import hlo

    ex = ("%ar = f32[256]{0} all-reduce(%x), "
          "replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add")
    assert hlo.parse_replica_groups(ex) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    iota = "%ar = f32[8]{0} all-reduce(%x), replica_groups=[2,4]<=[8]"
    assert hlo.parse_replica_groups(iota) == [[0, 1, 2, 3], [4, 5, 6, 7]]
    # transposed iota is ambiguous here — refuse rather than guess
    assert hlo.parse_replica_groups(
        "replica_groups=[2,4]<=[4,2]T(1,0)") is None
    assert hlo.parse_replica_groups("%ar = f32[4] all-reduce(%x)") is None
    pairs = ("%cp = f32[4]{0} collective-permute(%x), "
             "source_target_pairs={{0,1},{1,2},{3,4}}")
    assert hlo.parse_replica_groups(pairs) == [[0, 1], [1, 2], [3, 4]]

    text = """
HloModule m

ENTRY %main (x: f32[256]) -> f32[256] {
  %x = f32[256]{0} parameter(0)
  %intra = f32[256]{0} all-reduce(%x), replica_groups={{0,1,2,3},{4,5,6,7}}, to_apply=%add
  %inter = f32[256]{0} all-reduce(%intra), replica_groups={{0,4},{1,5},{2,6},{3,7}}, to_apply=%add
  ROOT %global = f32[256]{0} all-reduce(%inter), to_apply=%add
}
"""
    res = hlo.partition_crossing_bytes(text, [[0, 1, 2, 3], [4, 5, 6, 7]])
    # intra stays inside the cells; inter + group-less global cross
    assert res["local"] == 2048.0 and res["local_count"] == 1.0
    assert res["crossing"] == 4096.0 and res["crossing_count"] == 2.0
    assert res["by_op"] == {"all-reduce": 4096.0}
    # one cell: nothing can cross
    one = hlo.partition_crossing_bytes(text, [[0, 1, 2, 3, 4, 5, 6, 7]])
    assert one["crossing"] == 0.0 and one["local"] == 6144.0


def test_collective_rounds_contract_subprocess_8way():
    """The 2K-round contract passes on the real power method and FAILS on a
    doctored one paying an extra collective round — proof the declaration
    actually bites on compiled HLO, not on intent."""
    src = str(Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = src
    script = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.analysis.contracts import ContractViolation
        from repro.compat import shard_map_compat
        from repro.core import power_method

        K, n, m = 2, 256, 32
        mesh = jax.make_mesh((8,), ("data",))
        a = jax.ShapeDtypeStruct((n, m), jnp.float32)
        v0 = jax.ShapeDtypeStruct((m,), jnp.float32)
        contract = power_method.collective_rounds_contract(K)

        def wrap(fn):
            return shard_map_compat(
                fn, mesh, in_specs=(P("data"), P()),
                out_specs=power_method.PowerResult(u=P(), v=P(), sigma=P()))

        def good(a, v0):
            return power_method.power_iterations(
                lambda v: a @ v, lambda u: a.T @ u, v0, K, axis_name="data")

        def broken(a, v0):
            res = good(a, v0)
            # the pre-carried-sigma bug: one extra aggregation after the loop
            sigma = jnp.linalg.norm(
                jax.lax.psum(a.T @ res.u, "data"))  # REP001-ok: test fixture
            return power_method.PowerResult(u=res.u, v=res.v, sigma=sigma)

        contract.check_hlo(wrap(good), a, v0)
        try:
            contract.check_hlo(wrap(broken), a, v0)
        except ContractViolation as e:
            assert "collective_counts" in str(e), e
            print("verdicts OK")
        else:
            raise SystemExit("broken power method passed its contract")
    """)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=env)
    assert out.returncode == 0, f"STDOUT:\n{out.stdout}\nSTDERR:\n{out.stderr[-4000:]}"
    assert "verdicts OK" in out.stdout
