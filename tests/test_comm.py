"""Compressed power-method collectives (repro/comm + kernels/quantize).

In-process units cover the reducer math (bit-exact dense plumbing, int8
unbiasedness, top-k error feedback) on one device; the 8-worker tolerance and
wire-bytes checks run in subprocesses with fake CPU devices, matching the
idiom of tests/test_dfw_launch.py.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.core import power_method, tasks
from repro.core.power_method import sphere_vector
from repro.kernels.quantize import ops as qops
from repro.kernels.quantize import ref as qref
from repro.launch import dfw

SRC = str(Path(__file__).resolve().parent.parent / "src")
KEY = jax.random.PRNGKey(0)


def _run(script: str, timeout=600):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# Factory / spec parsing
# ---------------------------------------------------------------------------


def test_make_reducer_parses_all_specs():
    assert isinstance(comm.make_reducer("dense"), comm.DenseReducer)
    r8 = comm.make_reducer("int8", num_workers=8)
    assert isinstance(r8, comm.Int8Reducer) and r8.budget == 15
    rk = comm.make_reducer("topk:32")
    assert isinstance(rk, comm.TopKReducer) and rk.k == 32
    assert rk.spec == "topk:32" and r8.spec == "int8"


def test_make_reducer_rejects_bad_specs():
    with pytest.raises(ValueError, match="unknown comm spec"):
        comm.make_reducer("float16")
    with pytest.raises(ValueError, match="k must be"):
        comm.make_reducer("topk:0")
    with pytest.raises(ValueError, match="1..127"):
        comm.make_reducer("int8", num_workers=256)


def test_dfw_config_rejects_bad_comm_spec():
    task = tasks.MultiTaskLeastSquares(d=8, m=6)
    x = jax.random.normal(KEY, (64, 8))
    y = jax.random.normal(jax.random.fold_in(KEY, 1), (64, 6))
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=2, comm="nope")
    with pytest.raises(ValueError, match="unknown comm spec"):
        dfw.fit(task, x, y, cfg=cfg, key=KEY, num_workers=1)


# ---------------------------------------------------------------------------
# Dense reducer: the plumbing itself must be bit-exact
# ---------------------------------------------------------------------------


def test_dense_reducer_bit_exact_vs_uninjected():
    a = jax.random.normal(KEY, (40, 30))
    v0 = sphere_vector(jax.random.fold_in(KEY, 1), 30)
    plain = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u, v0, 8
    )
    routed, cs = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u, v0, 8, reducer=comm.DenseReducer()
    )
    assert cs == ()
    for got, want in zip(routed, plain):
        assert np.array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# int8: stochastic rounding is unbiased; roundtrip error is one grid step
# ---------------------------------------------------------------------------


def test_int8_stochastic_rounding_unbiased():
    """E[dequant(quant(x))] = x: the empirical mean over independent noise
    draws converges at the CLT rate; assert within 6 standard errors."""
    r = comm.Int8Reducer(num_workers=8)  # budget 15: the coarse, real regime
    x = jax.random.normal(KEY, (64,)) * jnp.linspace(0.01, 3.0, 64)
    trials = 4000

    def one(k):
        y, _ = r.exchange(x, (), slot="u", key=k, axis_name=None)
        return y

    ys = jax.vmap(one)(jax.random.split(jax.random.fold_in(KEY, 2), trials))
    mean = np.asarray(jnp.mean(ys, axis=0))
    step = float(jnp.max(jnp.abs(x))) / r.budget  # quantization grid step
    stderr = 0.5 * step / np.sqrt(trials)  # SR noise std <= step/2
    np.testing.assert_array_less(np.abs(mean - np.asarray(x)), 5.0 * stderr)


def test_int8_roundtrip_error_bounded_by_grid_step():
    r = comm.Int8Reducer(num_workers=4)
    x = jax.random.normal(KEY, (257,))
    y, _ = r.exchange(x, (), slot="v", key=jax.random.fold_in(KEY, 3), axis_name=None)
    step = float(jnp.max(jnp.abs(x))) / r.budget
    assert float(jnp.max(jnp.abs(y - x))) <= step * (1 + 1e-6)


def test_int8_zero_vector_is_fixed_point():
    r = comm.Int8Reducer(num_workers=8)
    y, _ = r.exchange(jnp.zeros((32,)), (), slot="u", key=KEY, axis_name=None)
    np.testing.assert_array_equal(np.asarray(y), np.zeros(32, np.float32))


def test_verify_quantize_kernels_passes_and_catches():
    err = comm.verify_quantize_kernels(KEY, num_workers=8)
    assert err <= 1e-6
    with pytest.raises(AssertionError, match="diverges"):
        comm.verify_quantize_kernels(KEY, num_workers=8, tol=-1.0)


# ---------------------------------------------------------------------------
# Quantize kernel trio: interpret-mode Pallas vs jnp ref (exact: same noise)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,budget", [(256, 15), (300, 127), (7, 1)])
def test_quantize_kernel_matches_ref(n, budget):
    x = jax.random.normal(KEY, (n,)) * 2.0
    noise = jax.random.uniform(jax.random.fold_in(KEY, 4), (n,))
    scale = jnp.max(jnp.abs(x))
    got = qops.quantize(x, noise, scale, budget=budget, block_n=64, interpret=True)
    want = qref.quantize(x, noise, scale, budget)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert got.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(got.astype(jnp.int32)))) <= budget
    deq = qops.dequantize(got, scale, budget=budget, block_n=64, interpret=True)
    np.testing.assert_allclose(
        np.asarray(deq), np.asarray(qref.dequantize(want, scale, budget)),
        rtol=1e-6, atol=1e-6)


# ---------------------------------------------------------------------------
# top-k: error feedback keeps the transmitted signal honest
# ---------------------------------------------------------------------------


def test_topk_exact_when_k_covers_dim():
    r = comm.TopKReducer(k=64)
    st = r.init_state(16, 12)
    x = jax.random.normal(KEY, (16,))
    y, st = r.exchange(x, st, slot="u", key=KEY, axis_name=None)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=1e-6)
    assert float(jnp.linalg.norm(st["u"])) == 0.0


def test_topk_error_feedback_residual_decays():
    """EF identity: sum_t y_t = T x + e_0 - e_T, so for a constant input the
    residual stays bounded by the unsent mass and the running-mean error
    decays as O(1/T) — the property that makes sparsification safe."""
    r = comm.TopKReducer(k=8)
    d = 32
    st = {"u": jnp.zeros((d,)), "v": jnp.zeros((2,))}
    x = jax.random.normal(KEY, (d,))
    x_norm = float(jnp.linalg.norm(x))
    ys, enorms = [], []
    for t in range(64):
        y, st = r.exchange(x, st, slot="u", key=jax.random.fold_in(KEY, t),
                           axis_name=None)
        ys.append(np.asarray(y))
        enorms.append(float(jnp.linalg.norm(st["u"])))
    # residual stays under the EF plateau: with contraction factor
    # delta = k/d, ||e_{t+1}|| <= sqrt(1-delta) (||x|| + ||e_t||), whose
    # fixed point is sqrt(1-delta) / (1 - sqrt(1-delta)) * ||x||.
    c = np.sqrt(1.0 - r.k / d)
    assert max(enorms) <= c / (1.0 - c) * x_norm * (1 + 1e-5)
    # running-mean deviation decays ~1/T (sum_t y_t = T x - e_T exactly)
    err_10 = np.linalg.norm(np.mean(ys[:10], axis=0) - np.asarray(x))
    err_64 = np.linalg.norm(np.mean(ys, axis=0) - np.asarray(x))
    assert err_64 < err_10 / 2.0
    np.testing.assert_allclose(err_64, enorms[-1] / 64, rtol=1e-4)


def test_topk_masked_worker_sends_nothing_and_freezes_residual():
    """Straggler interaction: a sampled-out worker (weight 0) has x = 0 but a
    nonzero residual; it must neither leak top-k(e) into the aggregate nor
    update e — otherwise the driver's unbiased reweighting breaks."""
    r = comm.TopKReducer(k=4)
    e0 = jax.random.normal(KEY, (16,))
    st = {"u": e0, "v": jnp.zeros((2,))}
    y, st2 = r.exchange(jnp.zeros((16,)), st, slot="u",
                        key=jax.random.fold_in(KEY, 1), axis_name=None,
                        weight=jnp.float32(0.0))
    np.testing.assert_array_equal(np.asarray(y), np.zeros(16, np.float32))
    np.testing.assert_array_equal(np.asarray(st2["u"]), np.asarray(e0))
    # a live worker (any weight > 0, incl. fractional reweights) still sends
    x = jax.random.normal(jax.random.fold_in(KEY, 2), (16,))
    y_w, _ = r.exchange(x, st, slot="u", key=jax.random.fold_in(KEY, 3),
                        axis_name=None, weight=jnp.float32(8.0 / 5.0))
    y_n, _ = r.exchange(x, st, slot="u", key=jax.random.fold_in(KEY, 3),
                        axis_name=None, weight=None)
    np.testing.assert_array_equal(np.asarray(y_w), np.asarray(y_n))


def test_topk_state_threads_through_power_iterations():
    a = jax.random.normal(KEY, (24, 18))
    v0 = sphere_vector(jax.random.fold_in(KEY, 1), 18)
    r = comm.TopKReducer(k=6)
    res, cs = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u, v0, 4, reducer=r,
        key=jax.random.fold_in(KEY, 2),
    )
    assert set(cs) == {"u", "v"}
    assert cs["u"].shape == (24,) and cs["v"].shape == (18,)
    assert float(jnp.linalg.norm(cs["u"])) > 0.0  # k=6 < 24: mass withheld
    # threading the state back in continues, not restarts
    res2, cs2 = power_method.power_iterations(
        lambda v: a @ v, lambda u: a.T @ u, res.v, 4, reducer=r, comm_state=cs,
        key=jax.random.fold_in(KEY, 3),
    )
    assert res2.sigma > 0.0


# ---------------------------------------------------------------------------
# 8-worker sharded runs: every reducer tracks the serial dense trajectory
# ---------------------------------------------------------------------------

_SETUP = """
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        base = dfw.DFWConfig(mu=1.0, num_epochs=6, schedule="const:2",
                             step_size="linesearch")
        ser = dfw.fit_serial(task, X, Y, cfg=base, key=jax.random.PRNGKey(1))
"""


def test_sharded_reducers_track_serial_dense():
    """8-worker runs under each reducer stay within tolerance of the serial
    dense trajectory; comm='dense' reproduces it to psum rounding exactly as
    the un-knobbed driver does."""
    out = _run(_SETUP + """
        tol = {"dense": 1e-4, "int8": 0.02, "topk:16": 0.35}
        for cm, rtol in tol.items():
            cfg = dataclasses.replace(base, comm=cm)
            dist = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                           num_workers=8)
            np.testing.assert_allclose(ser.history["loss"], dist.history["loss"],
                                       rtol=rtol)
            rel = abs(dist.final_loss - ser.final_loss) / ser.final_loss
            assert rel < rtol, (cm, rel)
            print(cm, "rel", rel)
        print("sharded reducers OK")
    """)
    assert "sharded reducers OK" in out


def test_sharded_dense_reducer_bit_exact_vs_legacy_epoch():
    """The unified-carry reducer plumbing must be lossless: one epoch built
    with the default DenseReducer yields floats identical to a hand-inlined
    raw-psum epoch (the pre-engine construction, kept here as the oracle)."""
    out = _run(_SETUP + """
        from jax.sharding import PartitionSpec as P
        from repro.compat import shard_map_compat
        from repro.core import frank_wolfe, low_rank, power_method
        from repro.core.trace_norm import duality_gap

        mesh = dfw.data_mesh(8)
        xs, ys = dfw.shard_rowwise(mesh, (X, Y))
        state = task.init_state(xs, ys)
        it = low_rank.init(base.num_epochs, d, m)
        k = jax.random.PRNGKey(3)
        mask = jnp.ones((8,), jnp.float32)

        # hand-inlined raw-psum epoch: exactly the legacy un-injected math
        def oracle(state, it, kk, mask):
            w = mask[0]
            v0 = power_method.sphere_vector(
                jax.random.fold_in(kk, jnp.int32(0)), m)
            res = power_method.power_iterations(
                lambda v: task.matvec(state, v),
                lambda u: task.rmatvec(state, u),
                v0, 2, axis_name="data", worker_weight=w)
            loss = jax.lax.psum(w * task.local_loss(state), "data")
            inner = jax.lax.psum(w * task.inner_w_grad(state), "data")
            gap = duality_gap(inner, res.sigma, 1.0)
            numer, denom = task.linesearch_terms(state, res.u, res.v, 1.0)
            numer = jax.lax.psum(w * numer, "data")
            denom = jax.lax.psum(w * denom, "data")
            gamma = jnp.clip(numer / jnp.maximum(denom, 1e-30), 0.0, 1.0)
            state = task.update(state, res.u, res.v, gamma, 1.0)
            it = low_rank.fw_update(it, res.u, res.v, gamma, 1.0)
            return state, it, frank_wolfe.EpochAux(
                loss, gap, res.sigma, gamma, jnp.full((), 2, jnp.float32))

        ss = jax.tree.map(lambda _: P("data"), state)
        isp = low_rank.FactoredIterate(u=P(), s=P(), v=P(), alpha=P(), count=P())
        asp = frank_wolfe.EpochAux(P(), P(), P(), P(), P())
        wrapped = shard_map_compat(oracle, mesh,
            in_specs=(ss, isp, P(), P("data")), out_specs=(ss, isp, asp))
        s1, it1, aux1 = jax.jit(wrapped)(state, it, k, mask)

        routed = dfw.make_sharded_epoch(task, base, mesh, 2,
                                        state_example=state)
        carry = frank_wolfe.init_carry(state, it, k)
        carry2, aux2 = jax.jit(routed)(carry, mask)
        assert carry2.comm_state == ()
        assert int(carry2.t) == 1
        for a, b in zip(jax.tree.leaves((s1, it1, aux1)),
                        jax.tree.leaves((carry2.state, carry2.iterate, aux2))):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        print("dense reducer sharded bit-exact OK")
    """)
    assert "OK" in out


@pytest.mark.slow  # subprocess + multi-epoch sweep: the acceptance-bar check
def test_int8_within_2pct_and_3x_fewer_bytes():
    """The PR acceptance bar, as a test: 8-way MTLS and matrix-completion
    runs under comm='int8' reach within 2% of dense final loss while the
    HLO-measured collective bytes per epoch drop >= 3x."""
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.analysis import contracts
        from repro.core import tasks, low_rank, frank_wolfe
        from repro.launch import dfw
        from repro import comm as comm_lib

        # --- convergence: MTLS ---
        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        base = dfw.DFWConfig(mu=1.0, num_epochs=15, schedule="const:2",
                             step_size="linesearch")
        dense = dfw.fit(task, X, Y, cfg=base, key=jax.random.PRNGKey(1),
                        num_workers=8)
        int8 = dfw.fit(task, X, Y,
                       cfg=dataclasses.replace(base, comm="int8"),
                       key=jax.random.PRNGKey(1), num_workers=8)
        rel = abs(int8.final_loss - dense.final_loss) / dense.final_loss
        assert rel < 0.02, ("mtls", rel, int8.final_loss, dense.final_loss)
        print("mtls int8 rel", rel)

        # --- convergence: matrix completion ---
        d2, m2, rank = 64, 48, 5
        ku, kv, ko = jax.random.split(jax.random.PRNGKey(7), 3)
        U = jnp.linalg.qr(jax.random.normal(ku, (d2, rank)))[0]
        V = jnp.linalg.qr(jax.random.normal(kv, (m2, rank)))[0]
        sv = jnp.linspace(1.0, 0.2, rank); sv = sv / jnp.sum(sv)
        Wmc = (U * sv) @ V.T
        mask = jax.random.bernoulli(ko, 0.35, (d2, m2))
        rows, cols = jnp.nonzero(mask)
        vals = Wmc[rows, cols]
        mtask = tasks.MatrixCompletion(d=d2, m=m2)
        mcfg = dfw.DFWConfig(mu=1.5, num_epochs=15, schedule="const:2",
                             step_size="linesearch")
        idx8, yw8 = dfw.shard_observations(rows, cols, vals, 8, d2, m=m2)
        mdense = dfw.fit(mtask, idx8, yw8, cfg=mcfg,
                         key=jax.random.PRNGKey(2), num_workers=8)
        mint8 = dfw.fit(mtask, idx8, yw8,
                        cfg=dataclasses.replace(mcfg, comm="int8"),
                        key=jax.random.PRNGKey(2), num_workers=8)
        mrel = abs(mint8.final_loss - mdense.final_loss) / mdense.final_loss
        assert mrel < 0.02, ("mc", mrel)
        print("mc int8 rel", mrel)

        # --- wire bytes: HLO-measured epoch collectives, dense vs int8,
        # at the SAME sizes the convergence runs above used ---
        mesh = jax.make_mesh((8,), ("data",))
        K = 2
        x = jax.ShapeDtypeStruct((n, d), jnp.float32)
        y = jax.ShapeDtypeStruct((n, m), jnp.float32)
        st = tasks.MTLSState(x=x, y=y, r=y)
        it = jax.eval_shape(lambda: low_rank.init(30, d, m))
        msk = jax.ShapeDtypeStruct((8,), jnp.float32)
        bytes_by = {}
        for cm in ("dense", "int8"):
            cfg = dataclasses.replace(base, comm=cm)
            red = comm_lib.make_reducer(cm, num_workers=8)
            ep = dfw.make_sharded_epoch(task, cfg, mesh, K,
                                        state_example=st, reducer=red)
            carry = frank_wolfe.EpochCarry(
                state=st, iterate=it,
                comm_state=jax.tree.map(
                    lambda l: jax.ShapeDtypeStruct((8,) + l.shape, l.dtype),
                    red.init_state(d, m)),
                t=jax.ShapeDtypeStruct((), jnp.int32),
                key=jax.ShapeDtypeStruct((2,), jnp.uint32))
            bytes_by[cm] = contracts.measure(
                ep, carry, msk)["collective_bytes_total"]
        ratio = bytes_by["dense"] / bytes_by["int8"]
        assert ratio >= 3.0, bytes_by
        print("bytes ratio", ratio)
        print("acceptance OK")
    """, timeout=900)
    assert "acceptance OK" in out
