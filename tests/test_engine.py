"""Device-resident epoch engine (core/engine.py).

Covers the segment plan, scan-vs-legacy trajectory equivalence, gap-based
early stopping, the dispatch/host-sync regression pins, and the segment
granularity of the progress callback. Multi-device coverage runs in
subprocesses with 8 fake CPU devices (the device count locks at the first
jax init in the main pytest process), matching tests/test_dfw_launch.py.
"""
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import comm
from repro.core import engine, frank_wolfe, tasks

SRC = str(Path(__file__).resolve().parent.parent / "src")


def _run(script: str, timeout=900):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC
    res = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(script)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-4000:]}"
    return res.stdout


# ---------------------------------------------------------------------------
# Segment plan
# ---------------------------------------------------------------------------


def test_plan_segments_const_is_one_segment():
    (seg,) = engine.plan_segments("const:2", 30)
    assert seg == engine.Segment(start=0, length=30, k=2)


def test_plan_segments_log_is_maximal_constant_runs():
    segs = engine.plan_segments("log", 50)
    sched = frank_wolfe.k_schedule("log")
    # contiguous, exhaustive, constant-K inside, maximal at the boundaries
    t = 0
    for seg in segs:
        assert seg.start == t
        for e in range(seg.start, seg.start + seg.length):
            assert sched(e) == seg.k
        t = seg.start + seg.length
    assert t == 50
    for a, b in zip(segs, segs[1:]):
        assert a.k != b.k  # maximality: adjacent segments differ in K
    assert len(segs) <= int(np.log(50)) + 2  # O(log T) dispatches


def test_plan_segments_block_epochs_caps_length():
    segs = engine.plan_segments("const:1", 25, block_epochs=10)
    assert [s.length for s in segs] == [10, 10, 5]
    assert all(s.k == 1 for s in segs)
    with pytest.raises(ValueError, match="block_epochs"):
        engine.plan_segments("const:1", 5, block_epochs=0)
    with pytest.raises(ValueError, match="num_epochs"):
        engine.plan_segments("const:1", 0)


def test_resolve_max_rank_contract():
    assert engine.resolve_max_rank(None, 7) == 7
    assert engine.resolve_max_rank(12, 7) == 12
    with pytest.raises(ValueError, match="max_rank"):
        engine.resolve_max_rank(6, 7)


# ---------------------------------------------------------------------------
# Scan-vs-legacy trajectory equivalence (serial; the 8-way variant is below)
# ---------------------------------------------------------------------------


def _mtls(key, n=400, d=24, m=18):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (d, m))
    w = w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    return x, x @ w


def _fit_pair(task, state_fn, *, reducer=None, schedule="const:2",
              step_size="linesearch", num_epochs=10, gap_tol=None):
    out = {}
    for mode in ("scan", "legacy"):
        out[mode] = frank_wolfe.fit(
            task, state_fn(), mu=1.0, num_epochs=num_epochs,
            key=jax.random.PRNGKey(1), schedule=schedule, step_size=step_size,
            reducer=reducer, gap_tol=gap_tol, mode=mode,
        )
    return out["scan"], out["legacy"]


def _assert_traj_match(a, b):
    assert a.history["k"] == b.history["k"]
    for key in ("loss", "gap", "sigma", "gamma"):
        np.testing.assert_allclose(a.history[key], b.history[key],
                                   rtol=1e-5, atol=1e-6, err_msg=key)
    np.testing.assert_allclose(a.final_loss, b.final_loss, rtol=1e-5)


@pytest.mark.parametrize("schedule", ["const:2", "log"])
def test_scan_equals_legacy_mtls(schedule):
    x, y = _mtls(jax.random.PRNGKey(0))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    sc, lg = _fit_pair(task, lambda: task.init_state(x, y), schedule=schedule)
    _assert_traj_match(sc, lg)


def test_scan_equals_legacy_logistic_int8():
    """Logistic task + int8 reducer: the stochastic-rounding noise streams
    are keyed by the carried epoch counter, so scan and legacy draw the
    identical noise and the trajectories match."""
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (300, 20))
    yl = jax.random.randint(jax.random.fold_in(key, 1), (300,), 0, 12)
    task = tasks.MultinomialLogistic(d=20, m=12)
    sc, lg = _fit_pair(task, lambda: task.init_state(x, yl),
                       reducer=comm.Int8Reducer(num_workers=1),
                       step_size="default")
    _assert_traj_match(sc, lg)


def test_scan_equals_legacy_matrix_completion():
    key = jax.random.PRNGKey(3)
    d, m, rank = 32, 24, 4
    ku, kv, ko = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
    v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
    w = (u * (jnp.linspace(1.0, 0.3, rank) / rank)) @ v.T
    mask = jax.random.bernoulli(ko, 0.4, (d, m))
    rows, cols = jnp.nonzero(mask)
    idx, yw = tasks.pack_observations(rows, cols, w[rows, cols])
    task = tasks.MatrixCompletion(d=d, m=m)
    sc, lg = _fit_pair(task, lambda: task.init_state(idx, yw))
    _assert_traj_match(sc, lg)


def test_scan_equals_legacy_with_topk_comm_state():
    """Stateful reducer: the error-feedback residuals thread through the
    scan carry exactly as through the per-epoch loop."""
    x, y = _mtls(jax.random.PRNGKey(4))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    sc, lg = _fit_pair(task, lambda: task.init_state(x, y),
                       reducer=comm.TopKReducer(k=6))
    _assert_traj_match(sc, lg)


# ---------------------------------------------------------------------------
# Gap-certificate early stopping
# ---------------------------------------------------------------------------


def test_early_stop_truncates_consistently():
    x, y = _mtls(jax.random.PRNGKey(5))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    full = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0, num_epochs=40,
                           key=jax.random.PRNGKey(1), step_size="linesearch")
    tol = float(full.history["gap"][0]) * 0.4  # loose: fires mid-run
    sc, lg = _fit_pair(task, lambda: task.init_state(x, y), num_epochs=40,
                       gap_tol=tol)
    assert 0 < sc.epochs_run < 40
    assert sc.epochs_run == lg.epochs_run  # scan and legacy stop identically
    for key in ("loss", "gap", "sigma", "gamma", "k"):
        assert len(sc.history[key]) == sc.epochs_run, key
        assert np.all(np.isfinite(np.asarray(sc.history[key], np.float64))), key
    # the stopping epoch is certified; everything before it is not
    assert sc.history["gap"][-1] <= tol
    assert all(g > tol for g in sc.history["gap"][:-1])
    # the prefix matches the untruncated run
    np.testing.assert_allclose(sc.history["loss"],
                               full.history["loss"][: sc.epochs_run], rtol=1e-5)


def test_early_stop_block_epochs_bounds_overshoot():
    """block_epochs caps how far a converged run can scan past its
    certificate: with blocks of 5, at most 4 no-op epochs trail the stop."""
    x, y = _mtls(jax.random.PRNGKey(6))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    full = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0, num_epochs=40,
                           key=jax.random.PRNGKey(1), step_size="linesearch")
    tol = float(full.history["gap"][0]) * 0.4
    res = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0, num_epochs=40,
                          key=jax.random.PRNGKey(1), step_size="linesearch",
                          gap_tol=tol, block_epochs=5)
    assert res.epochs_run < 40
    # the engine never launched segments past the one that converged
    assert res.stats["segments_run"] <= -(-res.epochs_run // 5)


def test_gap_tol_none_runs_everything():
    x, y = _mtls(jax.random.PRNGKey(7))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    res = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0, num_epochs=12,
                          key=jax.random.PRNGKey(1))
    assert res.epochs_run == 12
    assert len(res.history["loss"]) == 12


# ---------------------------------------------------------------------------
# Dispatch / host-sync regression pins (the engine's reason to exist)
# ---------------------------------------------------------------------------


def test_serial_const2_is_two_dispatches_o1_syncs():
    """A 30-epoch const:2 run is one scan dispatch (+ one final-loss eval):
    <= 2 executables, <= 2 dispatches, O(1) explicit device->host transfers,
    and — enforced by the contract's transfer guard — zero implicit per-epoch
    pulls. The bounds are ``engine.dispatch_contract()``'s declaration, not
    this test's: the same Contract backs ``tools/repro_contracts.py``."""
    x, y = _mtls(jax.random.PRNGKey(8))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    state = task.init_state(x, y)
    contract = engine.dispatch_contract()
    with contract.guard():
        res = frank_wolfe.fit(task, state, mu=1.0, num_epochs=30,
                              key=jax.random.PRNGKey(1),
                              step_size="linesearch")
    assert res.epochs_run == 30
    contract.check_stats(res.stats)
    # legacy mode, by contrast, pays per-epoch dispatches and 4 pulls/epoch
    legacy = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0,
                             num_epochs=30, key=jax.random.PRNGKey(1),
                             step_size="linesearch", mode="legacy")
    assert legacy.stats["dispatches"] == 31
    assert legacy.stats["host_syncs"] >= 4 * 30


def test_serial_const2_pin_holds_with_telemetry_enabled():
    """The acceptance bar for the obs spine: a live Telemetry handle keeps
    the exact same dispatch/compile/host-sync stats under the same transfer
    guard — instrumentation rides existing transfers, it never adds one —
    and the trajectory is bit-identical to the uninstrumented run."""
    from repro.obs import Telemetry

    x, y = _mtls(jax.random.PRNGKey(8))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    base = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0, num_epochs=30,
                           key=jax.random.PRNGKey(1), step_size="linesearch")
    tel = Telemetry()
    contract = engine.dispatch_contract()
    with contract.guard():
        res = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0,
                              num_epochs=30, key=jax.random.PRNGKey(1),
                              step_size="linesearch", telemetry=tel)
    assert res.epochs_run == 30
    contract.check_stats(res.stats)
    assert res.stats == base.stats
    np.testing.assert_array_equal(np.asarray(res.history["loss"]),
                                  np.asarray(base.history["loss"]))
    names = {ev["name"] for ev in tel.events()}
    assert {"engine.segment", "engine.dispatch", "comm.exchange"} <= names


def test_log_schedule_is_olog_dispatches():
    n_segments = len(engine.plan_segments("log", 30))
    contract = engine.dispatch_contract(segments=n_segments,
                                        max_compilations=None)
    x, y = _mtls(jax.random.PRNGKey(9))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    with contract.guard():
        res = frank_wolfe.fit(task, task.init_state(x, y), mu=1.0,
                              num_epochs=30, key=jax.random.PRNGKey(1),
                              schedule="log", step_size="linesearch")
    contract.check_stats(res.stats)
    # and the cap is tight: the engine really launches one scan per segment
    assert res.stats["dispatches"] == n_segments + 1


def test_sharded8_const2_is_two_dispatches_o1_syncs():
    """The 8-way pin of the acceptance bar, under the same transfer guard."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import engine, tasks
        from repro.launch import dfw

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        task = tasks.MultiTaskLeastSquares(d=d, m=m)
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=30, schedule="const:2",
                            step_size="linesearch")
        contract = engine.dispatch_contract(name="engine.dispatch[8-way]")
        with contract.guard():
            res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                          num_workers=8)
        assert res.epochs_run == 30
        contract.check_stats(res.stats)
        assert res.history["loss"][-1] < 0.2 * res.history["loss"][0]
        print("sharded 30-epoch const:2 stats OK", res.stats)
    """)
    assert "OK" in out


# ---------------------------------------------------------------------------
# Callback granularity: per segment, not per epoch
# ---------------------------------------------------------------------------


def test_callback_fires_per_segment_with_host_blocks():
    x, y = _mtls(jax.random.PRNGKey(10))
    task = tasks.MultiTaskLeastSquares(d=24, m=18)
    calls = []
    res = frank_wolfe.fit(
        task, task.init_state(x, y), mu=1.0, num_epochs=20,
        key=jax.random.PRNGKey(1), step_size="linesearch", block_epochs=8,
        callback=lambda start, aux: calls.append((start, len(aux.loss),
                                                  np.asarray(aux.loss))),
    )
    assert [(s, n) for s, n, _ in calls] == [(0, 8), (8, 8), (16, 4)]
    # the blocks are the history, in order
    np.testing.assert_allclose(np.concatenate([b for _, _, b in calls]),
                               res.history["loss"], rtol=1e-6)


# ---------------------------------------------------------------------------
# 8-way scan-vs-legacy equivalence: three tasks, dense + int8, stragglers on
# ---------------------------------------------------------------------------


@pytest.mark.slow  # subprocess + 12 fits: the full equivalence matrix
def test_sharded8_scan_equals_legacy_all_tasks():
    out = _run("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import tasks
        from repro.launch import dfw

        def check(task, x, y, cfg, tag):
            runs = {}
            for mode in ("scan", "legacy"):
                runs[mode] = dfw.fit(
                    task, x, y, cfg=dataclasses.replace(cfg, engine=mode),
                    key=jax.random.PRNGKey(1), num_workers=8)
            sc, lg = runs["scan"], runs["legacy"]
            assert sc.history["k"] == lg.history["k"], tag
            for k in ("loss", "gap", "sigma", "gamma"):
                np.testing.assert_allclose(sc.history[k], lg.history[k],
                                           rtol=1e-5, atol=1e-6,
                                           err_msg=f"{tag}:{k}")
            np.testing.assert_allclose(sc.final_loss, lg.final_loss, rtol=1e-5)
            if sc.masks is not None:
                np.testing.assert_allclose(np.asarray(sc.masks),
                                           np.asarray(lg.masks))
            print(tag, "OK")

        n, d, m = 1600, 40, 30
        key = jax.random.PRNGKey(0)
        kx, kw = jax.random.split(key)
        W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
        X = jax.random.normal(kx, (n, d)); Y = X @ W
        yl = jnp.argmax(X @ W, axis=1)

        # straggler sampling ON for the whole matrix: masks are indexed
        # inside the scan, so this exercises the (num_epochs, nw) path
        base = dfw.DFWConfig(mu=1.0, num_epochs=8, schedule="const:2",
                             step_size="linesearch", sample_prob=0.7)
        mtls = tasks.MultiTaskLeastSquares(d=d, m=m)
        for comm in ("dense", "int8"):
            check(mtls, X, Y, dataclasses.replace(base, comm=comm),
                  f"mtls/{comm}")

        logi = tasks.MultinomialLogistic(d=d, m=m)
        lcfg = dfw.DFWConfig(mu=10.0, num_epochs=8, schedule="log",
                             sample_prob=0.7)
        for comm in ("dense", "int8"):
            check(logi, X, yl, dataclasses.replace(lcfg, comm=comm),
                  f"logistic/{comm}")

        d2, m2, rank = 64, 48, 5
        ku, kv, ko = jax.random.split(jax.random.PRNGKey(7), 3)
        U = jnp.linalg.qr(jax.random.normal(ku, (d2, rank)))[0]
        V = jnp.linalg.qr(jax.random.normal(kv, (m2, rank)))[0]
        sv = jnp.linspace(1.0, 0.2, rank); sv = sv / jnp.sum(sv)
        Wmc = (U * sv) @ V.T
        msk = jax.random.bernoulli(ko, 0.35, (d2, m2))
        rows, cols = jnp.nonzero(msk)
        idx8, yw8 = dfw.shard_observations(rows, cols, Wmc[rows, cols], 8,
                                           d2, m=m2)
        mc = tasks.MatrixCompletion(d=d2, m=m2)
        mcfg = dfw.DFWConfig(mu=1.5, num_epochs=8, schedule="const:2",
                             step_size="linesearch", sample_prob=0.7)
        for comm in ("dense", "int8"):
            check(mc, idx8, yw8, dataclasses.replace(mcfg, comm=comm),
                  f"mc/{comm}")

        # early stop agrees across modes in the sharded driver too
        ecfg = dfw.DFWConfig(mu=1.0, num_epochs=40, schedule="const:2",
                             step_size="linesearch")
        probe = dfw.fit(mtls, X, Y, cfg=ecfg, key=jax.random.PRNGKey(1),
                        num_workers=8)
        tol = float(probe.history["gap"][0]) * 0.4
        ecfg = dataclasses.replace(ecfg, gap_tol=tol)
        es = dfw.fit(mtls, X, Y, cfg=ecfg, key=jax.random.PRNGKey(1),
                     num_workers=8)
        el = dfw.fit(mtls, X, Y,
                     cfg=dataclasses.replace(ecfg, engine="legacy"),
                     key=jax.random.PRNGKey(1), num_workers=8)
        assert 0 < es.epochs_run < 40
        assert es.epochs_run == el.epochs_run
        assert len(es.history["loss"]) == es.epochs_run
        print("early-stop sharded OK", es.epochs_run)
        print("equivalence matrix OK")
    """, timeout=1200)
    assert "equivalence matrix OK" in out
