"""Hypothesis property tests on the system's core invariants."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st  # noqa: E402
import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given

from repro.core import low_rank, tasks
from repro.core.trace_norm import trace_norm as exact_trace_norm

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=25, derandomize=True
)
hypothesis.settings.load_profile("ci")

dims = st.integers(min_value=2, max_value=12)
gammas = st.lists(st.floats(0.01, 1.0), min_size=1, max_size=6)
seeds = st.integers(min_value=0, max_value=2**31 - 1)


def _rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape)


# ---------------------------------------------------------------------------
# FactoredIterate invariants
# ---------------------------------------------------------------------------


@given(d=dims, m=dims, gs=gammas, seed=seeds)
def test_factored_store_matches_dense_recurrence(d, m, gs, seed):
    """alpha/s bookkeeping == literal dense FW recurrence for any gamma seq."""
    mu = 1.7
    it = low_rank.init(len(gs), d, m)
    w = jnp.zeros((d, m))
    for i, g in enumerate(gs):
        u = _rand(seed + 2 * i, (d,))
        u = u / jnp.linalg.norm(u)
        v = _rand(seed + 2 * i + 1, (m,))
        v = v / jnp.linalg.norm(v)
        it = low_rank.fw_update(it, u, v, jnp.float32(g), mu)
        w = (1 - g) * w + g * (-mu) * jnp.outer(u, v)
    np.testing.assert_allclose(low_rank.materialize(it), w, rtol=2e-3, atol=2e-4)


@given(d=dims, m=dims, gs=gammas, seed=seeds)
def test_factored_iterate_stays_feasible(d, m, gs, seed):
    """Any convex combination of -mu u v^T stays in the mu trace-norm ball."""
    mu = 2.5
    it = low_rank.init(len(gs), d, m)
    for i, g in enumerate(gs):
        u = _rand(seed + 3 * i, (d,))
        u = u / jnp.linalg.norm(u)
        v = _rand(seed + 3 * i + 1, (m,))
        v = v / jnp.linalg.norm(v)
        it = low_rank.fw_update(it, u, v, jnp.float32(g), mu)
    w = low_rank.materialize(it)
    assert float(exact_trace_norm(w)) <= mu * (1 + 1e-4)
    # factored upper bound dominates
    assert float(low_rank.trace_norm_upper_bound(it)) >= float(
        exact_trace_norm(w)) - 1e-4


@given(d=dims, m=dims, seed=seeds)
def test_factored_matvec_agrees_with_dense(d, m, seed):
    it = low_rank.init(4, d, m)
    for i in range(3):
        u = _rand(seed + 5 * i, (d,))
        u = u / jnp.linalg.norm(u)
        v = _rand(seed + 5 * i + 1, (m,))
        v = v / jnp.linalg.norm(v)
        it = low_rank.fw_update(it, u, v, jnp.float32(0.3), 1.0)
    w = low_rank.materialize(it)
    x = _rand(seed + 100, (m,))
    xt = _rand(seed + 101, (d,))
    xm = _rand(seed + 102, (7, d))
    np.testing.assert_allclose(low_rank.matvec(it, x), w @ x, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(low_rank.rmatvec(it, xt), w.T @ xt, rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(
        low_rank.right_multiply(it, xm), xm @ w, rtol=2e-3, atol=2e-4)


@given(d=dims, m=dims, max_rank=st.integers(1, 8), live=st.integers(0, 8),
       extra=st.integers(0, 5), seed=seeds)
def test_pack_unpack_roundtrip_at_any_live_rank(d, m, max_rank, live, extra, seed):
    """pack_live -> unpack_live is bit-exact at every live rank — empty,
    partial, and full capacity — and re-pads to any larger capacity."""
    live = min(live, max_rank)
    it = low_rank.init(max_rank, d, m)
    for i in range(live):
        u = _rand(seed + 2 * i, (d,))
        u = u / jnp.linalg.norm(u)
        v = _rand(seed + 2 * i + 1, (m,))
        v = v / jnp.linalg.norm(v)
        it = low_rank.fw_update(it, u, v, jnp.float32(0.4), 1.5)
    packed = low_rank.pack_live(it)
    assert packed["u"].shape == (live, d) and packed["s"].shape == (live,)
    back = low_rank.unpack_live(packed, max_rank)
    for got, want in zip(back, it):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    # re-pad to a larger capacity: same matrix, zero tail rows
    wide = low_rank.unpack_live(packed, max_rank + extra)
    np.testing.assert_array_equal(
        np.asarray(low_rank.materialize(wide)),
        np.asarray(low_rank.materialize(it)))
    assert not np.any(np.asarray(wide.u)[live:])
    if live > max_rank - 1 and extra == 0 and live > 0:
        with pytest.raises(ValueError, match="max_rank"):
            low_rank.unpack_live(packed, live - 1)


@given(d=dims, m=dims, bt=st.integers(1, 9), live=st.integers(0, 5),
       transpose=st.booleans(), dt=st.sampled_from(["float32", "bfloat16"]),
       seed=seeds)
def test_factor_scoring_matches_dense_oracle(d, m, bt, live, transpose, dt, seed):
    """Factor-form scoring (the serving hot path) == X @ (U^T diag(s) V) for
    random ranks, batch shapes, dtypes, and both scoring directions."""
    from repro.kernels import factor_matvec

    dtype = jnp.bfloat16 if dt == "bfloat16" else jnp.float32
    it = low_rank.init(max(live, 1), d, m)
    for i in range(live):
        u = _rand(seed + 3 * i, (d,))
        u = u / jnp.linalg.norm(u)
        v = _rand(seed + 3 * i + 1, (m,))
        v = v / jnp.linalg.norm(v)
        it = low_rank.fw_update(it, u, v, jnp.float32(0.35), 2.0)
    w = np.asarray(low_rank.materialize(it), np.float32)
    x = _rand(seed + 99, (bt, m if transpose else d)).astype(dtype)
    a, b = (it.v, it.u) if transpose else (it.u, it.v)
    got = factor_matvec.factor_matvec(
        x, a.astype(dtype), it.s, b.astype(dtype), alpha=it.alpha)
    want = np.asarray(x, np.float32) @ (w.T if transpose else w)
    tol = dict(rtol=5e-2, atol=5e-2) if dt == "bfloat16" else dict(rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got), want, **tol)


# ---------------------------------------------------------------------------
# Task operator invariants (implicit gradient == dense gradient)
# ---------------------------------------------------------------------------


@given(seed=seeds, n=st.integers(4, 30), d=dims, m=dims)
def test_mtls_operator_consistency(seed, n, d, m):
    x = _rand(seed, (n, d))
    y = _rand(seed + 1, (n, m))
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    s = task.init_state(x, y)
    g = np.asarray(task.local_grad(s))
    v = _rand(seed + 2, (m,))
    u = _rand(seed + 3, (d,))
    np.testing.assert_allclose(task.matvec(s, v), g @ np.asarray(v), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(task.rmatvec(s, u), g.T @ np.asarray(u), rtol=2e-3, atol=2e-3)
    # <W, grad> with W=0 must be 0 at init
    assert float(task.inner_w_grad(s)) == 0.0


@given(seed=seeds, n=st.integers(4, 30), d=dims, m=st.integers(3, 12))
def test_logistic_operator_consistency(seed, n, d, m):
    x = _rand(seed, (n, d))
    yv = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, m)
    task = tasks.MultinomialLogistic(d=d, m=m)
    s = task.init_state(x, yv)
    g = np.asarray(task.local_grad(s))
    v = _rand(seed + 2, (m,))
    u = _rand(seed + 3, (d,))
    np.testing.assert_allclose(task.matvec(s, v), g @ np.asarray(v), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(task.rmatvec(s, u), g.T @ np.asarray(u), rtol=2e-3, atol=2e-3)


@given(seed=seeds, n=st.integers(4, 20), d=dims, m=dims,
       g1=st.floats(0.05, 1.0), g2=st.floats(0.05, 1.0))
def test_mtls_recursive_update_equals_recompute(seed, n, d, m, g1, g2):
    """App-B sufficient-information recursion == recompute from scratch."""
    mu = 1.3
    x = _rand(seed, (n, d))
    y = _rand(seed + 1, (n, m))
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    s = task.init_state(x, y)
    w = jnp.zeros((d, m))
    for i, g in enumerate((g1, g2)):
        u = _rand(seed + 7 * i, (d,))
        u = u / jnp.linalg.norm(u)
        v = _rand(seed + 7 * i + 1, (m,))
        v = v / jnp.linalg.norm(v)
        s = task.update(s, u, v, jnp.float32(g), mu)
        w = (1 - g) * w + g * (-mu) * jnp.outer(u, v)
    np.testing.assert_allclose(s.r, x @ w - y, rtol=3e-3, atol=3e-3)


@given(seed=seeds, n=st.integers(4, 20), d=dims, m=st.integers(3, 10),
       g1=st.floats(0.05, 1.0))
def test_logistic_recursive_update_equals_recompute(seed, n, d, m, g1):
    mu = 2.0
    x = _rand(seed, (n, d))
    yv = jax.random.randint(jax.random.PRNGKey(seed + 1), (n,), 0, m)
    task = tasks.MultinomialLogistic(d=d, m=m)
    s = task.init_state(x, yv)
    u = _rand(seed + 3, (d,))
    u = u / jnp.linalg.norm(u)
    v = _rand(seed + 4, (m,))
    v = v / jnp.linalg.norm(v)
    s = task.update(s, u, v, jnp.float32(g1), mu)
    w = g1 * (-mu) * jnp.outer(u, v)
    np.testing.assert_allclose(s.z, x @ w, rtol=3e-3, atol=3e-3)


# ---------------------------------------------------------------------------
# Data pipeline invariants
# ---------------------------------------------------------------------------


@given(step=st.integers(0, 10_000), host=st.integers(0, 3))
def test_data_pipeline_deterministic(step, host):
    from repro.configs import get_config
    from repro.data import SyntheticLMStream
    from repro.models.config import ShapeSpec

    cfg = get_config("qwen2_1_5b", smoke=True)
    shape = ShapeSpec("t", "train", 32, 8)
    s1 = SyntheticLMStream(cfg, shape, host_id=host, num_hosts=4)
    s2 = SyntheticLMStream(cfg, shape, host_id=host, num_hosts=4)
    b1, b2 = s1.batch_for_step(step), s2.batch_for_step(step)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].min() >= 0 and b1["tokens"].max() < cfg.vocab_size
    # label alignment: labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])
