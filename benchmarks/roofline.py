"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch x shape x mesh) cell, from experiments/dryrun/*.json:

  compute term    = HLO_FLOPs_per_device / peak_FLOP/s      (197e12 bf16, v5e)
  memory term     = dot_bytes_per_device / HBM_bw           (819e9 B/s)
  collective term = wire_bytes_per_device / link_bw         (50e9 B/s ICI;
                    the 'pod' axis share would ride DCN — single-pod table
                    per assignment)

Sources: FLOPs and dot-bytes from the trip-count-aware HLO walker
(src/repro/analysis/hlo.py — XLA's cost_analysis visits scan bodies once, so it
is NOT usable directly); collective bytes from the partitioned HLO with ring
factors (all-reduce 2x). MODEL_FLOPS = 6ND (train) / 2ND (inference), MoE
active-params, embeddings + attention excluded (standard convention).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

PEAK = 197e12
HBM = 819e9
ICI = 50e9

DRYRUN_DIR = Path(__file__).resolve().parent.parent / "experiments" / "dryrun"


def load_cells(dryrun_dir: Path = DRYRUN_DIR, mesh: Optional[str] = "pod16x16") -> List[Dict]:
    cells = []
    for f in sorted(dryrun_dir.glob("*.json")):
        data = json.loads(f.read_text())
        if mesh is not None and data.get("mesh") != mesh:
            continue
        cells.append(data)
    return cells


def terms(cell: Dict) -> Dict:
    n_dev = cell["n_devices"]
    flops_dev = cell["hlo"]["flops"]
    dot_bytes_dev = cell["hlo"]["dot_bytes"]
    coll_dev = cell["hlo"]["collective_bytes_total"]

    t_compute = flops_dev / PEAK
    t_memory = dot_bytes_dev / HBM
    t_coll = coll_dev / ICI
    dominant = max(
        (("compute", t_compute), ("memory", t_memory), ("collective", t_coll)),
        key=lambda kv: kv[1],
    )[0]
    model_flops = cell["model_flops"]
    hlo_total = flops_dev * n_dev
    useful = model_flops / hlo_total if hlo_total else 0.0
    # roofline fraction: useful model FLOPs per second achievable if the step
    # ran at the max of the three terms, vs the all-chips peak
    t_bound = max(t_compute, t_memory, t_coll)
    frac = (model_flops / t_bound) / (n_dev * PEAK) if t_bound else 0.0
    return {
        "arch": cell["arch"],
        "shape": cell["shape"],
        "mesh": cell["mesh"],
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": useful,
        "roofline_fraction": frac,
        "temp_gib": cell["memory"]["temp_bytes"] / 2**30,
        "args_gib": cell["memory"]["argument_bytes"] / 2**30,
    }


def table(dryrun_dir: Path = DRYRUN_DIR, mesh: str = "pod16x16") -> List[Dict]:
    return [terms(c) for c in load_cells(dryrun_dir, mesh)]


def markdown(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | t_comp (s) | t_mem (s) | t_coll (s) | bound | "
           "useful | roofline | temp GiB |\n"
           "|---|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']:.3f} | "
            f"{r['t_memory_s']:.3f} | {r['t_collective_s']:.3f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']*100:.1f}% | {r['temp_gib']:.1f} |\n"
        )
    return "".join(out)


def run():
    from .common import emit

    for mesh in ("pod16x16", "pod2x16x16"):
        rows = table(mesh=mesh)
        for r in rows:
            emit(
                f"roofline.{mesh}.{r['arch']}.{r['shape']}",
                0.0,
                f"t_comp={r['t_compute_s']:.3f};t_mem={r['t_memory_s']:.3f};"
                f"t_coll={r['t_collective_s']:.3f};bound={r['dominant']};"
                f"useful={r['useful_ratio']:.2f};"
                f"roofline={r['roofline_fraction']*100:.1f}%",
            )
        if not rows:
            emit(f"roofline.{mesh}", 0.0, "NO_DRYRUN_ARTIFACTS(run launch/dryrun.py)")


if __name__ == "__main__":
    import sys

    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
    print(markdown(table(mesh=mesh)))
