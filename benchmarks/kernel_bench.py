"""Kernel microbench: FLOPs / HBM bytes / arithmetic intensity per kernel
config (the TPU-relevant numbers) + CPU ref-path wall time as a smoke check."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention, power_matvec, rank1_update

from .common import emit, time_call


def run():
    # power_matvec: A(n,m)@v — bandwidth-bound, AI ~ 0.5 FLOP/B in f32
    for n, m in ((4096, 2048), (16384, 2048)):
        a = jax.random.normal(jax.random.PRNGKey(0), (n, m))
        v = jax.random.normal(jax.random.PRNGKey(1), (m,))
        us = time_call(lambda: power_matvec.matvec(a, v, use_pallas=False))
        flops = 2 * n * m
        bytes_ = 4 * (n * m + n + m)
        emit(f"kern.matvec.{n}x{m}", us,
             f"flops={flops:.2e};hbm_bytes={bytes_:.2e};AI={flops/bytes_:.2f}")

    # rank1_update fused vs unfused traffic
    n, m = 4096, 2048
    z = jax.random.normal(jax.random.PRNGKey(2), (n, m))
    xv = jax.random.normal(jax.random.PRNGKey(3), (n,))
    yv = jax.random.normal(jax.random.PRNGKey(4), (m,))
    us = time_call(lambda: rank1_update.rank1_update(z, xv, yv, 0.9, -0.1,
                                                     use_pallas=False))
    fused = 4 * (2 * n * m)
    unfused = 4 * (4 * n * m)
    emit(f"kern.rank1.{n}x{m}", us,
         f"fused_bytes={fused:.2e};unfused_bytes={unfused:.2e};saving={unfused/fused:.1f}x")

    # flash attention: FLOPs and VMEM working set per block config
    b, hq, hkv, s, dh = 1, 8, 2, 2048, 128
    q = jax.random.normal(jax.random.PRNGKey(5), (b, hq, s, dh), jnp.bfloat16)
    k = jax.random.normal(jax.random.PRNGKey(6), (b, hkv, s, dh), jnp.bfloat16)
    v = jax.random.normal(jax.random.PRNGKey(7), (b, hkv, s, dh), jnp.bfloat16)
    us = time_call(lambda: flash_attention.flash_attention(
        q, k, v, scale=dh**-0.5, causal=True, use_pallas=False))
    flops = 4 * b * hq * s * s * dh  # qk^T + pv
    for bq, bk in ((128, 128), (256, 512)):
        vmem = 2 * (bq * dh + 2 * bk * dh) + 4 * (bq * dh + 2 * bq)  # bf16 io + f32 acc
        emit(f"kern.flash.s{s}.bq{bq}.bk{bk}", us,
             f"flops={flops:.2e};vmem_bytes={vmem:.2e};"
             f"fits_vmem={vmem < 16 * 2**20}")
