# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV; ``--json PATH`` additionally dumps every row as a structured record
# (suite, parsed derived metrics, jax/device metadata) for the perf
# trajectory and the CI regression gate (benchmarks/check_regression.py).
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None, help="run a single benchmark module")
    ap.add_argument("--fast", action="store_true", help="smaller sizes")
    ap.add_argument(
        "--json", default=None, metavar="PATH",
        help="write structured records (BENCH_<name>.json) besides the CSV",
    )
    args = ap.parse_args()

    from . import (
        block_fw_convergence,
        comm_cost,
        dfw_scaling,
        engine_bench,
        gossip_consensus,
        imagenet_head,
        kernel_bench,
        logistic_convergence,
        matrix_completion,
        mtls_convergence,
        power_accuracy,
        roofline,
        scaling,
        serving_latency,
    )

    suites = {
        "table1_comm_cost": comm_cost.run,
        "table1_comm_sweep": (lambda: comm_cost.run_sweep(fast=True))
        if args.fast else comm_cost.run_sweep,
        # gossip_consensus keeps the gated hier.inter_bytes record at the
        # same sizes in --fast: it is an HLO byte ratio of one compiled
        # exchange, immune to runner speed; only the fit epochs shrink.
        "gossip_consensus": (lambda: gossip_consensus.run(fast=True))
        if args.fast else gossip_consensus.run,
        "fig1_mtls": (lambda: mtls_convergence.run(epochs=15, n=8000, d=128, m=128))
        if args.fast else mtls_convergence.run,
        "fig2_logistic": (lambda: logistic_convergence.run(epochs=12, n=4000, d=96, m=48))
        if args.fast else logistic_convergence.run,
        "fig3_imagenet_head": (lambda: imagenet_head.run(epochs=15, m=50, tokens=2048))
        if args.fast else imagenet_head.run,
        "fig4_scaling": scaling.run,
        "fig4_dfw_scaling": (lambda: dfw_scaling.run(n=2048, d=64, m=32, epochs=5))
        if args.fast else dfw_scaling.run,
        "fig5_matrix_completion": (
            lambda: matrix_completion.run(d=128, m=96, obs=0.3, epochs=8))
        if args.fast else matrix_completion.run,
        "engine_overhead": (lambda: engine_bench.run(epochs=96, block=24))
        if args.fast else engine_bench.run,
        # serving_latency keeps Table-1 sizes even in --fast: the gated
        # record IS the rank=d/8 point at d=m=1024; only repetitions shrink.
        "serving_latency": (
            lambda: serving_latency.run(ranks=(16, 128), dispatches=15))
        if args.fast else serving_latency.run,
        # block_fw_convergence keeps Table-1 sizes even in --fast: the
        # gated epochs_to_gap.speedup records ARE the d=m=1024 cells (the
        # metric is an epoch-count ratio, immune to runner speed).
        "block_fw_convergence": block_fw_convergence.run,
        "thm2_power_accuracy": power_accuracy.run,
        "kernels": kernel_bench.run,
        "roofline": roofline.run,
    }
    if args.only:
        suites = {args.only: suites[args.only]}

    from . import common

    print("name,us_per_call,derived")
    failures = []
    for name, fn in suites.items():
        print(f"# === {name} ===", flush=True)
        common.begin_suite(name)
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            # Through emit, not print: a failed suite must show up in the
            # JSON dump too, or the regression gate would read its absence
            # as "nothing to check" instead of "broken".
            common.emit(name, 0.0, f"FAILED({type(e).__name__}:{e})")
    common.begin_suite(None)
    if args.json:
        common.write_json(args.json)
    if failures:
        sys.exit(f"benchmark suites failed: {failures}")


if __name__ == "__main__":
    main()
