"""Paper Table 1: communication cost per epoch.

Analytic bytes-per-epoch for the three strategies at the paper's sizes, plus
a MEASURED check: the collective bytes of one sharded DFW-TRACE epoch counted
from the compiled HLO on an 8-device mesh (subprocess; cached to a JSON file).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import emit

F32 = 4


def analytic(n_workers: int, d: int, m: int, k: int):
    return {
        "naive_dfw": n_workers * d * m * F32,
        "sva": n_workers * (d + m) * F32,
        "dfw_trace": 2 * n_workers * k * (d + m) * F32,
    }


_MEASURE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import tasks, frank_wolfe, low_rank
from repro.launch import hlo_analysis
from repro.compat import shard_map_compat

n, d, m, K = 1024, 256, 128, 2
task = tasks.MultiTaskLeastSquares(d=d, m=m)
mesh = jax.make_mesh((8,), ("data",))
ss = tasks.MTLSState(x=P("data"), y=P("data"), r=P("data"))
isp = low_rank.FactoredIterate(u=P(), s=P(), v=P(), alpha=P(), count=P())
asp = frank_wolfe.EpochAux(P(), P(), P(), P())
step = frank_wolfe.make_epoch_step(task, 1.0, K, step_size="linesearch",
                                   axis_name="data")
wrapped = shard_map_compat(step, mesh, in_specs=(ss, isp, P(), P()),
                           out_specs=(ss, isp, asp))
x = jax.ShapeDtypeStruct((n, d), jnp.float32)
y = jax.ShapeDtypeStruct((n, m), jnp.float32)
st = tasks.MTLSState(x=x, y=y, r=y)
it = jax.eval_shape(lambda: low_rank.init(30, d, m))
comp = jax.jit(wrapped).lower(st, it, jax.ShapeDtypeStruct((), jnp.float32),
                              jax.ShapeDtypeStruct((2,), jnp.uint32)).compile()
res = hlo_analysis.analyze(comp.as_text())
print(json.dumps({"collective_bytes": res["collective_bytes_total"],
                  "counts": res["collective_count"],
                  "d": d, "m": m, "K": K}))
"""


def measure_epoch_collectives(cache: Path) -> dict:
    if cache.exists():
        return json.loads(cache.read_text())
    src = str(Path(__file__).resolve().parent.parent / "src")
    script = _MEASURE_SCRIPT.replace("SRC", src)
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=600, env=env)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    data = json.loads(out.stdout.strip().splitlines()[-1])
    cache.parent.mkdir(parents=True, exist_ok=True)
    cache.write_text(json.dumps(data))
    return data


def run():
    # paper-size analytic table (d=m=1000, N=96 logical workers, K=2)
    a = analytic(96, 1000, 1000, 2)
    emit("table1.naive_dfw.bytes", 0.0, f"bytes={a['naive_dfw']:.3e}")
    emit("table1.sva.bytes", 0.0, f"bytes={a['sva']:.3e}")
    emit("table1.dfw_trace.bytes", 0.0,
         f"bytes={a['dfw_trace']:.3e};saving_vs_naive={a['naive_dfw']/a['dfw_trace']:.0f}x")

    # measured: one DFW-TRACE epoch on 8 devices, HLO-counted wire bytes
    try:
        meas = measure_epoch_collectives(
            Path(__file__).resolve().parent.parent
            / "experiments" / "bench_cache" / "comm_cost.json")
        d, m, k = meas["d"], meas["m"], meas["K"]
        # per-device analytic: 2K psums of (d,)+(m,) vectors (+1 sigma psum of m)
        # all-reduce wire factor 2 -> 2 * (2K+1 vectors)
        expect = 2 * F32 * ((2 * k + 1) * m + k * d + d)  # u:(d) k times, v:(m) k+?
        emit("table1.measured_dfw_epoch", 0.0,
             f"hlo_bytes={meas['collective_bytes']:.3e};counts={meas['counts']}")
    except Exception as e:  # noqa: BLE001
        emit("table1.measured_dfw_epoch", 0.0, f"SKIPPED({type(e).__name__})")
