"""Paper Table 1: communication cost per epoch — now with a comm= axis.

Three layers, all emitted as CSV rows:

1. the paper-size *analytic* table (naive-DFW vs SVA vs DFW-TRACE),
2. an HLO-*measured* bytes-per-epoch table for one sharded DFW-TRACE epoch on
   an 8-device mesh under each ``comm=`` reducer (dense / int8 / topk:r),
   cross-checked against the reducers' own analytic ``wire_bytes`` — the
   measured row carries the analytic expectation and the relative delta, so
   a regression in either the epoch's collective count or the HLO walker
   shows up as a nonzero delta,
3. a convergence-vs-bits sweep (``run_sweep``): 8-way MTLS and
   matrix-completion fits under each reducer, reporting final loss relative
   to dense next to the measured bytes ratio — the acceptance numbers
   (int8: <= 2% loss delta at >= 3x fewer bytes) come from here.

Subprocesses own all multi-device work (the parent pytest/bench process
locks the CPU device count at first jax init); results are cached to a
versioned JSON keyed by the exact parameters.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import emit

F32 = 4
_CACHE_VERSION = 3  # bump when the measured quantities change meaning

COMM_MODES = ("dense", "int8", "topk:16")


def analytic(n_workers: int, d: int, m: int, k: int):
    return {
        "naive_dfw": n_workers * d * m * F32,
        "sva": n_workers * (d + m) * F32,
        "dfw_trace": 2 * n_workers * k * (d + m) * F32,
    }


def expect_epoch_bytes(comm: str, d: int, m: int, k: int, n_workers: int) -> int:
    """Analytic per-device wire bytes of one epoch (ring all-reduce 2x,
    all-gather 1x — the same conventions as repro.analysis.hlo): K vector
    exchanges of (d,) and (m,) through the reducer plus the four exact f32
    scalar psums (loss, <W,grad>, line-search numerator/denominator)."""
    from repro.comm import make_reducer

    r = make_reducer(comm, num_workers=n_workers)
    vectors = k * (r.wire_bytes(d, n_workers) + r.wire_bytes(m, n_workers))
    scalars = 4 * 2 * F32
    return vectors + scalars


_MEASURE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp
from repro.core import tasks, low_rank, frank_wolfe
from repro.analysis import hlo as hlo_analysis
from repro.launch import dfw
from repro import comm as comm_lib

P = json.loads('PARAMS')
n, d, m, K, nw = P["n"], P["d"], P["m"], P["K"], P["workers"]
mesh = jax.make_mesh((nw,), ("data",))
if P.get("task", "mtls") == "mc":
    # COO completion state: p observed-entry slots per epoch; the epoch's
    # collectives ((d,)/(m,) vector reduces + 4 scalars) match MTLS's.
    task = tasks.MatrixCompletion(d=d, m=m)
    ent = jax.ShapeDtypeStruct((n,), jnp.float32)
    idx = jax.ShapeDtypeStruct((n,), jnp.int32)
    st = tasks.MCState(rows=idx, cols=idx, vals=ent, resid=ent, weight=ent)
else:
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    x = jax.ShapeDtypeStruct((n, d), jnp.float32)
    y = jax.ShapeDtypeStruct((n, m), jnp.float32)
    st = tasks.MTLSState(x=x, y=y, r=y)
it = jax.eval_shape(lambda: low_rank.init(30, d, m))
mask = jax.ShapeDtypeStruct((nw,), jnp.float32)

out = {}
for cm in P["modes"]:
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=1, schedule=f"const:{K}",
                        step_size="linesearch", comm=cm)
    red = comm_lib.make_reducer(cm, num_workers=nw)
    ep = dfw.make_sharded_epoch(task, cfg, mesh, K, state_example=st,
                                reducer=red)
    carry = frank_wolfe.EpochCarry(
        state=st, iterate=it,
        comm_state=jax.tree.map(
            lambda l: jax.ShapeDtypeStruct((nw,) + l.shape, l.dtype),
            red.init_state(d, m)),
        t=jax.ShapeDtypeStruct((), jnp.int32),
        key=jax.ShapeDtypeStruct((2,), jnp.uint32))
    comp = jax.jit(ep).lower(carry, mask).compile()
    res = hlo_analysis.analyze(comp.as_text())
    out[cm] = {"collective_bytes": res["collective_bytes_total"],
               "counts": res["collective_count"]}
print(json.dumps(out))
"""


_SWEEP_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "SRC")
import dataclasses
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

P = json.loads('PARAMS')
nw, epochs = P["workers"], P["epochs"]
out = {}

# --- 8-way MTLS ---
n, d, m = P["n"], P["d"], P["m"]
key = jax.random.PRNGKey(0)
kx, kw = jax.random.split(key)
W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
X = jax.random.normal(kx, (n, d)); Y = X @ W
task = tasks.MultiTaskLeastSquares(d=d, m=m)
base = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule="const:2",
                     step_size="linesearch")
out["mtls"] = {}
for cm in P["modes"]:
    cfg = dataclasses.replace(base, comm=cm)
    res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                  num_workers=nw)
    out["mtls"][cm] = res.final_loss

# --- 8-way matrix completion ---
d2, m2, rank = P["mc_d"], P["mc_m"], 5
ku, kv, ko = jax.random.split(jax.random.PRNGKey(7), 3)
U = jnp.linalg.qr(jax.random.normal(ku, (d2, rank)))[0]
V = jnp.linalg.qr(jax.random.normal(kv, (m2, rank)))[0]
sv = jnp.linspace(1.0, 0.2, rank); sv = sv / jnp.sum(sv)
Wmc = (U * sv) @ V.T
mask = jax.random.bernoulli(ko, 0.35, (d2, m2))
rows, cols = jnp.nonzero(mask)
vals = Wmc[rows, cols]
mtask = tasks.MatrixCompletion(d=d2, m=m2)
mcfg = dfw.DFWConfig(mu=1.5, num_epochs=epochs, schedule="const:2",
                     step_size="linesearch")
idx, yw = dfw.shard_observations(rows, cols, vals, nw, d2, m=m2)
out["mc"] = {}
for cm in P["modes"]:
    cfg = dataclasses.replace(mcfg, comm=cm)
    res = dfw.fit(mtask, idx, yw, cfg=cfg, key=jax.random.PRNGKey(2),
                  num_workers=nw)
    out["mc"][cm] = res.final_loss
print(json.dumps(out))
"""


def _run_subprocess(template: str, params: dict) -> dict:
    src = str(Path(__file__).resolve().parent.parent / "src")
    script = template.replace("SRC", src).replace("PARAMS", json.dumps(params))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=1200, env=env)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def _cached(cache: Path, section: str, params: dict, template: str) -> dict:
    """Per-section subprocess cache, invalidated by version + exact params."""
    blob = {}
    if cache.exists():
        try:
            blob = json.loads(cache.read_text())
        except json.JSONDecodeError:
            blob = {}
    if blob.get("version") != _CACHE_VERSION:
        # Drop the whole blob: re-stamping the version while keeping other
        # sections would let their stale data masquerade as current.
        blob = {"version": _CACHE_VERSION}
    entry = blob.get(section)
    if entry is not None and entry.get("params") == params:
        return entry["data"]
    data = _run_subprocess(template, params)
    blob[section] = {"params": params, "data": data}
    cache.parent.mkdir(parents=True, exist_ok=True)
    cache.write_text(json.dumps(blob))
    return data


def _cache_path() -> Path:
    return (Path(__file__).resolve().parent.parent
            / "experiments" / "bench_cache" / "comm_cost.json")


def run():
    # paper-size analytic table (d=m=1000, N=96 logical workers, K=2)
    a = analytic(96, 1000, 1000, 2)
    emit("table1.naive_dfw.bytes", 0.0, f"bytes={a['naive_dfw']:.3e}")
    emit("table1.sva.bytes", 0.0, f"bytes={a['sva']:.3e}")
    emit("table1.dfw_trace.bytes", 0.0,
         f"bytes={a['dfw_trace']:.3e};saving_vs_naive={a['naive_dfw']/a['dfw_trace']:.0f}x")

    # measured: one DFW-TRACE epoch on 8 devices per comm mode, HLO-counted
    # wire bytes, checked against the reducers' analytic expectation
    params = {"n": 1024, "d": 256, "m": 128, "K": 2, "workers": 8,
              "modes": list(COMM_MODES)}
    try:
        meas = _cached(_cache_path(), "measure", params, _MEASURE_SCRIPT)
        dense_bytes = meas["dense"]["collective_bytes"]
        for cm in params["modes"]:
            got = meas[cm]["collective_bytes"]
            expect = expect_epoch_bytes(
                cm, params["d"], params["m"], params["K"], params["workers"])
            delta = (got - expect) / expect
            emit(
                f"table1.measured_epoch.{cm}", 0.0,
                f"hlo_bytes={got:.3e};expect_bytes={expect:.3e};"
                f"rel_delta={delta:+.3f};ratio_vs_dense={dense_bytes / got:.2f}x;"
                f"counts={meas[cm]['counts']}",
            )
    except Exception as e:  # noqa: BLE001
        emit("table1.measured_epoch", 0.0, f"SKIPPED({type(e).__name__})")


def run_sweep(fast: bool = False):
    """Convergence-vs-bits: final loss under each reducer relative to dense,
    alongside the bytes ratio HLO-measured *at that bench's own sizes* (the
    PR's acceptance numbers). Pairing a loss with a ratio from a different
    problem size would invert the conclusion for top-k, whose saving depends
    on N*r vs dim."""
    params = {
        "workers": 8,
        "epochs": 8 if fast else 15,
        "n": 800 if fast else 1600,
        "d": 40, "m": 30, "mc_d": 64, "mc_m": 48,
        "modes": list(COMM_MODES),
    }
    # HLO measurement configs matching each sweep bench's epoch exactly.
    mparams = {
        "mtls": {"task": "mtls", "n": params["n"], "d": params["d"],
                 "m": params["m"], "K": 2, "workers": 8,
                 "modes": list(COMM_MODES)},
        "mc": {"task": "mc", "n": 2048, "d": params["mc_d"],
               "m": params["mc_m"], "K": 2, "workers": 8,
               "modes": list(COMM_MODES)},
    }
    try:
        sweep = _cached(_cache_path(), "sweep_fast" if fast else "sweep",
                        params, _SWEEP_SCRIPT)
        meas = {
            bench: _cached(_cache_path(), f"measure_{bench}", mp,
                           _MEASURE_SCRIPT)
            for bench, mp in mparams.items()
        }
    except Exception as e:  # noqa: BLE001
        emit("comm_sweep", 0.0, f"SKIPPED({type(e).__name__})")
        return
    for bench in ("mtls", "mc"):
        dense_loss = sweep[bench]["dense"]
        dense_bytes = meas[bench]["dense"]["collective_bytes"]
        for cm in params["modes"]:
            loss = sweep[bench][cm]
            rel = abs(loss - dense_loss) / abs(dense_loss)
            ratio = dense_bytes / meas[bench][cm]["collective_bytes"]
            emit(
                f"comm_sweep.{bench}.{cm.replace(':', '_')}", 0.0,
                f"final_loss={loss:.6f};rel_vs_dense={rel:.4f};"
                f"bytes_ratio={ratio:.2f}x;epochs={params['epochs']}",
            )
