"""Linear-convergence solver tier: epochs-to-gap, block:k vs rank1.

The BlockFW tier's acceptance bar (and this suite's gated record): on the
paper's Table-1 problem sizes (d = m = 1024, low-rank ground truth) the
``block:k`` solver reaches a fixed duality-gap target in **>= 5x fewer
epochs** than the paper's rank-1 LMO — serial and 8-way sharded, on both
the MTLS regression task and matrix completion. The warm-start ablation
(``:cold`` re-randomizes the probe every epoch) rides along, isolating how
much of the win the carried probe buys.

Protocol per (task, worker-count) cell:

1. rank1, ``const:2`` + line search (the paper's strongest setting), run to
   an epoch budget; ``gap0`` is its first recorded duality gap and the
   target is ``frac * gap0``.
2. ``block:K:adapt`` (warm) and ``block:K:adapt:cold``, same mu/line
   search, early-stopped on ``gap_tol=target``.
3. ``epochs_to_gap`` = first history index with gap <= target, + 1.
   ``epochs_to_gap.speedup`` = rank1 / warm-block epochs — the gated
   metric (``benchmarks/baselines.json`` pins its floor >= 5x). A rank1
   run that never reaches the target within the budget counts the full
   budget — a conservative *floor* on the true speedup.

Subprocesses per cell (the device count locks at first jax init), the same
pattern as ``engine_bench.py`` / ``matrix_completion.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import emit

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=__NDEV__"
import sys, json
sys.path.insert(0, "__SRC__")
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

NDEV = __NDEV__
TASK = "__TASK__"
d, m, rank, n, budget, frac, K = __D__, __M__, __RANK__, __N__, __BUDGET__, __FRAC__, __K__

key = jax.random.PRNGKey(0)
ku, kv, kx = jax.random.split(key, 3)
u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
sv = jnp.linspace(1.0, 0.1, rank)
w_true = (u * (sv / jnp.sum(sv))) @ v.T  # ||W||_* = 1 (paper normalization)

if TASK == "mtls":
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    x = jax.random.normal(kx, (n, d))
    y = x @ w_true
    if NDEV > 1:
        data = (x, y)
else:
    task = tasks.MatrixCompletion(d=d, m=m)
    mask = jax.random.bernoulli(kx, __OBS__, (d, m))
    rows, cols = jnp.nonzero(mask)
    vals = w_true[rows, cols]
    if NDEV > 1:
        data = dfw.shard_observations(rows, cols, vals, NDEV, d, m=m)
    else:
        x, y = tasks.pack_observations(rows, cols, vals)


def run(solver, schedule, gap_tol=None):
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=budget, schedule=schedule,
                        step_size="linesearch", solver=solver,
                        gap_tol=gap_tol, block_epochs=5,
                        verify_kernels=False)
    if NDEV == 1:
        return dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1))
    return dfw.fit(task, data[0], data[1], cfg=cfg,
                   key=jax.random.PRNGKey(1), num_workers=NDEV)


def epochs_to(history, target):
    for i, g in enumerate(history["gap"]):
        if g <= target:
            return i + 1
    return None


r1 = run("rank1", "const:2")
gap0 = r1.history["gap"][0]
target = frac * gap0
out = {"gap0": gap0, "target": target, "budget": budget}
out["rank1"] = {"epochs": epochs_to(r1.history, target),
                "gap_final": r1.history["gap"][-1]}
for label, solver in (("warm", f"block:{K}:adapt"),
                      ("cold", f"block:{K}:adapt:cold")):
    res = run(solver, "const:8", gap_tol=target)
    out[label] = {"epochs": epochs_to(res.history, target),
                  "epochs_run": res.epochs_run,
                  "gap_hist": list(res.history["gap"])}
print(json.dumps(out))
"""


def _cell(task, ndev, *, d, m, rank, n, obs, budget, frac, k, timeout):
    src = str(Path(__file__).resolve().parent.parent / "src")
    script = (
        _SCRIPT.replace("__NDEV__", str(ndev)).replace("__SRC__", src)
        .replace("__TASK__", task).replace("__D__", str(d))
        .replace("__M__", str(m)).replace("__RANK__", str(rank))
        .replace("__N__", str(n)).replace("__OBS__", str(obs))
        .replace("__BUDGET__", str(budget)).replace("__FRAC__", str(frac))
        .replace("__K__", str(k))
    )
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    label = "serial" if ndev == 1 else f"sharded{ndev}"
    name = f"blockfw.{task}.{label}"
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        emit(name, 0.0, f"FAILED:{proc.stderr[-200:]}")
        return
    data = json.loads(proc.stdout.strip().splitlines()[-1])
    # rank1 missing the target inside the budget floors the speedup at
    # budget/warm_epochs (real speedup is larger) — never silently capped.
    r1_epochs = data["rank1"]["epochs"]
    r1_effective = r1_epochs if r1_epochs is not None else data["budget"]
    warm, cold = data["warm"]["epochs"], data["cold"]["epochs"]
    if warm is None:
        emit(name, 0.0, "FAILED:block solver never reached the gap target")
        return
    speedup = r1_effective / warm
    emit(
        name, 0.0,
        f"epochs_to_gap.speedup={speedup:.2f}x;"
        f"rank1_epochs={r1_epochs if r1_epochs is not None else 'budget'};"
        f"block_epochs={warm};k={k};gap0={data['gap0']:.4f};"
        f"target={data['target']:.4f}",
    )
    # Warm-start ablation: epochs-to-target ratio is coarse (both variants
    # can land in the same segment), so also compare the duality gap at the
    # last epoch both runs executed — warmth shows up as a smaller gap.
    cold_eff = cold if cold is not None else data["budget"]
    wh, ch = data["warm"]["gap_hist"], data["cold"]["gap_hist"]
    matched = min(len(wh), len(ch))
    gap_ratio = ch[matched - 1] / max(wh[matched - 1], 1e-12)
    emit(
        f"{name}.warm_vs_cold", 0.0,
        f"cold_over_warm_epochs={cold_eff / warm:.2f}x;"
        f"cold_over_warm_gap={gap_ratio:.2f}x;matched_epoch={matched};"
        f"warm_epochs={warm};"
        f"cold_epochs={cold if cold is not None else 'budget'}",
    )


def run(d=1024, m=1024, rank=32, n=2048, obs=0.05, budget=160, frac=0.1,
        k=32, timeout=1800):
    # Table-1 sizes are the point of this suite — `--fast` shrinks the
    # epoch budget/timeout upstream, never d/m (the gated record IS the
    # d=m=1024 cell).
    for task in ("mtls", "mc"):
        for ndev in (1, 8):
            _cell(task, ndev, d=d, m=m, rank=rank, n=n, obs=obs,
                  budget=budget, frac=frac, k=k, timeout=timeout)
