"""Paper Figure 4 analogue: scaling with the number of workers.

Wall-clock on fake CPU devices is meaningless, so the CPU-bound analogue
reports the quantities that determine the real speedup curve: per-worker
FLOPs (compute shrinks ~1/N) and per-epoch collective bytes (communication
term grows ~log N on a tree / const per device on a ring), extracted from the
compiled HLO at N = 1, 2, 4, 8 workers. A modeled time-per-epoch combines
them with the v5e constants.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import emit

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=__NDEV__"
import sys, json
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.core import tasks, frank_wolfe, low_rank
from repro.analysis import hlo as hlo_analysis
from repro.compat import shard_map_compat

NDEVN = __NDEV__
n, d, m, K = 4096, 256, 128, 2
task = tasks.MultiTaskLeastSquares(d=d, m=m)
if NDEVN == 1:
    step = frank_wolfe.make_epoch_step(task, 1.0, K, step_size="linesearch")
    wrapped = step
else:
    mesh = jax.make_mesh((NDEVN,), ("data",))
    ss = tasks.MTLSState(x=P("data"), y=P("data"), r=P("data"))
    isp = low_rank.FactoredIterate(u=P(), s=P(), v=P(), alpha=P(), count=P())
    asp = frank_wolfe.EpochAux(P(), P(), P(), P(), P())
    csp = frank_wolfe.EpochCarry(state=ss, iterate=isp, comm_state=(),
                                 t=P(), key=P())
    step = frank_wolfe.make_epoch_step(task, 1.0, K, step_size="linesearch",
                                       axis_name="data")
    wrapped = shard_map_compat(step, mesh, in_specs=(csp,),
                               out_specs=(csp, asp))
x = jax.ShapeDtypeStruct((n, d), jnp.float32)
y = jax.ShapeDtypeStruct((n, m), jnp.float32)
st = tasks.MTLSState(x=x, y=y, r=y)
it = jax.eval_shape(lambda: low_rank.init(30, d, m))
carry = frank_wolfe.EpochCarry(
    state=st, iterate=it, comm_state=(),
    t=jax.ShapeDtypeStruct((), jnp.int32),
    key=jax.ShapeDtypeStruct((2,), jnp.uint32))
comp = jax.jit(wrapped).lower(carry).compile()
res = hlo_analysis.analyze(comp.as_text())
print(json.dumps({"flops": res["flops"], "coll": res["collective_bytes_total"]}))
"""


def run():
    src = str(Path(__file__).resolve().parent.parent / "src")
    cache = Path(__file__).resolve().parent.parent / "experiments" / "bench_cache"
    cache.mkdir(parents=True, exist_ok=True)
    base_flops = None
    for ndev in (1, 2, 4, 8):
        f = cache / f"scaling_{ndev}.json"
        if f.exists():
            data = json.loads(f.read_text())
        else:
            script = _SCRIPT.replace("__NDEV__", str(ndev)).replace("SRC", src)
            env = dict(os.environ)
            env.pop("XLA_FLAGS", None)
            out = subprocess.run([sys.executable, "-c", script],
                                 capture_output=True, text=True, timeout=600, env=env)
            if out.returncode != 0:
                emit(f"fig4.workers{ndev}", 0.0, f"SKIPPED:{out.stderr[-200:]}")
                continue
            data = json.loads(out.stdout.strip().splitlines()[-1])
            f.write_text(json.dumps(data))
        if base_flops is None:
            base_flops = data["flops"]
        # modeled epoch time on v5e: compute + collective terms
        t_model = data["flops"] / 197e12 + data["coll"] / 50e9
        emit(f"fig4.workers{ndev}", 0.0,
             f"flops_per_worker={data['flops']:.3e};coll_bytes={data['coll']:.3e};"
             f"speedup_flops={base_flops/data['flops']:.2f}x;t_model_us={t_model*1e6:.1f}")
