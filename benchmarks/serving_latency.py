"""Factor-form serving latency: p50/p99 per dispatch, QPS, factor vs dense.

The serving claim of the factored iterate: scoring a padded batch against
``W = alpha * U^T diag(s) V`` costs O(B * r * (d + m)) FLOPs through the
fused factor matvec versus O(B * d * m) for a materialized dense score — an
m / (2r)-ish win whenever the live rank is small, which DFW-Trace
guarantees by construction (rank <= epochs). This bench measures the
*production path* end to end (host pad -> device -> AOT executable ->
explicit device_get), not the bare matmul, at the paper's Table-1 scale
(d = m = 1024; the fast variant halves it), and pins the canonical
``rank = d/8`` point as ``serve.table1.speedup`` for the CI gate.

Also reported, ungated: hot-swap publish latency (``ServingEngine.load``
from an in-memory iterate — the steady-state swap cost excluding disk) and
``serve.telemetry.overhead`` — the smallest-rank point re-measured with a
live ``repro.obs.Telemetry`` handle, whose p50 ratio against the
telemetry-off run is the serving cost of the observability spine (budget:
under 2% — events are appended off the dispatch critical path).
"""
from __future__ import annotations

import time

import numpy as np

from .common import emit


def _percentiles(times_s):
    arr = np.asarray(times_s) * 1e6  # us
    return float(np.percentile(arr, 50)), float(np.percentile(arr, 99))


def _measure(call, iters, warmup=3):
    for _ in range(warmup):
        call()
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        call()
        ts.append(time.perf_counter() - t0)
    return ts


def run(d=1024, m=1024, ranks=(16, 64, 128), max_batch=64, dispatches=40):
    import jax
    import jax.numpy as jnp

    from repro import serve
    from repro.core import low_rank

    key = jax.random.PRNGKey(0)
    rng = np.random.default_rng(0)
    canonical = d // 8  # the Table-1 rank the CI gate pins

    # Dense baseline: the same request pipeline (host pad -> device ->
    # compiled executable -> explicit device_get) against a materialized W.
    # AOT-compiled like the engine's scorer so neither side pays tracing.
    def dense_pipeline(w_np):
        w_dev = jnp.asarray(w_np)
        compiled = (
            jax.jit(lambda w, x: x @ w)
            .lower(
                jax.ShapeDtypeStruct((d, m), jnp.float32),
                jax.ShapeDtypeStruct((max_batch, d), jnp.float32),
            )
            .compile()
        )

        def call():
            x = np.zeros((max_batch, d), np.float32)
            x[:] = rng.standard_normal((max_batch, d), np.float32)
            jax.device_get(compiled(w_dev, jnp.asarray(x)))

        return call

    results = {}
    for rank in ranks:
        ks = jax.random.split(jax.random.fold_in(key, rank), 3)
        it = low_rank.FactoredIterate(
            u=jax.random.normal(ks[0], (rank, d)),
            s=jax.random.normal(ks[1], (rank,)),
            v=jax.random.normal(ks[2], (rank, m)),
            alpha=jnp.asarray(1.0),
            count=jnp.asarray(rank, jnp.int32),
        )
        eng = serve.ServingEngine(
            d, m,
            serve.ServeConfig(max_batch=max_batch, rank_block=max(rank, 1),
                              verify_kernels=False),
        )
        eng.load(it)

        def factor_call(eng=eng):
            eng.score(rng.standard_normal((max_batch, d), np.float32))

        ts = _measure(factor_call, dispatches)
        p50, p99 = _percentiles(ts)
        qps = max_batch / (np.mean(ts))
        results[rank] = p50
        emit(
            f"serve.factor.r{rank}", p50,
            f"p50_us={p50:.1f};p99_us={p99:.1f};qps={qps:.0f};rank={rank};"
            f"d={d};m={m};max_batch={max_batch}",
        )

        # Hot-swap publish latency: stage + republish a same-bucket model.
        it2 = it._replace(s=it.s * 0.5)
        swap_ts = _measure(lambda: eng.load(it2), max(dispatches // 4, 5))
        sp50, sp99 = _percentiles(swap_ts)
        emit(
            f"serve.swap.r{rank}", sp50,
            f"p50_us={sp50:.1f};p99_us={sp99:.1f};"
            f"compilations={eng.stats['compilations']}",
        )

    # Telemetry overhead: the smallest-rank point as a back-to-back A/B —
    # fresh engines, identical fixed request batch, off measured immediately
    # before on (reusing the earlier p50 would fold process-aging noise into
    # the ratio). Ungated but recorded — acceptance budget: <2% on p50.
    from repro.obs import Telemetry

    rank = ranks[0]
    ks = jax.random.split(jax.random.fold_in(key, rank), 3)
    it = low_rank.FactoredIterate(
        u=jax.random.normal(ks[0], (rank, d)),
        s=jax.random.normal(ks[1], (rank,)),
        v=jax.random.normal(ks[2], (rank, m)),
        alpha=jnp.asarray(1.0),
        count=jnp.asarray(rank, jnp.int32),
    )
    xb = rng.standard_normal((max_batch, d), np.float32)

    def mk(tel):
        eng = serve.ServingEngine(
            d, m,
            serve.ServeConfig(max_batch=max_batch, rank_block=max(rank, 1),
                              verify_kernels=False, telemetry=tel),
        )
        eng.load(it)
        for _ in range(3):
            eng.score(xb)
        return eng

    tel = Telemetry()
    eng_off, eng_on = mk(None), mk(tel)
    ts_off, ts_on = [], []
    # Per-call alternation: machine drift (shared CPU, allocator aging)
    # lands evenly on both sides instead of on whichever ran last — the
    # residual ratio is the instrumentation itself, not the weather. The
    # within-pair order also swaps each iteration: whichever call runs
    # right after the other's device fetch sees warmer caches, and that
    # positional bias must not always favor the same side.
    for i in range(max(dispatches, 20) * 2):
        first, second = (eng_off, eng_on) if i % 2 == 0 else (eng_on, eng_off)
        t0 = time.perf_counter()
        first.score(xb)
        t1 = time.perf_counter()
        second.score(xb)
        t2 = time.perf_counter()
        if i % 2 == 0:
            ts_off.append(t1 - t0)
            ts_on.append(t2 - t1)
        else:
            ts_on.append(t1 - t0)
            ts_off.append(t2 - t1)
    p50_off = _percentiles(ts_off)[0]
    p50_on = _percentiles(ts_on)[0]
    emit(
        "serve.telemetry.overhead", p50_on,
        f"p50_on_us={p50_on:.1f};p50_off_us={p50_off:.1f};"
        f"ratio={p50_on / max(p50_off, 1e-9):.3f}x;rank={rank};"
        f"events={tel.event_count()}",
    )

    w_np = np.asarray(
        low_rank.materialize(
            low_rank.FactoredIterate(
                u=jax.random.normal(key, (canonical, d)),
                s=jax.random.normal(key, (canonical,)),
                v=jax.random.normal(key, (canonical, m)),
                alpha=jnp.asarray(1.0),
                count=jnp.asarray(canonical, jnp.int32),
            )
        ),
        np.float32,
    )
    ts = _measure(dense_pipeline(w_np), dispatches)
    dense_p50, dense_p99 = _percentiles(ts)
    dense_qps = max_batch / np.mean(ts)
    emit(
        "serve.dense", dense_p50,
        f"p50_us={dense_p50:.1f};p99_us={dense_p99:.1f};qps={dense_qps:.0f};"
        f"d={d};m={m};max_batch={max_batch}",
    )

    for rank in ranks:
        emit(
            f"serve.speedup.r{rank}", 0.0,
            f"factor_vs_dense={dense_p50 / max(results[rank], 1e-9):.2f}x",
        )
    # The gated record: factor-form must beat dense at the Table-1 point
    # rank = d/8 — stable name across fast/full so baselines.json can pin it.
    if canonical in results:
        emit(
            "serve.table1.speedup", 0.0,
            f"factor_vs_dense={dense_p50 / max(results[canonical], 1e-9):.2f}x;"
            f"rank={canonical};d={d};m={m}",
        )
