"""Theorem 2 regimes: power-method accuracy vs K against the
Kuczynski-Wozniakowski ln(m)/(K-1) bound and the spectral-gap rate."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import top_singular_pair

from .common import emit


def run(m: int = 64, d: int = 96, trials: int = 32):
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (d, m))
    s1 = float(jnp.linalg.svd(a, compute_uv=False)[0])
    for k in (2, 4, 8, 16):
        errs = []
        for t in range(trials):
            res = top_singular_pair(a, jax.random.fold_in(key, 17 * t + k), num_iters=k)
            errs.append(abs(float(res.sigma) ** 2 - s1**2) / s1**2)
        bound = 0.871 * np.log(m) / (k - 1)
        emit(f"thm2.K{k}", 0.0,
             f"mean_rel_err={np.mean(errs):.5f};kw_bound={bound:.5f};"
             f"within_bound={np.mean(errs) <= bound}")

    # well-behaved regime (paper §5: ratio ~0.86): error decays ~beta^(2K)
    u, s, vt = jnp.linalg.svd(a, full_matrices=False)
    s_gap = s.at[1:].multiply(0.5)  # enforce sigma2/sigma1 = 0.5 * old ratio
    a_gap = (u * s_gap) @ vt
    s1g = float(s_gap[0])
    errs_by_k = []
    for k in (2, 4, 8):
        res = top_singular_pair(a_gap, jax.random.PRNGKey(5), num_iters=k)
        errs_by_k.append(abs(float(res.sigma) - s1g) / s1g)
    emit("thm2.spectral_gap_decay", 0.0,
         f"errs={';'.join(f'{e:.2e}' for e in errs_by_k)};monotone={errs_by_k[0] >= errs_by_k[-1]}")
