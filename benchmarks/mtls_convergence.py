"""Paper Figure 1: multi-task least squares — NAIVE-DFW vs SVA vs DFW-TRACE.

CPU-scaled (paper: n=1e5, d=m=300/1000): we keep d=m=200, n=20k so the full
method comparison runs in seconds while preserving the phenomena (SVA bias at
higher dim, DFW-TRACE-2 ~ exact LMO per epoch).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import baselines, fit, low_rank, tasks

from .common import emit, mtls_problem


def _run_baseline(make_step, task, x, y, epochs, mu):
    st = task.init_state(x, y)
    it = low_rank.init(epochs, task.d, task.m)
    step = jax.jit(make_step)
    t0 = time.perf_counter()
    loss = None
    for t in range(epochs):
        st, it, aux = step(st, it, jnp.float32(t), jax.random.PRNGKey(0))
        loss = float(aux.loss)
    return loss, it, (time.perf_counter() - t0) / epochs * 1e6


def run(epochs: int = 25, n: int = 20000, d: int = 200, m: int = 200):
    x, y, w_true = mtls_problem(jax.random.PRNGKey(0), n, d, m)
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    mu = 1.0

    def err(it):
        w = low_rank.materialize(it)
        return float(jnp.linalg.norm(w - w_true) / jnp.linalg.norm(w_true))

    # NAIVE-DFW (exact LMO, O(dm) comm)
    loss, it, us = _run_baseline(
        baselines.make_naive_epoch_step(task, mu, step_size="linesearch"),
        task, x, y, epochs, mu)
    emit("fig1.naive_dfw", us, f"loss={loss:.4f};err={err(it):.4f}")

    # SVA
    loss, it, us = _run_baseline(
        baselines.make_sva_epoch_step(task, mu, step_size="linesearch"),
        task, x, y, epochs, mu)
    emit("fig1.sva", us, f"loss={loss:.4f};err={err(it):.4f}")

    # DFW-TRACE-{1,2,log}
    for sched, name in (("const:1", "dfw_trace_1"), ("const:2", "dfw_trace_2"),
                        ("log", "dfw_trace_log")):
        t0 = time.perf_counter()
        res = fit(task, task.init_state(x, y), mu=mu, num_epochs=epochs,
                  key=jax.random.PRNGKey(1), schedule=sched, step_size="linesearch")
        us = (time.perf_counter() - t0) / epochs * 1e6
        emit(f"fig1.{name}", us,
             f"loss={res.final_loss:.4f};err={err(res.iterate):.4f}")
