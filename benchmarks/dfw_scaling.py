"""Paper Figure 4 analogue on the real execution layer (launch/dfw.py).

Two sweeps:

1. Worker scaling — the identical DFW-Trace program at 1 (serial driver) and
   2/4/8-way sharded execution (fake CPU devices via subprocesses, since the
   device count locks at first jax init). Wall-clock on fake devices measures
   dispatch + collective overhead rather than true speedup, so the row also
   reports the serial/sharded loss drift as a correctness check.

2. K(t) schedules — gap/loss after a fixed epoch budget for the paper's four
   schedule families, plus the total number of power iterations each spends
   (the communication cost driver: 2 psums of d+m floats per iteration).

Timing: every fit() call builds fresh jitted closures, so a
warmup-run-then-timed-run pattern would still pay compilation. The engine
executes scan-compiled segments (callback granularity is per *segment*), so
both sweeps cap ``block_epochs`` to get several equal-shape blocks — which
share one executable — record per-epoch wall time per block via the driver
callback, and report the MEDIAN block: the compile-bearing first block lands
in the upper tail and drops out. ``benchmarks/engine_bench.py`` is the
dedicated scan-vs-legacy dispatch-overhead benchmark.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

from .common import emit

_SCALE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=__NDEV__"
import sys, json, time
sys.path.insert(0, "__SRC__")
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

NDEV = __NDEV__
n, d, m, epochs = __N__, __D__, __M__, __EPOCHS__
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (n, d))
w = jax.random.normal(jax.random.fold_in(key, 1), (d, m))
y = x @ (w / jnp.linalg.norm(w, ord="nuc"))
task = tasks.MultiTaskLeastSquares(d=d, m=m)
cfg = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule="const:2",
                    step_size="linesearch", verify_kernels=False,
                    block_epochs=max(1, epochs // 4))

ts, prev = [], [time.perf_counter()]
def cb(start, aux):  # per-segment: aux is an EpochAux of (block,) np arrays
    now = time.perf_counter()
    ts.append((now - prev[0]) / len(aux.loss))
    prev[0] = now

if NDEV == 1:
    res = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1),
                         callback=cb)
else:
    res = dfw.fit(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1),
                  num_workers=NDEV, callback=cb)
ts.sort()
print(json.dumps({"us_per_epoch": ts[len(ts) // 2] * 1e6,
                  "loss_final": res.final_loss}))
"""


def _worker_scaling(n, d, m, epochs):
    src = str(Path(__file__).resolve().parent.parent / "src")
    serial_loss = None  # drift is only meaningful vs the ndev=1 reference
    for ndev in (1, 2, 4, 8):
        script = (
            _SCALE_SCRIPT.replace("__NDEV__", str(ndev))
            .replace("__SRC__", src)
            .replace("__N__", str(n))
            .replace("__D__", str(d))
            .replace("__M__", str(m))
            .replace("__EPOCHS__", str(epochs))
        )
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=900, env=env)
        if out.returncode != 0:
            emit(f"dfw_scaling.workers{ndev}", 0.0,
                 f"SKIPPED:{out.stderr[-200:]}")
            continue
        data = json.loads(out.stdout.strip().splitlines()[-1])
        if ndev == 1:
            serial_loss = data["loss_final"]
        if serial_loss is None:
            drift = "n/a"  # serial run failed; don't fake a reference
        else:
            drift = "{:.2e}".format(
                abs(data["loss_final"] - serial_loss) / (abs(serial_loss) + 1e-12)
            )
        emit(f"dfw_scaling.workers{ndev}", data["us_per_epoch"],
             f"loss_final={data['loss_final']:.5f};serial_drift={drift}")


def _schedule_sweep(n, d, m, epochs):
    import jax
    import jax.numpy as jnp

    from repro.core import tasks
    from repro.launch import dfw

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, m))
    y = x @ (w / jnp.linalg.norm(w, ord="nuc"))
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    for sched in ("const:1", "const:2", "log", "log_half", "linear:0.2"):
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule=sched,
                            step_size="linesearch", verify_kernels=False,
                            block_epochs=max(1, epochs // 4))
        ts, prev = [], [time.perf_counter()]

        def cb(start, aux):  # per-segment (see module docstring)
            now = time.perf_counter()
            ts.append((now - prev[0]) / len(aux.loss))
            prev[0] = now

        res = dfw.fit_serial(task, x, y, cfg=cfg, key=jax.random.PRNGKey(1),
                             callback=cb)
        ts.sort()
        k_total = sum(res.history["k"])
        comm_kb = k_total * 2 * (d + m) * 4 / 1e3  # 2 psums of f32 vectors
        emit(f"dfw_scaling.sched[{sched}]", ts[len(ts) // 2] * 1e6,
             f"gap_final={res.history['gap'][-1]:.4f};"
             f"loss_final={res.final_loss:.5f};"
             f"k_total={k_total};comm_kb_per_worker={comm_kb:.1f}")


def run(n=4096, d=128, m=64, epochs=8):
    _worker_scaling(n, d, m, epochs)
    _schedule_sweep(n, d, m, epochs)
