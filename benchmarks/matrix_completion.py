"""Matrix-completion convergence + worker scaling on the real execution layer.

The paper's third synthetic task (§5.1): recover a rank-r matrix from sparse
observed entries. Two sweeps, mirroring ``dfw_scaling.py``:

1. Worker scaling — the identical completion program serial and 2/4/8-way
   row-block-sharded (fake CPU devices in subprocesses), reporting the median
   epoch time plus the serial/sharded final-loss drift as a correctness check.
   The padding overhead of equalizing entry shards is also reported — it is
   the price of static shapes under shard_map.

2. Schedule sweep — final train loss (of the *returned* iterate, via
   ``final_loss`` — history[-1] is one epoch stale) and held-out RMSE after a
   fixed epoch budget for the paper's K(t) families.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

from .common import emit

_SCALE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=__NDEV__"
import sys, json, time
sys.path.insert(0, "__SRC__")
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

NDEV = __NDEV__
d, m, rank, obs, epochs = __D__, __M__, 8, __OBS__, __EPOCHS__
key = jax.random.PRNGKey(0)
ku, kv, ko = jax.random.split(key, 3)
u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
sv = jnp.linspace(1.0, 0.2, rank)
w_true = (u * (sv / jnp.sum(sv))) @ v.T
mask = jax.random.bernoulli(ko, obs, (d, m))
rows, cols = jnp.nonzero(mask)
vals = w_true[rows, cols]

task = tasks.MatrixCompletion(d=d, m=m)
cfg = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule="const:2",
                    step_size="linesearch", verify_kernels=False,
                    block_epochs=max(1, epochs // 4))

ts, prev = [], [time.perf_counter()]
def cb(start, aux):  # per-segment: aux is an EpochAux of (block,) np arrays
    now = time.perf_counter()
    ts.append((now - prev[0]) / len(aux.loss))
    prev[0] = now

if NDEV == 1:
    idx, yw = tasks.pack_observations(rows, cols, vals)
    res = dfw.fit_serial(task, idx, yw, cfg=cfg, key=jax.random.PRNGKey(1),
                         callback=cb)
    pad = 0.0
else:
    idx, yw = dfw.shard_observations(rows, cols, vals, NDEV, d, m=m)
    pad = idx.shape[0] / rows.size - 1.0
    res = dfw.fit(task, idx, yw, cfg=cfg, key=jax.random.PRNGKey(1),
                  num_workers=NDEV, callback=cb)
ts.sort()
print(json.dumps({"us_per_epoch": ts[len(ts) // 2] * 1e6,
                  "final_loss": res.final_loss, "pad_frac": pad}))
"""


def _worker_scaling(d, m, obs, epochs):
    src = str(Path(__file__).resolve().parent.parent / "src")
    serial_loss = None
    for ndev in (1, 2, 4, 8):
        script = (
            _SCALE_SCRIPT.replace("__NDEV__", str(ndev))
            .replace("__SRC__", src)
            .replace("__D__", str(d))
            .replace("__M__", str(m))
            .replace("__OBS__", str(obs))
            .replace("__EPOCHS__", str(epochs))
        )
        env = dict(os.environ)
        env.pop("XLA_FLAGS", None)
        out = subprocess.run([sys.executable, "-c", script],
                             capture_output=True, text=True, timeout=900, env=env)
        if out.returncode != 0:
            emit(f"matrix_completion.workers{ndev}", 0.0,
                 f"SKIPPED:{out.stderr[-200:]}")
            continue
        data = json.loads(out.stdout.strip().splitlines()[-1])
        if ndev == 1:
            serial_loss = data["final_loss"]
        if serial_loss is None:
            drift = "n/a"
        else:
            drift = "{:.2e}".format(
                abs(data["final_loss"] - serial_loss) / (abs(serial_loss) + 1e-12)
            )
        emit(f"matrix_completion.workers{ndev}", data["us_per_epoch"],
             f"final_loss={data['final_loss']:.6f};serial_drift={drift};"
             f"pad_frac={data['pad_frac']:.3f}")


def _schedule_sweep(d, m, obs, epochs):
    import jax
    import jax.numpy as jnp

    from repro.core import low_rank, tasks
    from repro.launch import dfw

    key = jax.random.PRNGKey(0)
    ku, kv, ko, ks = jax.random.split(key, 4)
    rank = 8
    u = jnp.linalg.qr(jax.random.normal(ku, (d, rank)))[0]
    v = jnp.linalg.qr(jax.random.normal(kv, (m, rank)))[0]
    sv = jnp.linspace(1.0, 0.2, rank)
    w_true = (u * (sv / jnp.sum(sv))) @ v.T
    mask = jax.random.bernoulli(ko, obs, (d, m))
    rows, cols = jnp.nonzero(mask)
    vals = w_true[rows, cols]
    holdout = jax.random.bernoulli(ks, 0.1, rows.shape)
    tr, ho = jnp.nonzero(~holdout)[0], jnp.nonzero(holdout)[0]
    idx, yw = tasks.pack_observations(rows[tr], cols[tr], vals[tr])

    task = tasks.MatrixCompletion(d=d, m=m)
    for sched in ("const:1", "const:2", "log", "linear:0.2"):
        cfg = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule=sched,
                            step_size="linesearch", verify_kernels=False,
                            block_epochs=max(1, epochs // 4))
        ts, prev = [], [time.perf_counter()]

        def cb(start, aux):  # per-segment callback (engine contract)
            now = time.perf_counter()
            ts.append((now - prev[0]) / len(aux.loss))
            prev[0] = now

        res = dfw.fit_serial(task, idx, yw, cfg=cfg, key=jax.random.PRNGKey(1),
                             callback=cb)
        ts.sort()
        pred = low_rank.gather_entries(res.iterate, rows[ho], cols[ho])
        rmse = float(jnp.sqrt(jnp.mean((pred - vals[ho]) ** 2)))
        emit(f"matrix_completion.sched[{sched}]", ts[len(ts) // 2] * 1e6,
             f"final_loss={res.final_loss:.6f};holdout_rmse={rmse:.6f};"
             f"gap_final={res.history['gap'][-1]:.5f};"
             f"k_total={sum(res.history['k'])}")


def run(d=384, m=256, obs=0.2, epochs=20):
    _worker_scaling(d, m, obs, epochs)
    _schedule_sweep(d, m, obs, epochs)
