"""Epoch-engine dispatch overhead: scan-compiled segments vs legacy loop.

DFW-Trace epochs are O(d+m) cheap, so the driver's fixed costs — one jit
dispatch and four blocking scalar device->host pulls per epoch in the
pre-engine loop — dominate wall clock long before the algorithm does. This
bench pins the engine's win directly: the same fit run through

- ``engine="legacy"``: per-epoch dispatch + blocking ``float()`` pulls (the
  pre-engine driver, kept in ``core/engine.py`` as the baseline), and
- ``engine="scan"``: one ``lax.scan`` dispatch per K(t) segment, histories
  on device, host transfers at segment boundaries only,

reporting steady-state epochs/sec (compile excluded: segments share one
executable, so every timed block after the first is compile-free) and the
engine's own host-sync counter. Serial and 8-way sharded (the latter in a
subprocess: the device count locks at first jax init).

The acceptance bar this encodes: >= 5x epochs/sec for scan over legacy at
d = m = 256 on CPU.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

from .common import emit


def _steady_epochs_per_sec(run_fit):
    """Run a fit with a per-segment timing callback; return (epochs/sec over
    all blocks after the first, stats). The first block carries compilation
    and is dropped — later blocks reuse the same executable."""
    ts = []
    prev = [time.perf_counter()]

    def cb(start, aux):
        now = time.perf_counter()
        ts.append((now - prev[0], len(aux.loss)))
        prev[0] = now

    res = run_fit(cb)
    rest = ts[1:] if len(ts) > 1 else ts
    total_t = sum(t for t, _ in rest)
    total_e = sum(n for _, n in rest)
    return total_e / max(total_t, 1e-12), res.stats


def _serial(d, m, n, epochs, block):
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.core import tasks
    from repro.launch import dfw

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n, d))
    w = jax.random.normal(jax.random.fold_in(key, 1), (d, m))
    y = x @ (w / jnp.linalg.norm(w, ord="nuc"))
    task = tasks.MultiTaskLeastSquares(d=d, m=m)
    cfg = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule="const:2",
                        step_size="linesearch", verify_kernels=False,
                        block_epochs=block)
    out = {}
    for mode in ("legacy", "scan"):
        eps, stats = _steady_epochs_per_sec(
            lambda cb, mode=mode: dfw.fit_serial(
                task, x, y, cfg=dataclasses.replace(cfg, engine=mode),
                key=jax.random.PRNGKey(1), callback=cb)
        )
        out[mode] = {"eps": eps, "host_syncs": stats["host_syncs"],
                     "dispatches": stats["dispatches"]}
    return out


_SHARDED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json, time, dataclasses
sys.path.insert(0, "__SRC__")
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

d, m, n, epochs, block = __D__, __M__, __N__, __EPOCHS__, __BLOCK__
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (n, d))
w = jax.random.normal(jax.random.fold_in(key, 1), (d, m))
y = x @ (w / jnp.linalg.norm(w, ord="nuc"))
task = tasks.MultiTaskLeastSquares(d=d, m=m)
cfg = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule="const:2",
                    step_size="linesearch", verify_kernels=False,
                    block_epochs=block)
out = {}
for mode in ("legacy", "scan"):
    ts, prev = [], [time.perf_counter()]
    def cb(start, aux):
        now = time.perf_counter()
        ts.append((now - prev[0], len(aux.loss)))
        prev[0] = now
    res = dfw.fit(task, x, y, cfg=dataclasses.replace(cfg, engine=mode),
                  key=jax.random.PRNGKey(1), num_workers=8, callback=cb)
    rest = ts[1:] if len(ts) > 1 else ts
    out[mode] = {"eps": sum(n_ for _, n_ in rest) / max(sum(t for t, _ in rest), 1e-12),
                 "host_syncs": res.stats["host_syncs"],
                 "dispatches": res.stats["dispatches"]}
print(json.dumps(out))
"""


def _emit_pair(label, out, epochs):
    legacy, scan = out["legacy"], out["scan"]
    speedup = scan["eps"] / max(legacy["eps"], 1e-12)
    emit(f"engine.{label}.legacy", 1e6 / max(legacy["eps"], 1e-12),
         f"epochs_per_sec={legacy['eps']:.1f};host_syncs={legacy['host_syncs']};"
         f"dispatches={legacy['dispatches']};epochs={epochs}")
    emit(f"engine.{label}.scan", 1e6 / max(scan["eps"], 1e-12),
         f"epochs_per_sec={scan['eps']:.1f};host_syncs={scan['host_syncs']};"
         f"dispatches={scan['dispatches']};epochs={epochs}")
    emit(f"engine.{label}.speedup", 0.0,
         f"scan_vs_legacy={speedup:.2f}x")


def run(d=256, m=256, n=64, epochs=192, block=32):
    # n is deliberately thin: this bench isolates *driver* overhead (dispatch
    # + host syncs) at the acceptance sizes d = m = 256; per-epoch FLOPs
    # scale with n and would mask it. Compute-bound scaling lives in
    # dfw_scaling.py / matrix_completion.py.
    # serial (in-process: single device)
    out = _serial(d, m, n, epochs, block)
    _emit_pair("serial", out, epochs)

    # 8-way sharded (subprocess: fake CPU devices)
    src = str(Path(__file__).resolve().parent.parent / "src")
    script = (_SHARDED_SCRIPT.replace("__SRC__", src)
              .replace("__D__", str(d)).replace("__M__", str(m))
              .replace("__N__", str(max(n, 8))).replace("__EPOCHS__", str(epochs))
              .replace("__BLOCK__", str(block)))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run([sys.executable, "-c", script], capture_output=True,
                          text=True, timeout=900, env=env)
    if proc.returncode != 0:
        emit("engine.sharded8", 0.0, f"SKIPPED:{proc.stderr[-200:]}")
        return
    _emit_pair("sharded8", json.loads(proc.stdout.strip().splitlines()[-1]),
               epochs)
