"""Paper Figure 2: multinomial logistic regression, mu sweep (10/50/100).

Fixed step size 2/(t+2) (no closed-form line search), K(t)=floor(1+0.5 ln t)
for the log variant — exactly the paper's settings, CPU-scaled sizes.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import baselines, fit, low_rank, tasks

from .common import emit, logistic_problem


def run(epochs: int = 25, n: int = 8000, d: int = 128, m: int = 64):
    x, y, _ = logistic_problem(jax.random.PRNGKey(0), n, d, m)
    task = tasks.MultinomialLogistic(d=d, m=m)

    for mu in (10.0, 50.0, 100.0):
        for sched, name in (("const:1", "dfw_trace_1"), ("const:2", "dfw_trace_2"),
                            ("log_half", "dfw_trace_log")):
            t0 = time.perf_counter()
            res = fit(task, task.init_state(x, y), mu=mu, num_epochs=epochs,
                      key=jax.random.PRNGKey(1), schedule=sched, step_size="default")
            us = (time.perf_counter() - t0) / epochs * 1e6
            err = float(task.errors(res.state, top_k=5)) / n
            emit(f"fig2.mu{int(mu)}.{name}", us,
                 f"loss={res.final_loss:.1f};top5err={err:.4f}")

        # NAIVE-DFW reference at this mu
        st = task.init_state(x, y)
        it = low_rank.init(epochs, d, m)
        step = jax.jit(baselines.make_naive_epoch_step(task, mu))
        t0 = time.perf_counter()
        for t in range(epochs):
            st, it, aux = step(st, it, jnp.float32(t), jax.random.PRNGKey(0))
        us = (time.perf_counter() - t0) / epochs * 1e6
        err = float(task.errors(st, top_k=5)) / n
        emit(f"fig2.mu{int(mu)}.naive_dfw", us,
             f"loss={float(aux.loss):.1f};top5err={err:.4f}")
