"""Paper Figure 3 analogue: trace-norm head on frozen deep features.

The paper uses ResNet50 ImageNet features (n=1.28M, p=2048, m=1000). Offline
stand-in: features from a frozen smoke backbone of the model zoo + planted
low-rank class structure with label noise, so top-5 error is a meaningful
(learnable but not trivial) metric.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import dfw_head
from repro.models import lm

from .common import emit


def run(epochs: int = 30, m: int = 100, tokens: int = 4096):
    cfg = get_config("qwen2_1_5b", smoke=True)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    batches = []
    b, s = 4, 64
    n_batches = max(1, tokens // (b * s))
    for i in range(n_batches):
        key = jax.random.PRNGKey(100 + i)
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
        batches.append({"tokens": toks, "labels": toks})
    x, _ = dfw_head.extract_features(params, batches, cfg)
    # planted low-rank (rank 10) class structure + 5% label noise
    key = jax.random.PRNGKey(7)
    wu = jax.random.normal(key, (x.shape[1], 10))
    wv = jax.random.normal(jax.random.fold_in(key, 1), (10, m))
    logits = x @ (wu @ wv)
    y = jnp.argmax(logits, axis=1)
    flip = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.05, y.shape)
    y = jnp.where(flip, jax.random.randint(jax.random.fold_in(key, 3), y.shape, 0, m), y)

    for mu in (10.0, 30.0):
        for sched, name in (("const:1", "dfw_trace_1"), ("const:2", "dfw_trace_2")):
            t0 = time.perf_counter()
            res = dfw_head.train_head(x, y, m, mu=mu, num_epochs=epochs, schedule=sched)
            us = (time.perf_counter() - t0) / epochs * 1e6
            err5 = dfw_head.top_k_error(res.iterate, x, y, k=5)
            emit(f"fig3.mu{int(mu)}.{name}", us,
                 f"loss={res.final_loss:.1f};top5err={err5:.4f};"
                 f"rank<={int(res.iterate.count)}")
