"""Topology benchmark: gossip consensus quality + hier inter-group bytes.

Two measured layers, both emitted as CSV rows (and gated in
``baselines.json``):

1. **Inter-group HLO bytes** — compile one power-method vector exchange
   (d=256, 8 workers) under each topology and classify every collective's
   wire bytes against the 2-cell host partition ``[[0..3],[4..7]]`` with
   ``repro.analysis.hlo.partition_crossing_bytes`` (replica-group aware).
   ``flat`` sends everything across; ``hier:2`` keeps the exact psum inside
   the cells and only the reducer-encoded exchange crosses, so the
   ``hier:2 + int8`` composition is the headline: crossing bytes ~3.9x
   below flat/dense at identical sizes. The gated record is
   ``hier.inter_bytes`` (metric ``ratio`` = flat-dense crossing bytes over
   hier-int8 crossing bytes, floor in ``baselines.json``).

2. **Consensus error** — 8-way MTLS fits under ``flat``, ``ring`` (default
   auto-sized mixing rounds) and ``hier:2 + int8``, reporting each
   topology's final loss relative to the flat/dense master. Ring's drift is
   the PR's acceptance number (<= 1%); hier/dense is exact to standard
   tolerances and pinned bit-exact on integer grids in
   ``tests/test_topology.py``.

Subprocesses own all multi-device work (the parent locks the CPU device
count at first jax init); results are cached to a versioned JSON keyed by
the exact parameters, like ``benchmarks/comm_cost.py``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
from pathlib import Path

from .common import emit

_CACHE_VERSION = 1

_MEASURE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "SRC")
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro import comm, compat
from repro.analysis import hlo as hlo_analysis

P_ = json.loads('PARAMS')
nw, d = P_["workers"], P_["d"]
mesh = Mesh(np.asarray(jax.devices()[:nw]), ("data",))
cells = P_["partition"]

def compile_exchange(topo):
    def body(x):
        est, _ = topo.all_reduce(x[0], (), slot="u",
                                 key=jax.random.PRNGKey(0), axis_name="data")
        return est[None]
    f = compat.shard_map_compat(body, mesh, P("data"), P("data"))
    arg = jax.ShapeDtypeStruct((nw, d), jnp.float32)
    return jax.jit(f).lower(arg).compile().as_text()

out = {}
for spec, cm in P_["modes"]:
    topo = comm.make_topology(spec, num_workers=nw, comm=cm)
    txt = compile_exchange(topo)
    res = hlo_analysis.analyze(txt)
    cross = hlo_analysis.partition_crossing_bytes(txt, cells)
    out[f"{spec}+{cm}"] = {
        "total": res["collective_bytes_total"],
        "crossing": cross["crossing"], "local": cross["local"],
        "counts": res["collective_count"],
    }
print(json.dumps(out))
"""

_CONSENSUS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys, json
sys.path.insert(0, "SRC")
import dataclasses
import jax, jax.numpy as jnp
from repro.core import tasks
from repro.launch import dfw

P = json.loads('PARAMS')
nw, epochs = P["workers"], P["epochs"]
n, d, m = P["n"], P["d"], P["m"]
key = jax.random.PRNGKey(0)
kx, kw = jax.random.split(key)
W = jax.random.normal(kw, (d, m)); W = W / jnp.linalg.norm(W, ord="nuc")
X = jax.random.normal(kx, (n, d)); Y = X @ W
task = tasks.MultiTaskLeastSquares(d=d, m=m)
base = dfw.DFWConfig(mu=1.0, num_epochs=epochs, schedule="const:2",
                     step_size="linesearch")
out = {}
for spec, cm in P["modes"]:
    cfg = dataclasses.replace(base, topology=spec, comm=cm)
    res = dfw.fit(task, X, Y, cfg=cfg, key=jax.random.PRNGKey(1),
                  num_workers=nw)
    out[f"{spec}+{cm}"] = {"final_loss": res.final_loss,
                           "gap": float(res.history["gap"][-1]),
                           "epochs_run": res.epochs_run}
print(json.dumps(out))
"""


def _run_subprocess(template: str, params: dict) -> dict:
    src = str(Path(__file__).resolve().parent.parent / "src")
    script = template.replace("SRC", src).replace("PARAMS", json.dumps(params))
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, timeout=1200, env=env)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def _cached(section: str, params: dict, template: str) -> dict:
    cache = (Path(__file__).resolve().parent.parent
             / "experiments" / "bench_cache" / "gossip_consensus.json")
    blob = {}
    if cache.exists():
        try:
            blob = json.loads(cache.read_text())
        except json.JSONDecodeError:
            blob = {}
    if blob.get("version") != _CACHE_VERSION:
        blob = {"version": _CACHE_VERSION}
    entry = blob.get(section)
    if entry is not None and entry.get("params") == params:
        return entry["data"]
    data = _run_subprocess(template, params)
    blob[section] = {"params": params, "data": data}
    cache.parent.mkdir(parents=True, exist_ok=True)
    cache.write_text(json.dumps(blob))
    return data


MODES = [["flat", "dense"], ["hier:2", "dense"], ["hier:2", "int8"],
         ["ring", "dense"]]


def run(fast: bool = False):
    # --- inter-group bytes, one compiled (d,)-vector exchange per topology
    mparams = {"workers": 8, "d": 256, "partition": [[0, 1, 2, 3], [4, 5, 6, 7]],
               "modes": MODES}
    try:
        meas = _cached("measure", mparams, _MEASURE_SCRIPT)
        flat_cross = meas["flat+dense"]["crossing"]
        for spec_cm, rec in meas.items():
            emit(
                f"topology.bytes.{spec_cm.replace(':', '_')}", 0.0,
                f"crossing_bytes={rec['crossing']:.0f};"
                f"local_bytes={rec['local']:.0f};total={rec['total']:.0f};"
                f"counts={rec['counts']}",
            )
        ratio = flat_cross / meas["hier:2+int8"]["crossing"]
        emit("hier.inter_bytes", 0.0,
             f"ratio={ratio:.2f};flat_crossing={flat_cross:.0f};"
             f"hier_int8_crossing={meas['hier:2+int8']['crossing']:.0f}")
    except Exception as e:  # noqa: BLE001
        emit("hier.inter_bytes", 0.0, f"SKIPPED({type(e).__name__})")

    # --- consensus: 8-way MTLS final loss per topology vs the flat master
    cparams = {"workers": 8, "epochs": 8 if fast else 15,
               "n": 800 if fast else 1600, "d": 40, "m": 30, "modes": MODES}
    try:
        cons = _cached("consensus_fast" if fast else "consensus",
                       cparams, _CONSENSUS_SCRIPT)
    except Exception as e:  # noqa: BLE001
        emit("topology.consensus", 0.0, f"SKIPPED({type(e).__name__})")
        return
    flat_loss = cons["flat+dense"]["final_loss"]
    for spec_cm, rec in cons.items():
        rel = abs(rec["final_loss"] - flat_loss) / abs(flat_loss)
        emit(
            f"topology.consensus.{spec_cm.replace(':', '_')}", 0.0,
            f"final_loss={rec['final_loss']:.6f};rel_vs_flat={rel:.4f};"
            f"gap={rec['gap']:.4f};epochs={cparams['epochs']}",
        )
