"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp

ROWS: List[str] = []


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def mtls_problem(key, n, d, m, rank=10):
    """Paper §5.1 synthetic generator: ground truth rank-10, ||W||_* = 1."""
    ku, kv, kx = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(ku, (d, max(rank, 1))))[0]
    v = jnp.linalg.qr(jax.random.normal(kv, (m, max(rank, 1))))[0]
    s = jnp.linspace(1.0, 0.1, rank)
    s = s / jnp.sum(s)
    w = (u * s) @ v.T
    x = jax.random.normal(kx, (n, d))
    return x, x @ w, w


def logistic_problem(key, n, d, m, scale=5.0):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (d, m))
    w = scale * w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    y = jnp.argmax(x @ w, axis=1)
    return x, y, w
