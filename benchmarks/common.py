"""Shared helpers for the benchmark harness.

Every ``emit`` both prints the legacy ``name,us_per_call,derived`` CSV line
and appends a structured record (suite, name, timing, parsed derived
metrics) to ``RECORDS``; ``benchmarks/run.py --json PATH`` dumps them with
environment metadata so the perf trajectory is machine-readable —
``benchmarks/check_regression.py`` consumes exactly this format in CI.
"""
from __future__ import annotations

import json
import subprocess
import time
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

ROWS: List[str] = []
RECORDS: List[Dict[str, Any]] = []
_SUITE: List[Optional[str]] = [None]


def begin_suite(name: Optional[str]) -> None:
    """Tag subsequent ``emit`` records with the suite that produced them
    (run.py calls this as it enters each suite)."""
    _SUITE[0] = name


def parse_derived(derived: str) -> Dict[str, Any]:
    """``"a=3.5;b=2x;c=foo"`` -> ``{"a": 3.5, "b": 2.0, "c": "foo"}`` — the
    loose key=value convention the suites already print, parsed so JSON
    consumers get numbers, not strings (a trailing ``x`` on speedup ratios
    is stripped)."""
    out: Dict[str, Any] = {}
    for part in derived.split(";"):
        if "=" not in part:
            continue
        k, v = part.split("=", 1)
        k, v = k.strip(), v.strip()
        val: Any = v
        for candidate in (v, v[:-1] if v.endswith("x") else None):
            if candidate is None:
                continue
            try:
                val = float(candidate)
                break
            except ValueError:
                pass
        out[k] = val
    return out


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    RECORDS.append(
        {
            "suite": _SUITE[0],
            "name": name,
            "us_per_call": float(us_per_call),
            "derived": parse_derived(derived),
        }
    )
    print(row, flush=True)


def git_commit() -> Optional[str]:
    """HEAD SHA of the repo this file lives in, or None outside a checkout
    (e.g. an installed wheel or a stripped CI artifact dir)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parent,
            capture_output=True, text=True, timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = out.stdout.strip()
    return sha if out.returncode == 0 and sha else None


def environment() -> Dict[str, Any]:
    """The reproducibility stamp written into every JSON dump: enough to
    tell two BENCH files apart before comparing their numbers."""
    dev = jax.devices()[0]
    return {
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "platform": dev.platform,
        "device_kind": dev.device_kind,
        "device_count": jax.device_count(),
        "git_commit": git_commit(),
        "timestamp_unix": time.time(),
    }


def write_json(path: str) -> None:
    """Dump all records collected so far as ``{"meta": ..., "records":
    [...]}`` — the schema ``benchmarks/check_regression.py`` reads."""
    payload = {"meta": environment(), "records": RECORDS}
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"# wrote {len(RECORDS)} records to {path}", flush=True)


def time_call(fn: Callable, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall time per call in microseconds (blocks on jax outputs)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def mtls_problem(key, n, d, m, rank=10):
    """Paper §5.1 synthetic generator: ground truth rank-10, ||W||_* = 1."""
    ku, kv, kx = jax.random.split(key, 3)
    u = jnp.linalg.qr(jax.random.normal(ku, (d, max(rank, 1))))[0]
    v = jnp.linalg.qr(jax.random.normal(kv, (m, max(rank, 1))))[0]
    s = jnp.linspace(1.0, 0.1, rank)
    s = s / jnp.sum(s)
    w = (u * s) @ v.T
    x = jax.random.normal(kx, (n, d))
    return x, x @ w, w


def logistic_problem(key, n, d, m, scale=5.0):
    kx, kw = jax.random.split(key)
    w = jax.random.normal(kw, (d, m))
    w = scale * w / jnp.linalg.norm(w, ord="nuc")
    x = jax.random.normal(kx, (n, d))
    y = jnp.argmax(x @ w, axis=1)
    return x, y, w
