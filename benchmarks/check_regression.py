"""CI perf-regression gate over the machine-readable benchmark output.

Compares a ``BENCH_*.json`` (written by ``benchmarks/run.py --json``)
against the checked-in ``benchmarks/baselines.json``::

    python -m benchmarks.check_regression BENCH_smoke.json

Baselines schema — one entry per guarded metric::

    {
      "factor": 2.0,                       # default allowed ratio
      "metrics": {
        "engine_overhead/engine.serial.scan": {
          "metric": "epochs_per_sec",      # derived key ("us_per_call" = timing)
          "baseline": 3800.0,
          "direction": "higher",           # "higher" or "lower" is better
          "factor": 2.0                    # optional per-metric override
        }
      }
    }

A "higher"-is-better metric regresses when ``measured < baseline /
factor``; "lower" when ``measured > baseline * factor``. The factor is
deliberately generous (2x by default): CI runs on shared CPU runners whose
absolute throughput wobbles, and this gate exists to catch the engine
falling off a cliff (a reintroduced per-epoch host sync is ~7x on the
serial scan path), not 10% noise. A guarded metric that is *missing* from
the measurement — suite failed, record renamed — is itself a failure:
silence must not pass the gate.
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_BASELINES = Path(__file__).resolve().parent / "baselines.json"


def load_records(bench_path: str):
    payload = json.loads(Path(bench_path).read_text())
    index = {}
    for rec in payload.get("records", []):
        index[f"{rec.get('suite')}/{rec.get('name')}"] = rec
    return payload.get("meta", {}), index


def check(bench_path: str, baselines_path: str) -> list:
    """Returns a list of failure strings (empty = gate passes)."""
    base = json.loads(Path(baselines_path).read_text())
    default_factor = float(base.get("factor", 2.0))
    meta, records = load_records(bench_path)
    failures = []
    for key, spec in base.get("metrics", {}).items():
        rec = records.get(key)
        if rec is None:
            failures.append(f"{key}: no record in {bench_path} (suite failed?)")
            continue
        metric = spec.get("metric", "us_per_call")
        value = (
            rec.get("us_per_call")
            if metric == "us_per_call"
            else rec.get("derived", {}).get(metric)
        )
        if not isinstance(value, (int, float)):
            failures.append(
                f"{key}: derived metric {metric!r} missing or non-numeric "
                f"(got {value!r})"
            )
            continue
        baseline = float(spec["baseline"])
        factor = float(spec.get("factor", default_factor))
        direction = spec.get("direction", "higher")
        if direction == "higher":
            ok, bound = value >= baseline / factor, baseline / factor
            cmp = f"{value:.3g} < allowed minimum {bound:.3g}"
        elif direction == "lower":
            ok, bound = value <= baseline * factor, baseline * factor
            cmp = f"{value:.3g} > allowed maximum {bound:.3g}"
        else:
            failures.append(f"{key}: bad direction {direction!r}")
            continue
        status = "ok" if ok else "REGRESSION"
        print(
            f"{status:>10}  {key} {metric}={value:.4g} "
            f"(baseline {baseline:.4g}, {direction} is better, {factor}x slack)"
        )
        if not ok:
            failures.append(f"{key}: {metric} {cmp} ({factor}x vs {baseline:.4g})")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("bench_json", help="BENCH_*.json from benchmarks.run --json")
    ap.add_argument("--baselines", default=str(DEFAULT_BASELINES))
    args = ap.parse_args()
    failures = check(args.bench_json, args.baselines)
    if failures:
        sys.exit("perf regression gate FAILED:\n  " + "\n  ".join(failures))
    print("perf regression gate passed")


if __name__ == "__main__":
    main()
