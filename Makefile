# One-word entry points for the checks CI and contributors run.
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast bench-smoke bench-check trace-smoke lint analyze

# Tier-1 verify (see ROADMAP.md): full pytest suite, stop at first failure.
test:
	$(PYTHON) -m pytest -x -q

# Pre-merge gate: skips @pytest.mark.slow (multi-minute convergence sweeps
# and subprocess-heavy multi-device tests). CI runs this lane.
test-fast:
	$(PYTHON) -m pytest -x -q -m "not slow"

# Fast pass over the paper-figure benchmark suites (small problem sizes).
# Writes the machine-readable perf record BENCH_smoke.json at the repo root;
# CI uploads it as an artifact and gates on benchmarks/check_regression.py.
bench-smoke:
	$(PYTHON) -m benchmarks.run --fast --json BENCH_smoke.json

# Compare the smoke record against the checked-in baselines (the CI gate).
bench-check:
	$(PYTHON) -m benchmarks.check_regression BENCH_smoke.json

# Short instrumented train->serve run; writes TRACE_smoke.jsonl plus the
# Perfetto-loadable TRACE_smoke.trace.json and validates both parse and
# cover all four instrumented layers (docs/OBSERVABILITY.md). CI uploads
# the trace files as artifacts from the bench-smoke job.
trace-smoke:
	$(PYTHON) tools/trace_smoke.py

# Repo-specific correctness gate (docs/ANALYSIS.md): tier 1 is the REPxxx
# AST lint (fails on findings not frozen in tools/repro_lint_baseline.json),
# tier 2 compiles the layer-declared HLO/dispatch contracts on 8 fake CPU
# devices and asserts them against the emitted HLO + runtime counters.
analyze:
	$(PYTHON) tools/repro_lint.py
	$(PYTHON) tools/repro_contracts.py

# Syntax sweep; uses ruff/flake8 when available, byte-compilation otherwise.
lint:
	@if $(PYTHON) -m ruff --version >/dev/null 2>&1; then \
		$(PYTHON) -m ruff check src tests benchmarks examples; \
	elif $(PYTHON) -m flake8 --version >/dev/null 2>&1; then \
		$(PYTHON) -m flake8 src tests benchmarks examples; \
	else \
		$(PYTHON) -m compileall -q src tests benchmarks examples && echo "lint: compileall clean (install ruff for style checks)"; \
	fi
